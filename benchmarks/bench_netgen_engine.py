"""Online serving engine load generator (ISSUE 7 acceptance).

Drives `repro.netgen.engine.ServingEngine` with the two canonical load
shapes and reports p50/p99 latency and throughput in the
`name,us_per_call,derived` CSV idiom (us = p50; derived =
`p99us=...;rps=...`), persisted into `BENCH_netgen.json` by
`benchmarks/run.py`:

  * closed loop — C client threads, each blocking on `infer` in a tight
    loop. The naive baseline is the SAME engine with `slot_capacity=1`
    and zero batch delay: every request pays one full dispatch, the
    i7-style per-call software overhead the paper's §V throughput table
    charges against the CPU. Continuous slot batching amortizes that
    dispatch across the C clients — the acceptance claim is >= 5x the
    naive throughput at equal-or-better p99 on the paper-sized
    784-500-10 net (asserted under --full).

Both engines serve the bit-plane popcount datapath
(`pallas[planes=true]`, PR 5) — the backend whose cost shape batching
is FOR: ~670us fixed per launch at 784-500-10, ~35us marginal per row.
The dense int32 `jnp` artifact is the wrong instrument for this
measurement on CPU: XLA has no fast int32 GEMM, so its per-row cost
RISES past b=8 (368us/row at b=1, ~980us/row at b>=32) and batching
through it is a strict loss — no engine policy can amortize a backend
with no fixed cost to amortize. The baseline/batched comparison keeps
the backend identical on both sides so the only variable is the
batching policy.

  * open loop — Poisson arrivals (seeded; exponential inter-arrival
    gaps) over a rate sweep, submitted asynchronously via `submit`,
    end-to-end latency timestamped by future callbacks. Open loop is
    the honest SLO view: arrivals do not slow down when the server
    falls behind, so queueing delay shows up in p99 instead of
    silently throttling the offered load.

  PYTHONPATH=src python benchmarks/bench_netgen_engine.py \\
      [--full] [--smoke] [--json bench_netgen_engine.json]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np


def _net(sizes, seed: int = 0):
    from repro.core import quantize
    rng = np.random.default_rng(seed)
    return quantize.QuantizedNet(weights=[
        rng.integers(-5, 6, size=s).astype(np.int32)
        for s in zip(sizes, sizes[1:])])


def _images(b: int, n_in: int, seed: int = 9) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, 256, size=(b, n_in)).astype(np.uint8)


def _pcts(lat_s: list[float]) -> tuple[float, float]:
    """(p50, p99) in seconds over the collected request latencies."""
    a = np.asarray(lat_s)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _closed_loop(engine, version: str, images: np.ndarray, clients: int,
                 duration_s: float) -> dict:
    """C threads blocking on `infer`; returns latencies + throughput."""
    lat: list[float] = []
    lock = threading.Lock()
    start = time.perf_counter()
    t_end = start + duration_s

    def client(seed: int) -> None:
        rng = np.random.default_rng(seed)
        mine = []
        while time.perf_counter() < t_end:
            x = images[rng.integers(0, images.shape[0])]
            t0 = time.perf_counter()
            engine.infer(version, x)
            mine.append(time.perf_counter() - t0)
        with lock:
            lat.extend(mine)

    threads = [threading.Thread(target=client, args=(1000 + i,))
               for i in range(clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    span = time.perf_counter() - start
    p50, p99 = _pcts(lat)
    return {"clients": clients, "completed": len(lat),
            "duration_s": span, "rps": len(lat) / span,
            "p50_us": p50 * 1e6, "p99_us": p99 * 1e6}


def _open_loop(engine, version: str, images: np.ndarray, rate: float,
               duration_s: float, seed: int = 5) -> dict:
    """Poisson arrivals at `rate` req/s for `duration_s`; end-to-end
    latency (submit -> future done) via done callbacks. Arrivals are
    precomputed from a seeded exponential, so runs are reproducible."""
    rng = np.random.default_rng(seed)
    n = max(1, int(rate * duration_s))
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    picks = rng.integers(0, images.shape[0], size=n)

    lat: list[float] = []
    errors = [0]
    rejected = [0]
    lock = threading.Lock()
    done = threading.Semaphore(0)

    def _cb(t0):
        def cb(fut):
            dt = time.perf_counter() - t0
            with lock:
                if fut.exception() is None:
                    lat.append(dt)
                else:
                    errors[0] += 1
            done.release()
        return cb

    start = time.perf_counter()
    submitted = 0
    for i in range(n):
        delay = start + arrivals[i] - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t0 = time.perf_counter()
        try:
            engine.submit(version, images[picks[i]]).add_done_callback(
                _cb(t0))
            submitted += 1
        except Exception:  # noqa: BLE001 — queue-full shedding is the point
            rejected[0] += 1
    for _ in range(submitted):
        done.acquire()
    span = time.perf_counter() - start
    p50, p99 = _pcts(lat) if lat else (0.0, 0.0)
    return {"rate": rate, "offered": n, "completed": len(lat),
            "rejected": rejected[0], "errors": errors[0],
            "duration_s": span, "rps": len(lat) / span,
            "p50_us": p50 * 1e6, "p99_us": p99 * 1e6}


def _row(name: str, m: dict) -> str:
    return (f"{name},{m['p50_us']:.0f},"
            f"p99us={m['p99_us']:.0f};rps={m['rps']:.0f}")


def run(full: bool = False, smoke: bool = False,
        json_path: str | None = None) -> list[str]:
    """`smoke` is the tier-1 CI mode: tiny net, fractions of a second of
    load, no throughput assertions — it proves the engine serves
    concurrent traffic and the rows parse, not a perf claim."""
    from repro import netgen

    # the acceptance claim is about the paper's 784-500-10 net
    sizes = (784, 500, 10) if full else ((64, 32, 10) if smoke
                                         else (96, 48, 10))
    clients = 4 if smoke else 32
    cap = clients          # batched engine can absorb one full closed round
    duration = 0.25 if smoke else (2.0 if full else 0.8)
    rates = ((400.0,) if smoke else
             (1000.0, 4000.0, 16000.0) if full else (500.0, 2000.0))
    delay = 0.002

    target = "pallas[planes=true]"     # see module docstring: the packed
    qnet = _net(sizes)                 # datapath is the one batching amortizes
    images = _images(256, sizes[0])
    rows: list[str] = []
    results: dict = {"sizes": list(sizes), "clients": clients,
                     "slot_capacity": cap, "max_batch_delay": delay,
                     "target": target}

    # oracle for a bit-exactness spot check on engine answers
    oracle = netgen.compile_artifact(qnet, target="jnp")

    # -- closed loop: naive one-request-per-dispatch vs continuous batching --
    with netgen.ServingEngine(target=target, slot_capacity=1,
                              max_batch_delay=0.0,
                              max_queue_depth=1 << 16) as naive:
        naive.register("v", qnet)
        spot = images[:8]
        got = np.array([naive.infer("v", x) for x in spot])
        assert np.array_equal(got, np.asarray(oracle(spot))), "naive diverged"
        naive_m = _closed_loop(naive, "v", images, clients, duration)
    results["closed_naive"] = naive_m
    rows.append(_row(f"netgen_engine_closed_naive_c{clients}", naive_m))

    with netgen.ServingEngine(target=target, slot_capacity=cap,
                              max_batch_delay=delay,
                              max_queue_depth=1 << 16) as batched:
        batched.register("v", qnet)
        got = np.array([batched.infer("v", x) for x in spot])
        assert np.array_equal(got, np.asarray(oracle(spot))), \
            "batched engine diverged"
        batched_m = _closed_loop(batched, "v", images, clients, duration)

        # -- open loop: Poisson rate sweep on the batched engine ------------
        results["open_loop"] = []
        for rate in rates:
            m = _open_loop(batched, "v", images, rate, duration)
            results["open_loop"].append(m)
            rows.append(_row(f"netgen_engine_open_r{int(rate)}", m))

        results["engine_stats"] = vars(batched.stats())
    results["closed_batched"] = batched_m
    rows.insert(1, _row(f"netgen_engine_closed_batched_c{clients}",
                        batched_m))

    # -- the ISSUE 7 acceptance: >= 5x throughput at equal-or-better p99 ----
    speedup = batched_m["rps"] / max(naive_m["rps"], 1e-9)
    equal_p99 = batched_m["p99_us"] <= naive_m["p99_us"] * 1.10
    results["speedup_at_equal_p99"] = {
        "throughput_x": speedup, "equal_or_better_p99": equal_p99,
        "naive_p99_us": naive_m["p99_us"],
        "batched_p99_us": batched_m["p99_us"]}
    rows.append(f"netgen_engine_speedup_equal_p99,"
                f"{batched_m['p99_us']:.0f},{speedup:.1f}")
    if not smoke:
        assert equal_p99, (
            f"batched p99 {batched_m['p99_us']:.0f}us worse than naive "
            f"{naive_m['p99_us']:.0f}us — not an equal-p99 comparison")
    if full:
        assert speedup >= 5.0, (
            f"continuous batching only {speedup:.1f}x naive throughput "
            f"(acceptance needs >= 5x on the paper-sized net)")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tier-1 CI mode: tiny net, sub-second load, "
                         "no perf assertions")
    ap.add_argument("--json", default=None,
                    help="write the full measurement set here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(full=args.full, smoke=args.smoke, json_path=args.json):
        print(row, flush=True)


if __name__ == "__main__":
    main()
