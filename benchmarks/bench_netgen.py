"""Paper §V.D table: resource counts through the netgen rewrites.

Paper: >80k logic cells (naive) -> 38k (pruned) -> <16k (addend form).
Our units: multiply/add operation counts per prediction (what the cell
counts are proportional to), plus emitted-Verilog size as the direct
artifact analogue.
"""
from __future__ import annotations

import time


def run(full: bool = False) -> list[str]:
    import numpy as np
    from repro.core import dataset, mlp, netgen, quantize

    n_hidden = 500 if full else 128
    epochs = 60 if full else 20
    xtr, ytr, *_ = dataset.train_test_split(800, 10, seed=1)
    cfg = mlp.MLPConfig(n_hidden=n_hidden, epochs=epochs, seed=4)
    t0 = time.time()
    params = mlp.train(cfg, xtr, ytr)
    qnet = quantize.quantize(params)
    st = netgen.stats(qnet)
    _, pinfo = netgen.prune(qnet)
    dt = (time.time() - t0) * 1e6

    rows = [
        f"netgen_mults_dense,{dt:.0f},{st.mults_dense}",
        f"netgen_mults_pruned,0,{st.mults_pruned}",
        f"netgen_mults_addend,0,{st.mults_addend}",
        f"netgen_adds_addend,0,{st.adds_addend}",
        f"netgen_zero_fraction,0,{st.zero_fraction:.4f}",
        f"netgen_hidden_removed,0,{pinfo.hidden_removed}",
    ]
    # Verilog artifact (3x3 always; full-size only with --full: ~100 MB text)
    demo = quantize.QuantizedNet(
        w1=np.clip(qnet.w1[:3, :3], -9, 9), w2=np.clip(qnet.w2[:3, :3], -9, 9))
    v = netgen.emit_verilog(demo, addend=True)
    rows.append(f"netgen_verilog_3x3_lines,0,{len(v.splitlines())}")
    if full:
        t0 = time.time()
        vfull = netgen.emit_verilog(qnet, addend=False)
        rows.append(f"netgen_verilog_full_bytes,{(time.time()-t0)*1e6:.0f},{len(vfull)}")
    return rows
