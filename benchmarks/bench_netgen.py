"""Paper §V.D table: resource counts through the netgen rewrites.

Paper: >80k logic cells (naive) -> 38k (pruned) -> <16k (addend form).
Our units: multiply/add operation counts per prediction (what the cell
counts are proportional to), plus emitted-Verilog size as the direct
artifact analogue. Now routed through the `repro.netgen` compiler (the
old `repro.core.netgen` names are a shim over it); per-pass attribution
lives in bench_netgen_passes.
"""
from __future__ import annotations

import time


def run(full: bool = False) -> list[str]:
    import numpy as np
    from repro.core import dataset, mlp, quantize
    from repro import netgen

    n_hidden = 500 if full else 128
    epochs = 60 if full else 20
    xtr, ytr, *_ = dataset.train_test_split(800, 10, seed=1)
    cfg = mlp.MLPConfig(n_hidden=n_hidden, epochs=epochs, seed=4)
    t0 = time.time()
    params = mlp.train(cfg, xtr, ytr)
    qnet = quantize.quantize(params)
    circuit = netgen.lower(qnet)
    dense = netgen.ops(circuit)
    # zero_fraction counts only zero-weight terms (comparable with the
    # paper's ~50% and prior runs); dead-unit pruning is reported separately
    nz = netgen.ops(netgen.delete_zero_terms(circuit))
    pruned_c, _ = netgen.run_pipeline(circuit, netgen.DEFAULT_PASSES)
    dt = (time.time() - t0) * 1e6

    n_hidden_before = sum(
        1 for n in circuit.by_kind(netgen.WeightedSum) if n.layer < circuit.depth)
    n_hidden_after = sum(
        1 for n in pruned_c.by_kind(netgen.WeightedSum) if n.layer < pruned_c.depth)
    rows = [
        f"netgen_mults_dense,{dt:.0f},{dense.terms}",
        f"netgen_mults_pruned,0,{nz.terms}",
        f"netgen_mults_addend,0,0",
        f"netgen_adds_addend,0,{nz.addend_units}",
        f"netgen_zero_fraction,0,{1.0 - nz.terms / dense.terms:.4f}",
        f"netgen_hidden_removed,0,{n_hidden_before - n_hidden_after}",
    ]
    # Verilog artifact (3x3 always; full-size only with --full: ~100 MB text)
    demo = quantize.QuantizedNet(
        w1=np.clip(qnet.w1[:3, :3], -9, 9), w2=np.clip(qnet.w2[:3, :3], -9, 9))
    v = netgen.emit_verilog(demo, addend=True)
    rows.append(f"netgen_verilog_3x3_lines,0,{len(v.splitlines())}")
    if full:
        t0 = time.time()
        vfull = netgen.emit_verilog(qnet, addend=False)
        rows.append(f"netgen_verilog_full_bytes,{(time.time()-t0)*1e6:.0f},{len(vfull)}")
    return rows
