"""Compile-cache serving benchmark (the paper's compile-per-model economics).

Measures, in the `bench_throughput` CSV idiom:

  * cold compile (cache miss + first-trace warmup) vs warm predictor
    acquisition (cache hit) — ISSUE 2 acceptance: warm >= 100x faster
  * multi-version stacked dispatch (M versions, ONE jitted call) vs
    serving each CompiledNet individually, for M in 1..8 and batch
    sizes 1..1024, with a bit-exactness check on every configuration

The full measurement set is also written as JSON (CI uploads it as an
artifact):

  PYTHONPATH=src python benchmarks/bench_netgen_serve.py [--full] \\
      [--json bench_netgen_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _nets(m: int, sizes, seed: int = 0):
    from repro.core import quantize
    out = []
    for i in range(m):
        rng = np.random.default_rng(seed + i)
        out.append(quantize.QuantizedNet(weights=[
            rng.integers(-5, 6, size=s).astype(np.int32)
            for s in zip(sizes, sizes[1:])]))
    return out


def _images(b: int, n_in: int, seed: int = 9) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, 256, size=(b, n_in)).astype(np.uint8)


def run(full: bool = False, json_path: str | None = None) -> list[str]:
    from repro import netgen

    sizes = (784, 128, 10) if full else (96, 48, 10)
    m_versions = (1, 2, 4, 8) if full else (1, 2, 4)
    batches = (1, 32, 1024) if full else (1, 32, 256)
    reps = 5 if full else 3
    warm_reps = 1000

    rows: list[str] = []
    results: dict = {"sizes": list(sizes), "backend": "jnp",
                     "cold_ms": [], "multi": []}
    nets = _nets(max(m_versions), sizes)

    # -- cold compile vs warm acquisition -----------------------------------
    cache = netgen.CompileCache(capacity=64)
    warm_batch = _images(32, sizes[0])
    for net in nets:
        t0 = time.perf_counter()
        compiled = cache.get_or_compile(net)
        np.asarray(compiled(warm_batch))     # includes first-trace jit cost
        results["cold_ms"].append((time.perf_counter() - t0) * 1e3)
    cold_s = float(np.mean(results["cold_ms"])) / 1e3

    t0 = time.perf_counter()
    for _ in range(warm_reps):
        for net in nets:
            cache.get_or_compile(net)
    warm_s = (time.perf_counter() - t0) / (warm_reps * len(nets))
    speedup = cold_s / warm_s
    results["warm_us"] = warm_s * 1e6
    results["warm_vs_cold_speedup"] = speedup
    results["cache_stats"] = vars(cache.stats())
    rows.append(f"netgen_serve_cold_compile,{cold_s*1e6:.0f},{1.0/cold_s:.1f}")
    rows.append(f"netgen_serve_warm_acquire,{warm_s*1e6:.2f},{1.0/warm_s:.0f}")
    rows.append(f"netgen_serve_warm_vs_cold_speedup,{warm_s*1e6:.2f},{speedup:.0f}")

    # -- stacked multi-net dispatch vs individual serving -------------------
    for m in m_versions:
        for b in batches:
            server = netgen.NetServer(cache=cache, slot_capacity=b)
            for i in range(m):
                server.register(f"v{i}", nets[i])
            reqs = {f"v{i}": _images(b, sizes[0], seed=100 + i)
                    for i in range(m)}

            out = server.predict_many(reqs)          # warm both paths
            individual = {v: np.asarray(server.compiled_for(v)(x))
                          for v, x in reqs.items()}
            exact = all(np.array_equal(out[v], individual[v]) for v in reqs)

            t0 = time.perf_counter()
            for _ in range(reps):
                server.predict_many(reqs)
            dt_stacked = (time.perf_counter() - t0) / reps

            t0 = time.perf_counter()
            for _ in range(reps):
                for v, x in reqs.items():
                    np.asarray(server.compiled_for(v)(x))
            dt_indiv = (time.perf_counter() - t0) / reps

            preds = m * b
            results["multi"].append({
                "versions": m, "batch": b, "exact": exact,
                "stacked_dispatch": bool(m > 1),
                "stacked_us": dt_stacked * 1e6,
                "individual_us": dt_indiv * 1e6,
                "stacked_preds_per_s": preds / dt_stacked,
                "individual_preds_per_s": preds / dt_indiv,
            })
            assert exact, f"stacked dispatch diverged at m={m} b={b}"
            rows.append(f"netgen_serve_stacked_m{m}_b{b},"
                        f"{dt_stacked*1e6:.1f},{preds/dt_stacked:.0f}")
            rows.append(f"netgen_serve_individual_m{m}_b{b},"
                        f"{dt_indiv*1e6:.1f},{preds/dt_indiv:.0f}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default="bench_netgen_serve.json",
                    help="write the full measurement set here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(full=args.full, json_path=args.json):
        print(row, flush=True)


if __name__ == "__main__":
    main()
