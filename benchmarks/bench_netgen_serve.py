"""Compile-cache serving benchmark (the paper's compile-per-model economics).

Measures, in the `bench_throughput` CSV idiom:

  * cold compile (cache miss + first-trace warmup) vs warm predictor
    acquisition (cache hit) — ISSUE 2 acceptance: warm >= 100x faster
  * cold PROCESS vs warm STORE (ISSUE 3): a fresh Session pointed at an
    already-populated ArtifactStore directory loads the persisted
    artifact instead of recompiling — the cross-process warm-start the
    store exists for (load time vs full compile time, zero compiles
    asserted)
  * multi-version stacked dispatch (M versions, ONE jitted call) vs
    serving each compiled predictor individually, for M in 1..8 and
    batch sizes 1..1024, with a bit-exactness check on every
    configuration
  * the pallas activation/weight datapaths (ISSUE 4 + 5 + 9): dense vs
    `pallas[packed=true]` (end-to-end bit-packed activations) vs
    `pallas[planes=true]` (fully bit-packed: weights decomposed into
    popcount-accumulated signed bit-planes) vs `pallas[fusednet=true]`
    (the whole-net megakernel: every layer in ONE persistent launch),
    measured on the paper-sized 784-500-10 net under --full (bit-exact
    asserted against the jnp oracle) — the ISSUE-5 acceptance row
    (planes must beat the PR-4 packed path) and the ISSUE-9 one
    (fusednet must beat the per-layer planes chain by >= 1.2x)
  * the roofline gap (ISSUE 9): XLA `jit_cost` bytes/flops of the
    fusednet megakernel vs its measured time — the bytes-bound time at
    an assumed HBM bandwidth becomes the denominator of a tracked
    gap-to-hardware ratio (`netgen_roofline_*` rows; enormous in
    interpret mode on CPU, the point is the trend)
  * the persistent autotuner (ISSUE 5): `pallas[tuned=true]` grid
    search wall-clock, the winning (form, bm, bn, bkw), and the tuned
    predictor's timing next to the fixed-default forms
  * the design-space explorer (ISSUE 10): `Session.explore`'s joint
    pipeline x datapath x tile winner timed against the hand-tuned
    `pallas[tuned=true,fusednet=true]` path — the `netgen_explored_b256`
    row plus the pair-carrying `netgen_explored_vs_tuned_speedup` ratio
    row; --full asserts the explored config is no worse (>= 1.0x, or
    the search landed on the identical kernel config)
  * sharded vs single-device stacked serving (ISSUE 4): predict_many
    under a mesh with a data axis (shard_map over the slot dimension)
    vs the same requests without a mesh, bit-exact asserted; pass
    --fake-devices 8 (standalone runs only — the flag must precede
    jax initialization) to spread over faked host devices

The JSON artifact (CI uploads it) additionally registers the `cost`
target's Figure-7-style logic-cell estimates per pass for the benchmark
net.

  PYTHONPATH=src python benchmarks/bench_netgen_serve.py [--full] \\
      [--fake-devices N] [--json FILE]

The detailed measurement JSON is written ONLY when a path is given
(standalone --json, or benchmarks.run --serve-json): a run must never
drop artifacts outside its declared output paths — BENCH_netgen.json
is the single committed trajectory file.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np

# Roofline denominator: assumed HBM bandwidth of a TPU-class part. The
# bytes-bound time `bytes_accessed / _HBM_GBPS` is a hardware floor, not
# a CPU-interpret expectation — the measured/bound ratio it yields is
# the tracked gap-to-hardware number (ROADMAP item 4).
_HBM_GBPS = 900.0


def _nets(m: int, sizes, seed: int = 0):
    from repro.core import quantize
    out = []
    for i in range(m):
        rng = np.random.default_rng(seed + i)
        out.append(quantize.QuantizedNet(weights=[
            rng.integers(-5, 6, size=s).astype(np.int32)
            for s in zip(sizes, sizes[1:])]))
    return out


def _images(b: int, n_in: int, seed: int = 9) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, 256, size=(b, n_in)).astype(np.uint8)


def _timed_mean(section: str, fn, reps: int) -> float:
    """Mean seconds per call over `reps` calls, timed through
    `telemetry.timed` — the SAME histogram code path production latency
    metrics use, so bench numbers and serving metrics cannot drift."""
    from repro.netgen import telemetry
    with telemetry.timed("bench_serve_seconds", section=section) as t:
        for _ in range(reps):
            fn()
    return t.elapsed / reps


def run(full: bool = False, json_path: str | None = None) -> list[str]:
    from repro import netgen

    sizes = (784, 128, 10) if full else (96, 48, 10)
    m_versions = (1, 2, 4, 8) if full else (1, 2, 4)
    batches = (1, 32, 1024) if full else (1, 32, 256)
    reps = 5 if full else 3
    warm_reps = 1000

    rows: list[str] = []
    results: dict = {"sizes": list(sizes), "backend": "jnp",
                     "cold_ms": [], "multi": []}
    nets = _nets(max(m_versions), sizes)

    # -- cold compile vs warm acquisition -----------------------------------
    cache = netgen.CompileCache(capacity=64)
    warm_batch = _images(32, sizes[0])
    for net in nets:
        t0 = time.perf_counter()
        compiled = cache.get_or_compile(net)
        np.asarray(compiled(warm_batch))     # includes first-trace jit cost
        results["cold_ms"].append((time.perf_counter() - t0) * 1e3)
    cold_s = float(np.mean(results["cold_ms"])) / 1e3

    warm_s = _timed_mean(
        "warm_acquire",
        lambda: [cache.get_or_compile(net) for net in nets],
        warm_reps) / len(nets)
    speedup = cold_s / warm_s
    results["warm_us"] = warm_s * 1e6
    results["warm_vs_cold_speedup"] = speedup
    results["cache_stats"] = vars(cache.stats())
    rows.append(f"netgen_serve_cold_compile,{cold_s*1e6:.0f},{1.0/cold_s:.1f}")
    rows.append(f"netgen_serve_warm_acquire,{warm_s*1e6:.2f},{1.0/warm_s:.0f}")
    # ratio rows carry no us_per_call of their own (it used to duplicate
    # the numerator row's): derived holds the ratio AND its measurement
    # pair, so the row is self-contained in BENCH_netgen.json
    rows.append(f"netgen_serve_warm_vs_cold_speedup,0,"
                f"ratio={speedup:.1f};cold_us={cold_s*1e6:.0f};"
                f"warm_us={warm_s*1e6:.2f}")

    # -- cold process vs warm store (persisted-artifact load) ----------------
    with tempfile.TemporaryDirectory() as store_dir:
        cold_sess = netgen.Session(store=store_dir)
        t0 = time.perf_counter()
        art = cold_sess.compile(nets[0], target="jnp")
        np.asarray(art(warm_batch))
        cold_process_s = time.perf_counter() - t0

        warm_sess = netgen.Session(store=store_dir)   # simulated new process
        t0 = time.perf_counter()
        warm_art = warm_sess.compile(nets[0], target="jnp")
        np.asarray(warm_art(warm_batch))
        warm_store_s = time.perf_counter() - t0
        st = warm_sess.stats()
        assert (st.compiles, st.store_hits) == (0, 1), vars(st)
        assert np.array_equal(np.asarray(art(warm_batch)),
                              np.asarray(warm_art(warm_batch)))
        results["store"] = {
            "cold_process_ms": cold_process_s * 1e3,
            "warm_store_ms": warm_store_s * 1e3,
            "speedup": cold_process_s / warm_store_s,
            "warm_compiles": st.compiles,
            "warm_store_hits": st.store_hits,
        }
        rows.append(f"netgen_serve_cold_process,{cold_process_s*1e6:.0f},"
                    f"{1.0/cold_process_s:.1f}")
        rows.append(f"netgen_serve_warm_store,{warm_store_s*1e6:.0f},"
                    f"{1.0/warm_store_s:.1f}")
        rows.append(f"netgen_serve_store_speedup,0,"
                    f"ratio={cold_process_s/warm_store_s:.1f};"
                    f"cold_process_us={cold_process_s*1e6:.0f};"
                    f"warm_store_us={warm_store_s*1e6:.0f}")

    # -- Figure-7-style logic-cell estimates (cost target) -------------------
    cost = netgen.compile_artifact(
        nets[0], target="cost", pipeline="zeros,prune,addends").artifact
    results["cost_fig7"] = cost.as_dict()
    for stage, cells in cost.per_pass:
        rows.append(f"netgen_cost_cells_{stage},0,{cells.total}")

    # -- pallas datapaths: dense vs packed vs planes (ISSUE 4 + 5) ----------
    psizes = (784, 500, 10) if full else sizes        # paper net under --full
    pnet = _nets(1, psizes, seed=7)[0]
    pb = 256
    px = _images(pb, psizes[0], seed=11)
    oracle = netgen.compile_artifact(pnet, target="jnp")
    forms = {"dense": netgen.compile_artifact(pnet, target="pallas"),
             "packed": netgen.compile_artifact(
                 pnet, target="pallas[packed=true]"),
             "planes": netgen.compile_artifact(
                 pnet, target="pallas[planes=true]"),
             "fusednet": netgen.compile_artifact(
                 pnet, target="pallas[fusednet=true]")}
    want = np.asarray(oracle(px))
    results["packed"] = {"sizes": list(psizes), "batch": pb}
    for form, art in forms.items():
        got = np.asarray(art(px))                    # warm + exactness
        assert np.array_equal(got, want), f"{form} diverged from jnp oracle"
        # best-of-3 means: the fusednet_vs_planes ratio below is a hard
        # acceptance gate, so each form gets the low-noise protocol the
        # telemetry-overhead section already uses
        dt = min(_timed_mean(f"pallas_{form}",
                             lambda art=art: np.asarray(art(px)), reps)
                 for _ in range(3))
        results["packed"][form] = {
            "us_per_batch": dt * 1e6, "preds_per_s": pb / dt,
            "plan_form": art.plan_form, "exact_vs_jnp": True,
        }
        rows.append(f"netgen_serve_pallas_{form}_b{pb},"
                    f"{dt*1e6:.0f},{pb/dt:.0f}")
    results["packed"]["packed_vs_dense_speedup"] = (
        results["packed"]["dense"]["us_per_batch"]
        / results["packed"]["packed"]["us_per_batch"])
    # ISSUE 5 acceptance: the bit-plane datapath beats the PR-4 packed path
    planes_vs_packed = (results["packed"]["packed"]["us_per_batch"]
                        / results["packed"]["planes"]["us_per_batch"])
    results["packed"]["planes_vs_packed_speedup"] = planes_vs_packed
    results["packed"]["planes_vs_dense_speedup"] = (
        results["packed"]["dense"]["us_per_batch"]
        / results["packed"]["planes"]["us_per_batch"])
    rows.append(f"netgen_serve_planes_vs_packed_speedup,0,"
                f"ratio={planes_vs_packed:.2f};"
                f"packed_us={results['packed']['packed']['us_per_batch']:.0f};"
                f"planes_us={results['packed']['planes']['us_per_batch']:.0f}")
    # ISSUE 9 acceptance: the whole-net megakernel beats the per-layer
    # planes chain (one launch + zero HBM round-trips for activations
    # vs depth launches) by >= 1.2x on the paper net
    fusednet_vs_planes = (results["packed"]["planes"]["us_per_batch"]
                          / results["packed"]["fusednet"]["us_per_batch"])
    results["packed"]["fusednet_vs_planes_speedup"] = fusednet_vs_planes
    rows.append(
        f"netgen_serve_fusednet_vs_planes_speedup,0,"
        f"ratio={fusednet_vs_planes:.2f};"
        f"planes_us={results['packed']['planes']['us_per_batch']:.0f};"
        f"fusednet_us={results['packed']['fusednet']['us_per_batch']:.0f}")
    if full:    # the acceptance claims are about the paper-sized net; the
        # fast-mode net is small enough for timing noise to flip ordering
        assert planes_vs_packed > 1.0, (
            f"planes datapath did not beat packed: {planes_vs_packed:.2f}x")
        assert fusednet_vs_planes >= 1.2, (
            f"fusednet megakernel did not beat the per-layer planes "
            f"chain by 1.2x: {fusednet_vs_planes:.2f}x")

    # -- persistent autotuner (ISSUE 5): search cost + tuned predictor ------
    tune_sess = netgen.Session()        # in-memory tuner (default_tuner)
    t0 = time.perf_counter()
    tuned = tune_sess.compile(pnet, target="pallas[tuned=true]")
    tune_s = time.perf_counter() - t0
    tuner = netgen.default_tuner()
    got = np.asarray(tuned(px))
    assert np.array_equal(got, want), "tuned datapath diverged from oracle"
    dt_tuned = _timed_mean("pallas_tuned",
                           lambda: np.asarray(tuned(px)), reps)
    results["tuned"] = {
        "search_ms": tune_s * 1e3,
        "plan_form": tuned.plan_form,
        "blocks": tuned.artifact.blocks,
        "us_per_batch": dt_tuned * 1e6,
        "preds_per_s": pb / dt_tuned,
        "tuner_stats": vars(tuner.stats),
    }
    rows.append(f"netgen_serve_pallas_tuned_b{pb},"
                f"{dt_tuned*1e6:.0f},{pb/dt_tuned:.0f}")
    rows.append(f"netgen_serve_tune_search,{tune_s*1e6:.0f},"
                f"{tuner.stats.measurements}")

    # -- design-space explorer (ISSUE 10): joint search vs hand-tuned -------
    # The explorer searches pipeline x datapath x tiles as ONE problem;
    # the acceptance claim is that its winner is no worse than the
    # hand-coded `pallas[tuned=true,fusednet=true]` path on the paper
    # net. Both sides get the same best-of-3 low-noise protocol.
    rep = tune_sess.explore(pnet, objective="latency", strategy="anneal",
                            budget=16 if full else 10, seed=0, batch=pb)
    spec, etgt = rep.best_config()
    explored = tune_sess.compile(pnet, target=etgt,
                                 pipeline=spec.spec_string())
    got = np.asarray(explored(px))
    assert np.array_equal(got, want), "explored config diverged from oracle"
    dt_explored = min(_timed_mean("pallas_explored",
                                  lambda: np.asarray(explored(px)), reps)
                      for _ in range(3))
    hand = tune_sess.compile(pnet, target="pallas[tuned=true,fusednet=true]")
    dt_hand = min(_timed_mean("pallas_hand_tuned",
                              lambda: np.asarray(hand(px)), reps)
                  for _ in range(3))
    explored_vs_tuned = dt_hand / dt_explored
    same_config = (explored.plan_form == hand.plan_form
                   and explored.artifact.datapath == hand.artifact.datapath
                   and explored.artifact.blocks == hand.artifact.blocks)
    results["explored"] = {
        "target": etgt, "pipeline": spec.spec_string(),
        "candidates": rep.candidates, "pruned": len(rep.pruned),
        "measured": len(rep.evaluations),
        "us_per_batch": dt_explored * 1e6,
        "hand_tuned_us_per_batch": dt_hand * 1e6,
        "explored_vs_tuned_speedup": explored_vs_tuned,
        "same_config_as_hand_tuned": same_config,
    }
    rows.append(f"netgen_explored_b{pb},"
                f"{dt_explored*1e6:.0f},{pb/dt_explored:.0f}")
    rows.append(f"netgen_explored_vs_tuned_speedup,0,"
                f"ratio={explored_vs_tuned:.2f};"
                f"tuned_us={dt_hand*1e6:.0f};"
                f"explored_us={dt_explored*1e6:.0f}")
    if full:
        # ISSUE 10 acceptance: the joint search finds a config no worse
        # than the hand-tuned fusednet path. When the search lands on
        # the *same* kernel config, "no worse" holds by definition and
        # the measured ratio is pure timing noise around 1.0.
        assert explored_vs_tuned >= 1.0 or same_config, (
            f"explored config ({etgt}) is worse than the hand-tuned "
            f"fusednet path: {explored_vs_tuned:.2f}x")

    # -- sharded vs single-device stacked serving (ISSUE 4) -----------------
    import math

    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.parallel import sharding as shd

    m, b = (4, 1024) if full else (2, 256)
    # the data axis must divide the slot capacity or the dispatch falls
    # back to single-device; use the largest device count that does
    n_dev = math.gcd(len(jax.devices()), b)
    shard_server = netgen.NetServer(cache=cache, slot_capacity=b)
    for i in range(m):
        shard_server.register(f"v{i}", nets[i])
    shard_reqs = {f"v{i}": _images(b, sizes[0], seed=200 + i)
                  for i in range(m)}
    single_out = shard_server.predict_many(shard_reqs)     # warm
    dt_single = _timed_mean(
        "stacked_single_device",
        lambda: shard_server.predict_many(shard_reqs), reps)
    with shd.use_mesh(make_host_mesh(data=n_dev)):
        sharded_out = shard_server.predict_many(shard_reqs)  # warm
        dt_sharded = _timed_mean(
            "stacked_sharded",
            lambda: shard_server.predict_many(shard_reqs), reps)
    exact = all(np.array_equal(single_out[v], sharded_out[v])
                for v in shard_reqs)
    assert exact, "sharded dispatch diverged from single-device"
    assert shard_server.dispatch_counts["sharded"] > 0
    preds = m * b
    results["sharded"] = {
        "devices": n_dev, "versions": m, "batch": b, "exact": exact,
        "single_device_us": dt_single * 1e6,
        "sharded_us": dt_sharded * 1e6,
        "single_device_preds_per_s": preds / dt_single,
        "sharded_preds_per_s": preds / dt_sharded,
    }
    rows.append(f"netgen_serve_single_device_m{m}_b{b},"
                f"{dt_single*1e6:.0f},{preds/dt_single:.0f}")
    rows.append(f"netgen_serve_sharded{n_dev}_m{m}_b{b},"
                f"{dt_sharded*1e6:.0f},{preds/dt_sharded:.0f}")

    # -- stacked multi-net dispatch vs individual serving -------------------
    for m in m_versions:
        for b in batches:
            server = netgen.NetServer(cache=cache, slot_capacity=b)
            for i in range(m):
                server.register(f"v{i}", nets[i])
            reqs = {f"v{i}": _images(b, sizes[0], seed=100 + i)
                    for i in range(m)}

            out = server.predict_many(reqs)          # warm both paths
            individual = {v: np.asarray(server.compiled_for(v)(x))
                          for v, x in reqs.items()}
            exact = all(np.array_equal(out[v], individual[v]) for v in reqs)

            dt_stacked = _timed_mean(
                f"stacked_m{m}_b{b}",
                lambda: server.predict_many(reqs), reps)

            def _individual():
                for v, x in reqs.items():
                    np.asarray(server.compiled_for(v)(x))
            dt_indiv = _timed_mean(f"individual_m{m}_b{b}", _individual, reps)

            preds = m * b
            results["multi"].append({
                "versions": m, "batch": b, "exact": exact,
                "stacked_dispatch": bool(m > 1),
                "stacked_us": dt_stacked * 1e6,
                "individual_us": dt_indiv * 1e6,
                "stacked_preds_per_s": preds / dt_stacked,
                "individual_preds_per_s": preds / dt_indiv,
            })
            assert exact, f"stacked dispatch diverged at m={m} b={b}"
            rows.append(f"netgen_serve_stacked_m{m}_b{b},"
                        f"{dt_stacked*1e6:.1f},{preds/dt_stacked:.0f}")
            rows.append(f"netgen_serve_individual_m{m}_b{b},"
                        f"{dt_indiv*1e6:.1f},{preds/dt_indiv:.0f}")

    # -- telemetry overhead (ISSUE 6 acceptance) ----------------------------
    # Same paper-sized net as the datapath section, served through the
    # instrumented dispatch path with span tracing ON vs OFF. Metrics
    # are always live (they back the stats everyone reads), so "off"
    # here means what production pays by default: no span recording.
    from repro.netgen import telemetry

    ov_server = netgen.NetServer(cache=cache, slot_capacity=pb)
    ov_server.register("ov", pnet)
    ov_reqs = {"ov": px}
    ov_server.predict_many(ov_reqs)                          # warm
    ov_reps = 30 if full else 15
    was_enabled = telemetry.get_registry().enabled

    def _ov():
        ov_server.predict_many(ov_reqs)

    telemetry.disable()
    dt_off = min(_timed_mean("telemetry_off", _ov, ov_reps) for _ in range(3))
    telemetry.enable()
    dt_on = min(_timed_mean("telemetry_on", _ov, ov_reps) for _ in range(3))
    if not was_enabled:
        telemetry.disable()
    overhead = dt_on / dt_off - 1.0
    results["telemetry_overhead"] = {
        "sizes": list(psizes), "batch": pb,
        "tracing_off_us": dt_off * 1e6, "tracing_on_us": dt_on * 1e6,
        "overhead_frac": overhead,
    }
    rows.append(f"netgen_serve_telemetry_overhead,{dt_on*1e6:.1f},"
                f"{overhead*100:+.2f}%")
    # <= 5% when enabled (with a small absolute slack so a sub-ms
    # dispatch cannot fail on scheduler jitter alone)
    assert dt_on <= dt_off * 1.05 + 5e-4, (
        f"telemetry tracing overhead too high: on={dt_on*1e6:.1f}us "
        f"off={dt_off*1e6:.1f}us ({overhead*100:.1f}%)")

    # -- roofline: XLA cost analysis vs measured (ISSUE 9) ------------------
    prof = telemetry.jit_cost(oracle.artifact, (pb, psizes[0]))
    if prof is not None:
        results["roofline_jit"] = {
            "target": "jnp", "sizes": list(psizes), "batch": pb, **prof}
        rows.append(f"netgen_serve_jit_cost_jnp,0,"
                    f"flops={prof['flops']:.0f};"
                    f"bytes={prof['bytes_accessed']:.0f}")
    # the megakernel's gap-to-hardware row: measured time vs the
    # bytes-bound floor its jit_cost implies at an assumed HBM
    # bandwidth — persisted in BENCH_netgen.json so successive PRs
    # track the ratio (interpret mode is orders of magnitude off the
    # floor; the ratio's trend is the signal, not its magnitude)
    fused_fn = forms["fusednet"].artifact
    prof_f = telemetry.jit_cost(
        getattr(fused_fn, "jitted", fused_fn), (pb, psizes[0]))
    if prof_f is not None:
        measured_us = results["packed"]["fusednet"]["us_per_batch"]
        bound_us = prof_f["bytes_accessed"] / (_HBM_GBPS * 1e9) * 1e6
        ratio = measured_us / bound_us if bound_us > 0 else float("inf")
        results["roofline"] = {
            "target": "pallas[fusednet=true]", "sizes": list(psizes),
            "batch": pb, "flops": prof_f["flops"],
            "bytes_accessed": prof_f["bytes_accessed"],
            "hbm_gbps_assumed": _HBM_GBPS,
            "bytes_bound_us": bound_us,
            "measured_us": measured_us,
            "measured_vs_bound": ratio,
        }
        rows.append(f"netgen_roofline_fusednet_b{pb},{measured_us:.0f},"
                    f"bound_us={bound_us:.2f};ratio={ratio:.0f};"
                    f"flops={prof_f['flops']:.0f};"
                    f"bytes={prof_f['bytes_accessed']:.0f}")

    results["telemetry"] = telemetry.summary()

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fake-devices", type=int, default=0, metavar="N",
                    help="fake N host devices for the sharded rows "
                         "(standalone runs only: must be set before jax "
                         "initializes)")
    ap.add_argument("--json", default=None,
                    help="write the full measurement set here (no file "
                         "is written without an explicit path)")
    args = ap.parse_args()
    if args.fake_devices:
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.fake_devices}")
    print("name,us_per_call,derived")
    for row in run(full=args.full, json_path=args.json):
        print(row, flush=True)


if __name__ == "__main__":
    main()
