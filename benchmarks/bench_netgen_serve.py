"""Compile-cache serving benchmark (the paper's compile-per-model economics).

Measures, in the `bench_throughput` CSV idiom:

  * cold compile (cache miss + first-trace warmup) vs warm predictor
    acquisition (cache hit) — ISSUE 2 acceptance: warm >= 100x faster
  * cold PROCESS vs warm STORE (ISSUE 3): a fresh Session pointed at an
    already-populated ArtifactStore directory loads the persisted
    artifact instead of recompiling — the cross-process warm-start the
    store exists for (load time vs full compile time, zero compiles
    asserted)
  * multi-version stacked dispatch (M versions, ONE jitted call) vs
    serving each compiled predictor individually, for M in 1..8 and
    batch sizes 1..1024, with a bit-exactness check on every
    configuration

The JSON artifact (CI uploads it) additionally registers the `cost`
target's Figure-7-style logic-cell estimates per pass for the benchmark
net.

  PYTHONPATH=src python benchmarks/bench_netgen_serve.py [--full] \\
      [--json bench_netgen_serve.json]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np


def _nets(m: int, sizes, seed: int = 0):
    from repro.core import quantize
    out = []
    for i in range(m):
        rng = np.random.default_rng(seed + i)
        out.append(quantize.QuantizedNet(weights=[
            rng.integers(-5, 6, size=s).astype(np.int32)
            for s in zip(sizes, sizes[1:])]))
    return out


def _images(b: int, n_in: int, seed: int = 9) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, 256, size=(b, n_in)).astype(np.uint8)


def run(full: bool = False, json_path: str | None = None) -> list[str]:
    from repro import netgen

    sizes = (784, 128, 10) if full else (96, 48, 10)
    m_versions = (1, 2, 4, 8) if full else (1, 2, 4)
    batches = (1, 32, 1024) if full else (1, 32, 256)
    reps = 5 if full else 3
    warm_reps = 1000

    rows: list[str] = []
    results: dict = {"sizes": list(sizes), "backend": "jnp",
                     "cold_ms": [], "multi": []}
    nets = _nets(max(m_versions), sizes)

    # -- cold compile vs warm acquisition -----------------------------------
    cache = netgen.CompileCache(capacity=64)
    warm_batch = _images(32, sizes[0])
    for net in nets:
        t0 = time.perf_counter()
        compiled = cache.get_or_compile(net)
        np.asarray(compiled(warm_batch))     # includes first-trace jit cost
        results["cold_ms"].append((time.perf_counter() - t0) * 1e3)
    cold_s = float(np.mean(results["cold_ms"])) / 1e3

    t0 = time.perf_counter()
    for _ in range(warm_reps):
        for net in nets:
            cache.get_or_compile(net)
    warm_s = (time.perf_counter() - t0) / (warm_reps * len(nets))
    speedup = cold_s / warm_s
    results["warm_us"] = warm_s * 1e6
    results["warm_vs_cold_speedup"] = speedup
    results["cache_stats"] = vars(cache.stats())
    rows.append(f"netgen_serve_cold_compile,{cold_s*1e6:.0f},{1.0/cold_s:.1f}")
    rows.append(f"netgen_serve_warm_acquire,{warm_s*1e6:.2f},{1.0/warm_s:.0f}")
    rows.append(f"netgen_serve_warm_vs_cold_speedup,{warm_s*1e6:.2f},{speedup:.0f}")

    # -- cold process vs warm store (persisted-artifact load) ----------------
    with tempfile.TemporaryDirectory() as store_dir:
        cold_sess = netgen.Session(store=store_dir)
        t0 = time.perf_counter()
        art = cold_sess.compile(nets[0], target="jnp")
        np.asarray(art(warm_batch))
        cold_process_s = time.perf_counter() - t0

        warm_sess = netgen.Session(store=store_dir)   # simulated new process
        t0 = time.perf_counter()
        warm_art = warm_sess.compile(nets[0], target="jnp")
        np.asarray(warm_art(warm_batch))
        warm_store_s = time.perf_counter() - t0
        st = warm_sess.stats()
        assert (st.compiles, st.store_hits) == (0, 1), vars(st)
        assert np.array_equal(np.asarray(art(warm_batch)),
                              np.asarray(warm_art(warm_batch)))
        results["store"] = {
            "cold_process_ms": cold_process_s * 1e3,
            "warm_store_ms": warm_store_s * 1e3,
            "speedup": cold_process_s / warm_store_s,
            "warm_compiles": st.compiles,
            "warm_store_hits": st.store_hits,
        }
        rows.append(f"netgen_serve_cold_process,{cold_process_s*1e6:.0f},"
                    f"{1.0/cold_process_s:.1f}")
        rows.append(f"netgen_serve_warm_store,{warm_store_s*1e6:.0f},"
                    f"{1.0/warm_store_s:.1f}")
        rows.append(f"netgen_serve_store_speedup,{warm_store_s*1e6:.0f},"
                    f"{cold_process_s/warm_store_s:.1f}")

    # -- Figure-7-style logic-cell estimates (cost target) -------------------
    cost = netgen.compile_artifact(
        nets[0], target="cost", pipeline="zeros,prune,addends").artifact
    results["cost_fig7"] = cost.as_dict()
    for stage, cells in cost.per_pass:
        rows.append(f"netgen_cost_cells_{stage},0,{cells.total}")

    # -- stacked multi-net dispatch vs individual serving -------------------
    for m in m_versions:
        for b in batches:
            server = netgen.NetServer(cache=cache, slot_capacity=b)
            for i in range(m):
                server.register(f"v{i}", nets[i])
            reqs = {f"v{i}": _images(b, sizes[0], seed=100 + i)
                    for i in range(m)}

            out = server.predict_many(reqs)          # warm both paths
            individual = {v: np.asarray(server.compiled_for(v)(x))
                          for v, x in reqs.items()}
            exact = all(np.array_equal(out[v], individual[v]) for v in reqs)

            t0 = time.perf_counter()
            for _ in range(reps):
                server.predict_many(reqs)
            dt_stacked = (time.perf_counter() - t0) / reps

            t0 = time.perf_counter()
            for _ in range(reps):
                for v, x in reqs.items():
                    np.asarray(server.compiled_for(v)(x))
            dt_indiv = (time.perf_counter() - t0) / reps

            preds = m * b
            results["multi"].append({
                "versions": m, "batch": b, "exact": exact,
                "stacked_dispatch": bool(m > 1),
                "stacked_us": dt_stacked * 1e6,
                "individual_us": dt_indiv * 1e6,
                "stacked_preds_per_s": preds / dt_stacked,
                "individual_preds_per_s": preds / dt_indiv,
            })
            assert exact, f"stacked dispatch diverged at m={m} b={b}"
            rows.append(f"netgen_serve_stacked_m{m}_b{b},"
                        f"{dt_stacked*1e6:.1f},{preds/dt_stacked:.0f}")
            rows.append(f"netgen_serve_individual_m{m}_b{b},"
                        f"{dt_indiv*1e6:.1f},{preds/dt_indiv:.0f}")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default="bench_netgen_serve.json",
                    help="write the full measurement set here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(full=args.full, json_path=args.json):
        print(row, flush=True)


if __name__ == "__main__":
    main()
