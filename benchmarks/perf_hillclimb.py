import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> compare.

Three cells (selection rationale in EXPERIMENTS.md §Perf):
  qwen2-72b/decode_32k      — paper-representative (inference specialization)
  qwen3-moe-30b-a3b/train_4k — worst roofline fraction among train cells
  mamba2-2.7b/train_4k      — most collective-bound cell

Each experiment names a variant (runtime flags / rule overrides / serving
dtype / W8 quantization), states the napkin-math hypothesis, lowers and
measures, and appends to benchmarks/results/perf_hillclimb.json.

  python -m benchmarks.perf_hillclimb [--cell NAME] [--step N]
"""

import argparse
import json
import time

from repro import configs
from repro.launch.dryrun import run_cell, RESULTS_DIR
from repro.launch.mesh import make_production_mesh
from repro.models.base import SHAPES

# experiment registry: cell -> ordered list of (variant_name, hypothesis, variant)
EXPERIMENTS = {
    "qwen2-72b/decode_32k": [
        ("baseline",
         "fp32 FSDP-sharded training params reused for serving: every step "
         "all-gathers the data-axis weight shards (~190GB/dev) -> collective-"
         "bound at ~3.8s/token-step.",
         {}),
        ("serve_bf16",
         "Serving copy in bf16 halves every weight byte moved: expect "
         "t_coll and weight part of t_mem to drop ~2x.",
         {"serve_dtype": "bfloat16"}),
        ("serve_bf16_tp_only",
         "Inference wants weights resident, not FSDP-gathered: replicate "
         "the fsdp axis (TP-16 only: 9 GB/dev bf16 for 72B, fits 16GB "
         "HBM). Expect weight all-gathers to vanish; memory-bound next.",
         {"serve_dtype": "bfloat16", "rules": {"fsdp": ()}}),
        ("serve_w8_tp_only",
         "The paper's integer-weight specialization: int8 weights halve "
         "HBM streaming vs bf16 (4.5 GB/dev). Expect t_mem ~2x down on the "
         "weight term.",
         {"quant": True, "rules": {"fsdp": ()}}),
        ("serve_w8_tp_scatter",
         "The where-based cache update streams the whole KV cache twice; "
         "a true scatter touches one row. Expect cache bytes ~3x down "
         "(read-for-attention remains).",
         {"quant": True, "rules": {"fsdp": ()},
          "flags": {"cache_update": "scatter"}}),
        # --- second round: HLO dump showed the REAL bottleneck: the
        # materialized GQA head-repeat makes GSPMD all-gather the entire
        # seq-sharded KV cache (4x1.07GB/layer x 80 layers ~ 172GB/dev).
        ("grouped_attn",
         "Grouped GQA einsum (q reshaped (KV, rep); K/V consumed in stored "
         "layout, no repeat) keeps the cache seq-sharded: the big "
         "all-gathers should vanish, leaving small softmax/PV reductions. "
         "Expect t_coll ~3.4s -> ~ms scale.",
         {"flags": {"attn_impl": "grouped"}}),
        ("grouped_bf16_tp",
         "On top of grouped attention: bf16 serving copy + TP-only weight "
         "sharding (no fsdp gathers). Expect memory-bound at ~(9GB weights "
         "+ 5.4GB cache)/819GB/s ~ 18ms.",
         {"flags": {"attn_impl": "grouped"},
          "serve_dtype": "bfloat16", "rules": {"fsdp": ()}}),
        ("grouped_w8_tp_scatter",
         "Paper's integer-weight specialization on the fixed baseline: int8 "
         "weights (4.5GB/dev) + scatter cache update. Expect the weight "
         "term to halve again.",
         {"flags": {"attn_impl": "grouped", "cache_update": "scatter"},
          "quant": True, "rules": {"fsdp": ()}}),
    ],
    "qwen3-moe-30b-a3b/train_4k": [
        ("baseline",
         "MoE dispatch tensors are token-sharded over data only; GSPMD "
         "replicates sort/gather/scatter across the 16-way model axis -> "
         "memory term ~100s.",
         {}),
        ("token_shard_dispatch",
         "Shard routing/sort/dispatch over data x model (256-way): "
         "per-device dispatch bytes should drop ~16x; expect t_mem to "
         "fall toward the expert-matmul floor and collectives to become "
         "the all-to-all between token- and expert-sharded layouts.",
         {"flags": {"moe_token_shard": True}}),
        # --- second round: HLO byte profile showed convert+broadcast+select
        # dominating — the aux-loss (T, K, E) one-hot materializes 134 GB/dev
        # at train_4k. Replaced with a scatter-add count (exact rewrite).
        ("onehot_free_aux",
         "Count expert assignments with a scatter-add instead of a "
         "(T, K, E) one-hot: removes ~T*K*E*4B of broadcast/select/convert "
         "traffic per layer. Expect t_mem to collapse toward the "
         "expert-matmul + dispatch-gather floor.",
         {}),
        ("onehot_free_aux_tokshard",
         "On top of the one-hot fix, re-test token-sharded dispatch (the "
         "earlier regression may have been masked by the one-hot traffic).",
         {"flags": {"moe_token_shard": True}}),
        # --- third round: take dispatch out of GSPMD's hands entirely.
        ("shardmap_all_to_all",
         "Explicit shard_map dispatch: route locally per device, bucket by "
         "destination model-rank, one all_to_all out + one home, expert "
         "FFN on local E/16 experts (layers/moe_shardmap.py). Napkin: "
         "payload ~ T*K*D*2B/chips ~ 33 GB/dev/step vs GSPMD's all-reduced "
         "expert buffers ~ 11 TB/dev/step. 2-layer probe: bytes 5.4x down, "
         "coll 7.7x down, flops 2.7x down.",
         {"flags": {"moe_impl": "shardmap"}}),
    ],
    "mamba2-2.7b/train_4k": [
        ("baseline",
         "Hidden states sequence-sharded over the model axis, but the SSD "
         "chunk scan is sequential in seq: every chunk step gathers from "
         "the device owning that chunk -> t_coll 31s vs t_comp 0.5s.",
         {}),
        ("head_sharded_ssd",
         "The SSD recurrence is embarrassingly parallel over heads "
         "(80 heads / 16 = 5 per device) and channels; shard conv "
         "channels + heads over the model axis and keep seq local. "
         "Expect the per-chunk gathers to vanish (t_coll >> down), "
         "t_comp/t_mem roughly flat.",
         {"flags": {"ssm_shard": "heads"}}),
        # --- second round: heads mode confirmed on collectives (34.7->5.7s)
        # but doubled t_mem: replicated-d hidden between layers.
        ("mixed_sharded_ssd",
         "Keep hidden seq-sharded BETWEEN layers (SP activation bytes) and "
         "heads/channel sharding INSIDE the mixer: pay one seq<->channel "
         "resharding per layer boundary instead of per-chunk gathers. "
         "Expect t_mem back near baseline with t_coll between 5.7s and "
         "34.7s (the boundary all-to-alls).",
         {"flags": {"ssm_shard": "mixed"}}),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--step", type=int, default=None,
                    help="run only the Nth variant of each cell")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=False)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "perf_hillclimb.json")
    log = []
    if os.path.exists(out_path):
        log = json.load(open(out_path))
    seen = {(r["cell"], r["variant"]) for r in log}

    for cell, variants in EXPERIMENTS.items():
        if args.cell and cell != args.cell:
            continue
        arch, shape_name = cell.split("/")
        cfg = configs.get_config(arch)
        shape = SHAPES[shape_name]
        for i, (name, hypothesis, variant) in enumerate(variants):
            if args.step is not None and i != args.step:
                continue
            if (cell, name) in seen:
                print(f"[skip] {cell} :: {name}")
                continue
            print(f"\n[perf] {cell} :: {name}")
            print(f"  hypothesis: {hypothesis}")
            t0 = time.time()
            try:
                record, meta = run_cell(cfg, shape, mesh, variant=variant)
                entry = {"cell": cell, "variant": name,
                         "hypothesis": hypothesis, "ok": True,
                         **record.as_dict(),
                         "wall_s": time.time() - t0}
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                entry = {"cell": cell, "variant": name,
                         "hypothesis": hypothesis, "ok": False,
                         "error": f"{type(e).__name__}: {e}",
                         "wall_s": time.time() - t0}
            log.append(entry)
            with open(out_path, "w") as f:
                json.dump(log, f, indent=1, default=float)
    print(f"\nwrote {out_path}")


if __name__ == "__main__":
    main()
