"""Per-pass attribution for the netgen compiler + backend throughput.

Two tables the old flat §V.D numbers could not show:

  * per-pass op deltas — which rewrite saves what, on a real trained net
    (terms/mults/adds before and after delete_zero_terms,
    prune_dead_units, addend_rewrite) and, on a smaller net where the
    O(terms^2) greedy search is affordable, share_common_addends;
  * compiled-backend throughput — predictions/s of the jnp vs pallas vs
    fused artifacts for the same circuit (pallas/fused run interpret-mode
    on CPU containers; on TPU the same path compiles to Mosaic);
  * static-analysis overhead — one `analysis.analyze()` (structural
    verifier + range dataflow, what every compile runs pre-backend) as a
    percentage of pipeline time, asserted <= 10%.

Rows: name,us_per_call,derived.
"""
from __future__ import annotations

import time


def run(full: bool = False) -> list[str]:
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dataset, mlp, quantize
    from repro import netgen

    rows: list[str] = []

    # --- per-pass op deltas on a trained net -------------------------------
    n_hidden = (500,) if full else (96, 32)   # deeper stack in fast mode
    xtr, ytr, xte, _ = dataset.train_test_split(600, 256, seed=2)
    cfg = mlp.MLPConfig(n_hidden=n_hidden, epochs=30 if full else 12, seed=6)
    params = mlp.train(cfg, xtr, ytr)
    qnet = quantize.quantize(params)

    circuit = netgen.lower(qnet)
    spec = netgen.PipelineSpec.parse("zeros,prune,addends")
    t0 = time.time()
    compiled, stats = spec.run(circuit, verify=False)
    pipe_s = time.time() - t0
    dt = pipe_s * 1e6 / len(spec.steps)
    for s in stats:
        rows.append(f"pass_{s.name}_terms,{dt:.0f},{s.before.terms}->{s.after.terms}")
        rows.append(f"pass_{s.name}_mults,0,{s.before.mults}->{s.after.mults}")
        rows.append(f"pass_{s.name}_adds,0,{s.before.adds}->{s.after.adds}")

    # --- static analysis overhead (verifier + range dataflow) --------------
    # One full analyze() — what Session.compile_resolved always runs
    # pre-backend — must stay a small fraction of pipeline time.
    from repro.netgen import analysis
    reps = []
    for _ in range(3):
        t0 = time.perf_counter()
        analysis.analyze(compiled)
        reps.append(time.perf_counter() - t0)
    an_s = min(reps)
    pct = 100.0 * an_s / pipe_s
    rows.append(f"analysis_overhead,{an_s*1e6:.0f},{pct:.1f}pct_of_pipeline")
    assert pct <= 10.0, (
        f"analysis overhead {pct:.1f}% exceeds 10% of pipeline time")

    # --- CSE on a small net (greedy pair search is O(terms^2)) -------------
    rng = np.random.default_rng(0)
    small = quantize.QuantizedNet(
        w1=rng.integers(-4, 5, size=(32, 24)).astype(np.int32),
        w2=rng.integers(-4, 5, size=(24, 10)).astype(np.int32))
    t0 = time.time()
    _, cse_stats = netgen.PipelineSpec.coerce("hw").run(netgen.lower(small))
    cse = cse_stats[-1]
    rows.append(f"pass_{cse.name}_adds,{(time.time()-t0)*1e6:.0f},"
                f"{cse.before.adds}->{cse.after.adds}")

    # --- bucketed vs exhaustive CSE at 784-input scale ---------------------
    wide = quantize.QuantizedNet(weights=[
        rng.integers(-2, 3, size=(784, 4)).astype(np.int32),
        rng.integers(-2, 3, size=(4, 10)).astype(np.int32)])
    budget = 8 if full else 4
    for mode in ("bucketed=true", "bucketed=false"):
        t0 = time.time()
        _, st = netgen.PipelineSpec.parse(
            f"zeros,cse[budget={budget},{mode}]").run(netgen.lower(wide))
        rows.append(
            f"pass_cse_784_{mode.split('=')[1]},{(time.time()-t0)*1e6:.0f},"
            f"adds_saved_{st[-1].adds_saved}")

    # --- backend throughput on the compiled circuit ------------------------
    x = jnp.asarray(xte)
    for backend, n in (("jnp", 256), ("pallas", 64), ("fused", 64)):
        if backend == "fused" and qnet.depth != 2:
            rows.append(f"backend_fused,0,skipped_depth_{qnet.depth}")
            continue
        fn = netgen.specialize(qnet, backend=backend)
        xb = x[:n]
        fn(xb).block_until_ready()          # compile
        t0 = time.perf_counter()
        fn(xb).block_until_ready()
        dt = time.perf_counter() - t0
        rows.append(f"backend_{backend},{dt*1e6:.0f},{n/dt:.0f}_preds_per_s")
    return rows
