"""Paper §V.E table: predictions/second, software vs specialized.

Paper's numbers: ~1,000/s for the devectorized CPU script (Intel i7) vs
5x10^8/s for the clockless FPGA (clock-bound). Our measured analogues on
this container's CPU:

  devectorized  — the paper's expanded Python script (explicit scalar
                  arithmetic per node), the honest software baseline
  vectorized    — numpy matmul version
  specialized   — netgen-compiled jitted masked-add network (weights
                  constant-folded)
  fused-kernel  — whole-net single Pallas launch (interpret mode: Python
                  emulation, NOT TPU speed; reported for completeness)

plus the projected TPU v5e bound for the fused int kernel from the
hardware model (the analogue of the paper's 500 MHz clock bound).
"""
from __future__ import annotations

import time

import numpy as np


def _devectorized_predict(w1, w2, img, threshold=128):
    """The paper's §IV expanded script: pure Python scalar ops, zero
    vectorization (their ~1000 predictions/s artifact)."""
    n_in, n_h = w1.shape
    n_out = w2.shape[1]
    inb = [1 if img[i] > threshold else 0 for i in range(n_in)]
    ho = [0] * n_h
    for j in range(n_h):
        acc = 0
        col = w1[:, j]
        for i in range(n_in):
            if inb[i]:
                acc += col[i]
        ho[j] = 1 if acc > 0 else 0
    best, best_v = 0, None
    for k in range(n_out):
        acc = 0
        col = w2[:, k]
        for j in range(n_h):
            if ho[j]:
                acc += col[j]
        if best_v is None or acc > best_v:
            best, best_v = k, acc
    return best


def run(full: bool = False) -> list[str]:
    import jax.numpy as jnp
    from repro.core import dataset, mlp, netgen, quantize

    n_hidden = 500 if full else 128
    xtr, ytr, xte, _ = dataset.train_test_split(600, 256, seed=2)
    cfg = mlp.MLPConfig(n_hidden=n_hidden, epochs=30, seed=5)
    params = mlp.train(cfg, xtr, ytr)
    qnet = quantize.quantize(params)
    qp, _ = netgen.prune(qnet)
    rows = []

    # 1) devectorized python (paper baseline)
    n_dev = 20 if full else 10
    t0 = time.perf_counter()
    for i in range(n_dev):
        _devectorized_predict(qp.w1, qp.w2, xte[i])
    dt = (time.perf_counter() - t0) / n_dev
    rows.append(f"throughput_devectorized_python,{dt*1e6:.0f},{1.0/dt:.1f}")

    # 2) vectorized numpy
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        xb = (xte > 128).astype(np.int32)
        hi = xb @ qp.w1
        fi = (hi > 0).astype(np.int32) @ qp.w2
        fi.argmax(axis=1)
    dt = (time.perf_counter() - t0) / (reps * xte.shape[0])
    rows.append(f"throughput_vectorized_numpy,{dt*1e6:.2f},{1.0/dt:.0f}")

    # 3) specialized jitted (netgen, weights constant-folded)
    fn = netgen.specialize(qnet, backend="jnp")
    xj = jnp.asarray(xte)
    fn(xj).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(xj).block_until_ready()
    dt = (time.perf_counter() - t0) / (reps * xte.shape[0])
    rows.append(f"throughput_specialized_jit,{dt*1e6:.2f},{1.0/dt:.0f}")

    # 4) fused Pallas kernel (interpret mode — correctness, not TPU speed)
    fnf = netgen.specialize(qnet, backend="fused")
    small = xj[:32]
    fnf(small).block_until_ready()
    t0 = time.perf_counter()
    fnf(small).block_until_ready()
    dt = (time.perf_counter() - t0) / small.shape[0]
    rows.append(f"throughput_fused_interpret,{dt*1e6:.2f},{1.0/dt:.1f}")

    # 5) projected TPU bound (hardware-model analogue of the paper's
    #    500 MHz clock bound): int8 ops at MXU rate, whole net in VMEM
    from repro.launch.mesh import HW
    ops = 2 * (qp.w1.shape[0] * qp.w1.shape[1] + qp.w2.shape[0] * qp.w2.shape[1])
    t_pred = ops / (2 * HW["peak_bf16_flops"])   # int8 ~ 2x bf16 rate
    rows.append(f"throughput_tpu_v5e_bound,{t_pred*1e6:.4f},{1.0/t_pred:.0f}")
    return rows
