"""Benchmark harness — one module per paper table (+ kernels & dry-run
summary). Prints ``name,us_per_call,derived`` CSV.

  python -m benchmarks.run [--full]

--full runs paper-sized versions (500 hidden units, 60 epochs, full
Verilog emission); default is a fast sanity pass.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_kernels, bench_ladder, bench_netgen,
                            bench_netgen_passes, bench_netgen_serve,
                            bench_throughput, roofline_table)

    suites = {
        "ladder": bench_ladder.run,          # paper §III accuracy table
        "netgen": bench_netgen.run,          # paper §V.D resource table
        "netgen_passes": bench_netgen_passes.run,  # per-pass IR attribution
        "netgen_serve": bench_netgen_serve.run,    # compile cache + multi-net
        "throughput": bench_throughput.run,  # paper §V.E FPGA-vs-CPU table
        "kernels": bench_kernels.run,
        "roofline": roofline_table.run,      # dry-run summary counts
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn(full=args.full):
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name}_FAILED,0,0")
            failed += 1
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
