"""Benchmark harness — one module per paper table (+ kernels & dry-run
summary). Prints ``name,us_per_call,derived`` CSV and writes the
repo-root ``BENCH_netgen.json`` trajectory artifact (git rev + every
row + per-suite wall clock) so successive PRs can diff performance
instead of re-reading CI logs.

  python -m benchmarks.run [--full] [--only SUITE] [--fake-devices N]
      [--bench-json BENCH_netgen.json] [--serve-json FILE]
      [--explore-report FILE]

--full runs paper-sized versions (500 hidden units, 60 epochs, full
Verilog emission); default is a fast sanity pass. --fake-devices N
spreads the sharded serving rows over N faked host devices (must be
set before jax initializes, hence a flag here). --serve-json
additionally writes the serve suite's detailed measurement dict;
--explore-report the explore suite's ExplorationReport JSON. Suite
artifacts are written ONLY under these declared output paths — no
suite drops files in the working directory, so `BENCH_netgen.json`
stays the single committed trajectory file.

Row conventions: ratio rows (`*_speedup`) put 0 in us_per_call and
carry `ratio=..;<num>_us=..;<den>_us=..` in derived — the ratio's own
measurement pair, self-contained in BENCH_netgen.json. The serve suite
emits one `netgen_serve_pallas_<form>_b256` row per datapath (dense /
packed / planes / fusednet) plus `netgen_roofline_fusednet_b256`:
us_per_call is the measured time, derived holds the jit_cost-derived
bytes-bound floor and the measured/bound ratio.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT, check=True,
            capture_output=True, text=True).stdout.strip()
    except Exception:  # noqa: BLE001 — no git in some CI containers
        return "unknown"


def write_bench_json(path, rows: list[str], suite_seconds: dict,
                     full: bool) -> None:
    """The perf trajectory artifact: parse the printed CSV rows into
    records and stamp them with the git revision, so a future PR can
    diff `BENCH_netgen.json` against its parent's."""
    parsed = []
    for row in rows:
        name, _, rest = row.partition(",")
        us, _, derived = rest.partition(",")
        try:
            us_val: float | None = float(us)
        except ValueError:
            us_val = None
        parsed.append({"name": name, "us_per_call": us_val,
                       "derived": derived})
    payload = {
        "format": "bench-netgen-v1",
        "git_rev": _git_rev(),
        "created_unix": time.time(),
        "full": full,
        "suite_seconds": {k: round(v, 3) for k, v in suite_seconds.items()},
        "rows": parsed,
    }
    try:
        # fold the run's telemetry (compile/store/dispatch counters, the
        # bench timing histograms, cost_analysis gauges) into the
        # trajectory artifact — the roofline inputs ride along for free
        from repro.netgen import telemetry
        payload["telemetry"] = telemetry.summary()
    except Exception:  # noqa: BLE001 — a bench artifact must still be written
        pass
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names to run")
    ap.add_argument("--fake-devices", type=int, default=0, metavar="N",
                    help="fake N host devices for the sharded serving rows")
    ap.add_argument("--bench-json", default=str(REPO_ROOT / "BENCH_netgen.json"),
                    help="perf trajectory artifact (git rev + rows + "
                         "timings); empty string disables")
    ap.add_argument("--serve-json", default=None,
                    help="also write the serve suite's detailed JSON here")
    ap.add_argument("--explore-report", default=None,
                    help="also write the explore suite's "
                         "ExplorationReport JSON here")
    ap.add_argument("--store", default=None,
                    help="persistent ArtifactStore dir for the explore "
                         "suite (CI hands it the cached .netgen-store)")
    ap.add_argument("--tune-store", default=None,
                    help="persistent TuneStore dir for the explore suite "
                         "(explored winners land here for warm replays)")
    args = ap.parse_args()
    if args.fake_devices:
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.fake_devices}")

    from benchmarks import (bench_kernels, bench_ladder, bench_netgen,
                            bench_netgen_engine, bench_netgen_explore,
                            bench_netgen_passes, bench_netgen_serve,
                            bench_throughput, roofline_table)

    suites = {
        "ladder": bench_ladder.run,          # paper §III accuracy table
        "netgen": bench_netgen.run,          # paper §V.D resource table
        "netgen_passes": bench_netgen_passes.run,  # per-pass IR attribution
        "netgen_serve": lambda full: bench_netgen_serve.run(
            full=full, json_path=args.serve_json),  # compile cache + multi-net
        "netgen_engine": bench_netgen_engine.run,  # online serving load gen
        "netgen_explore": lambda full: bench_netgen_explore.run(
            full=full, report_path=args.explore_report,
            store=args.store, tune_store=args.tune_store),  # joint DSE
        "throughput": bench_throughput.run,  # paper §V.E FPGA-vs-CPU table
        "kernels": bench_kernels.run,
        "roofline": roofline_table.run,      # dry-run summary counts
    }
    print("name,us_per_call,derived")
    failed = 0
    all_rows: list[str] = []
    suite_seconds: dict[str, float] = {}
    only = (set(args.only.split(",")) if args.only else None)
    for name, fn in suites.items():
        if only is not None and name not in only:
            continue
        t0 = time.perf_counter()
        try:
            for row in fn(full=args.full):
                print(row, flush=True)
                all_rows.append(row)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            print(f"{name}_FAILED,0,0")
            failed += 1
        suite_seconds[name] = time.perf_counter() - t0
    if args.bench_json:
        write_bench_json(args.bench_json, all_rows, suite_seconds, args.full)
        print(f"# wrote {args.bench_json} ({len(all_rows)} rows)",
              file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
