"""Design-space explorer benchmarks + the budgeted CI smoke.

Suite rows (`python -m benchmarks.run --only netgen_explore`):

  netgen_explore_best    the joint-search winner's measured latency on
                         the bench net (us_per_call); derived carries
                         the winning pipeline/form/tiles and the search
                         accounting (candidates/pruned/measured).
  netgen_explore_replay  the same search re-run against the warm
                         in-process record: us_per_call is the replay
                         wall clock, derived asserts the zero-
                         measurement source.
  netgen_explore_ladder  the carried-over ladder-depth sweep AS AN
                         EXPLORER DIMENSION: nets of several hidden
                         depths enter one `SearchSpace.nets` axis, the
                         cells objective prices each depth's optimized
                         circuit, and derived records accuracy-vs-cells
                         per depth against the paper's accuracy ladder
                         (L3 reference: 92%).

Standalone — the tier-1 CI smoke (interpret mode, explicit budget):

  PYTHONPATH=src python benchmarks/bench_netgen_explore.py --smoke \\
      --budget 8 [--store DIR] [--tune-store DIR] [--report FILE] \\
      [--trace DIR]

The smoke explores, serves the winner through a stacked NetServer (so
the `explored=true` preference path and the dispatch/kernel spans are
exercised), re-explores to prove the zero-measurement replay, and —
with --trace — writes the trace directory `benchmarks/check_trace.py`
gates (including the explorer counting identities). --report writes
the `ExplorationReport` JSON the slow CI job uploads; artifacts are
written ONLY under explicitly given paths.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np


def _images(b: int, n_in: int, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, 256, size=(b, n_in)).astype(np.uint8)


def _random_net(sizes, seed: int = 0):
    from repro.core import quantize

    rng = np.random.default_rng(seed)
    return quantize.QuantizedNet(weights=[
        rng.integers(-6, 7, size=s).astype(np.int32)
        for s in zip(sizes, sizes[1:])])


def _timed_mean(fn, x, reps: int = 3) -> float:
    np.asarray(fn(x))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.asarray(fn(x))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _accuracy(artifact, x, y) -> float:
    return float((np.asarray(artifact(x)) == y).mean())


def explore_rows(session, net, *, budget: int, batch: int,
                 report_path=None) -> tuple[list[str], object]:
    """The search + replay rows; returns (rows, report)."""
    rep = session.explore(net, objective="latency", strategy="anneal",
                          budget=budget, seed=0, batch=batch,
                          interpret=True)
    spec, tgt = rep.best_config()
    art = session.compile(net, target=tgt, pipeline=spec.spec_string())
    x = _images(batch, art.circuit.n_inputs, seed=3)
    us = _timed_mean(art, x)
    rows = [
        f"netgen_explore_best,{us:.1f},"
        f"target={tgt};pipeline={spec.spec_string()};"
        f"candidates={rep.candidates};pruned={len(rep.pruned)};"
        f"measured={len(rep.evaluations)}",
    ]
    t0 = time.perf_counter()
    rep2 = session.explore(net, objective="latency", strategy="anneal",
                           budget=budget, seed=0, batch=batch,
                           interpret=True)
    replay_us = (time.perf_counter() - t0) * 1e6
    assert rep2.source != "search", rep2.source
    assert rep2.best == rep.best
    rows.append(f"netgen_explore_replay,{replay_us:.1f},"
                f"source={rep2.source};measurements=0")
    if report_path:
        with open(report_path, "w") as f:
            json.dump(rep.as_dict(), f, indent=1)
            f.write("\n")
    return rows, rep


def ladder_row(session, *, full: bool) -> str:
    """Ladder-depth sweep through the explorer's nets axis: train the
    paper protocol at several hidden-layer depths, explore all depths
    in ONE search space under the cells objective, and report each
    depth's accuracy against its explored cell price (paper L3
    reference: 92%)."""
    from repro.core import dataset, mlp, quantize
    from repro.netgen.explore import SearchSpace

    if full:
        depths = {"d1": (500,), "d2": (500, 250)}
        n_train, n_test, epochs = 1000, 1000, 30
    else:
        depths = {"d1": (48,), "d2": (48, 24)}
        n_train, n_test, epochs = 400, 300, 8
    xtr, ytr, xte, yte = dataset.train_test_split(n_train, n_test, seed=0)
    nets = {}
    for name, hidden in depths.items():
        params = mlp.train(
            mlp.MLPConfig(epochs=epochs, seed=1, n_hidden=hidden), xtr, ytr)
        nets[name] = quantize.quantize(params)
    space = SearchSpace(
        pipelines=("default", "zeros,prune,addends"),
        forms=("planes",), tiles=({"bm": 64, "bn": 64, "bkw": 8},),
        nets=tuple(nets))
    # budget == product size: the cells objective dedups each (net,
    # pipeline) to one measured evaluation, the rest prune
    rep = session.explore(nets=nets, space=space, objective="cells",
                          strategy="random",
                          budget=len(space.candidates()), seed=0,
                          interpret=True)
    best_cells: dict[str, float] = {}
    for cand, value in rep.evaluations:
        name = cand["net"]
        best_cells[name] = min(best_cells.get(name, float("inf")), value)
    parts = []
    for name in sorted(depths):
        art = session.compile(nets[name], target="jnp")
        acc = _accuracy(art, xte, yte)
        parts.append(f"{name}_acc={acc:.4f}")
        parts.append(f"{name}_cells={best_cells[name]:.0f}")
    parts.append("paper_l3_acc=0.92")
    return f"netgen_explore_ladder,0,{';'.join(parts)}"


def run(full: bool = False, report_path=None, store=None,
        tune_store=None) -> list[str]:
    from repro import netgen

    sizes = (784, 500, 10) if full else (96, 48, 10)
    budget = 16 if full else 10
    batch = 256 if full else 64
    with netgen.Session(store=store, tune_store=tune_store) as session:
        rows, _ = explore_rows(session, _random_net(sizes, seed=7),
                               budget=budget, batch=batch,
                               report_path=report_path)
        rows.append(ladder_row(session, full=full))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tier-1 CI smoke: tiny net, explicit budget, "
                         "serve the winner through a stacked NetServer")
    ap.add_argument("--budget", type=int, default=8,
                    help="unique candidates the smoke search considers")
    ap.add_argument("--store", default=None, help="ArtifactStore dir")
    ap.add_argument("--tune-store", default=None, help="TuneStore dir")
    ap.add_argument("--report", default=None,
                    help="write the ExplorationReport JSON here")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="write DIR/trace.jsonl + DIR/metrics.prom for "
                         "benchmarks/check_trace.py")
    args = ap.parse_args()

    from repro import netgen
    from repro.netgen import telemetry

    if args.trace:
        telemetry.enable()
    print("name,us_per_call,derived")
    if not args.smoke:
        for row in run(full=args.full, report_path=args.report):
            print(row, flush=True)
    else:
        sizes = (64, 32, 10)
        with netgen.Session(store=args.store,
                            tune_store=args.tune_store) as session:
            net = _random_net(sizes, seed=7)
            rows, rep = explore_rows(session, net, budget=args.budget,
                                     batch=32, report_path=args.report)
            for row in rows:
                print(row, flush=True)
            # serve the winner: stacked dispatch prefers the explored
            # record over the hand-coded form precedence
            server = netgen.NetServer(
                session=session, target="pallas[interpret=true]",
                slot_capacity=32, warmup=False)
            server.register("a", net)
            server.register("b", _random_net(sizes, seed=8))
            x = _images(16, sizes[0], seed=5)
            out = server.predict_many({"a": x, "b": x})
            ref = session.compile(net, target="jnp")
            np.testing.assert_array_equal(out["a"], np.asarray(ref(x)))
            fn, _ = server._stacked_fn(("a", "b"))
            print(f"netgen_explore_smoke,0,budget={args.budget};"
                  f"winner_form={rep.best.form};"
                  f"stacked_datapath={fn.datapath}", flush=True)
    if args.trace:
        trace_dir = Path(args.trace)
        trace_dir.mkdir(parents=True, exist_ok=True)
        telemetry.export_jsonl(trace_dir / "trace.jsonl")
        (trace_dir / "metrics.prom").write_text(telemetry.prometheus())


if __name__ == "__main__":
    main()
