"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle timings and
arithmetic checks. Interpret mode is Python emulation — the derived column
reports correctness/op-counts, not TPU speed (see roofline for that).
"""
from __future__ import annotations

import numpy as np

from repro.netgen import telemetry


def _time(fn, *args, reps=3):
    fn(*args)  # warm
    with telemetry.timed("bench_kernel_seconds") as t:
        for _ in range(reps):
            r = fn(*args)
            if hasattr(r, "block_until_ready"):
                r.block_until_ready()
            elif isinstance(r, tuple):
                r[0].block_until_ready()
    return t.elapsed / reps


def run(full: bool = False) -> list[str]:
    import jax.numpy as jnp
    from repro.kernels.binary_matvec import ops as bops, ref as bref
    from repro.kernels.quant_matmul import ops as qops, ref as qref
    from repro.kernels.ssd_scan import ops as sops, ref as sref

    rows = []
    rng = np.random.default_rng(0)

    # binary matvec: paper-sized layer 784 -> 500
    x = jnp.asarray(rng.integers(0, 2, size=(64, 784)).astype(np.int8))
    w = jnp.asarray(rng.integers(-9, 10, size=(784, 500)).astype(np.int32))
    t_ref = _time(lambda: bref.binary_matmul_ref(x, w))
    got = bops.binary_matmul(x, w)
    ok = int(np.array_equal(np.asarray(got),
                            np.asarray(bref.binary_matmul_ref(x, w))))
    rows.append(f"kern_binary_matmul_ref,{t_ref*1e6:.1f},exact={ok}")

    # the three pallas datapaths on the same layer: int8 activations vs
    # packed activations vs fully bit-packed (bit-plane weights, popcount)
    from repro.netgen.plan import decompose_planes
    want = np.asarray(x).astype(np.int64) @ np.asarray(w).astype(np.int64)
    xp = bops.pack_bits(x)
    kp = xp.shape[1] * 32
    wp = jnp.zeros((kp, 500), jnp.int32).at[:784].set(w)
    pos, neg, n_planes = decompose_planes(np.asarray(wp))
    pos, neg = jnp.asarray(pos), jnp.asarray(neg)
    for name, fn in (
            ("dense", lambda: bops.binary_matmul(x, w)),
            ("packed", lambda: bops.binary_matmul_packed(xp, wp)),
            ("planes", lambda: bops.binary_matmul_planes(xp, pos, neg))):
        t_k = _time(fn)
        ok = int(np.array_equal(np.asarray(fn()), want))
        detail = f"exact={ok}" + (f";planes={n_planes}" if name == "planes"
                                  else "")
        rows.append(f"kern_binary_matmul_{name},{t_k*1e6:.1f},{detail}")

    # quant matmul
    xq = jnp.asarray(rng.integers(-127, 128, size=(64, 512)).astype(np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, size=(512, 256)).astype(np.int8))
    sw = jnp.ones((256,), jnp.float32)
    t_ref = _time(lambda: qref.quant_matmul_ref(xq, wq, np.float32(1), sw))
    got = qops.quant_matmul(xq, wq, np.float32(1), sw)
    ok = int(np.allclose(np.asarray(got),
                         np.asarray(qref.quant_matmul_ref(xq, wq, np.float32(1), sw))))
    rows.append(f"kern_quant_matmul_ref,{t_ref*1e6:.1f},exact={ok}")

    # ssd scan
    b, l, h, g, p, n = (2, 256, 4, 1, 64, 128) if full else (1, 128, 2, 1, 32, 64)
    xx = jnp.asarray(rng.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, l, h)).astype(np.float32))
    a = jnp.asarray(-rng.uniform(0.5, 2, size=(h,)).astype(np.float32))
    bb = jnp.asarray(rng.normal(size=(b, l, g, n)).astype(np.float32) / np.sqrt(n))
    cc = jnp.asarray(rng.normal(size=(b, l, g, n)).astype(np.float32) / np.sqrt(n))
    t_k = _time(lambda: sops.ssd(xx, dt, a, bb, cc, chunk=64))
    yk, _ = sops.ssd(xx, dt, a, bb, cc, chunk=64)
    yr, _ = sref.ssd_batched_ref(xx, dt, a, bb, cc, chunk=64)
    err = float(np.max(np.abs(np.asarray(yk) - np.asarray(yr))))
    rows.append(f"kern_ssd_scan_interpret,{t_k*1e6:.1f},maxerr={err:.2e}")
    return rows
