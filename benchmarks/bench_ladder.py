"""Paper §III table: accuracy at each optimization-ladder stage.

Paper: L0 98% -> L1 95% -> L2 94% -> L3 92% (L4/L5 exact rewrites).
"""
from __future__ import annotations

import time


def run(full: bool = False) -> list[str]:
    from repro.core.ladder import run_ladder

    t0 = time.time()
    if full:
        r = run_ladder(n_train=1000, n_test=1000, epochs=60, seed=0,
                       backends=("jnp", "pallas", "fused"))
    else:
        r = run_ladder(n_train=500, n_test=400, epochs=30, seed=0,
                       backends=("jnp",))
    dt = time.time() - t0
    rows = [f"ladder_{k},{dt*1e6/max(len(r.acc),1):.0f},{v:.4f}"
            for k, v in r.acc.items()]
    rows.append(f"ladder_exact_rewrites,0,{int(r.exact_l4_l5)}")
    rows.append(f"ladder_zero_fraction,0,{r.stats.zero_fraction:.4f}")
    return rows
