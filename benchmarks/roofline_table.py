"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSON."""
from __future__ import annotations

import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def load(mesh: str = "single_pod", tag: str = "") -> list[dict]:
    path = os.path.join(RESULTS_DIR, f"dryrun_{mesh}{tag}.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def render(records: list[dict]) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | useful | roofline frac | peak GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(records, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if not r.get("ok"):
            lines.append(f"| {r['cell']} | - | - | - | - | FAILED: "
                         f"{r.get('error','?')[:60]} | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} | "
            f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['peak_mem_per_device']/2**30:.2f} |")
    return "\n".join(lines)


def run(full: bool = False) -> list[str]:
    rows = []
    for mesh, tag in (("single_pod", "_final"), ("multi_pod", "")):
        recs = load(mesh, tag) or load(mesh)
        ok = sum(1 for r in recs if r.get("ok"))
        rows.append(f"dryrun_{mesh}{tag}_cells_ok,0,{ok}/{len(recs)}")
    hc = os.path.join(RESULTS_DIR, "perf_hillclimb.json")
    if os.path.exists(hc):
        with open(hc) as f:
            n = sum(1 for r in json.load(f) if r.get("ok"))
        rows.append(f"perf_hillclimb_variants_ok,0,{n}")
    return rows


if __name__ == "__main__":
    for mesh, tag in (("single_pod", "_final"), ("multi_pod", "")):
        recs = load(mesh, tag) or load(mesh)
        if recs:
            print(f"\n### {mesh}{tag}\n")
            print(render(recs))
