"""CI gate over a `netgen.telemetry` trace directory.

`examples/mnist_fpga_pipeline.py --trace DIR` writes DIR/trace.jsonl
(one finished span per line) and DIR/metrics.prom (Prometheus text
exposition). This script fails CI when either file violates the
telemetry invariants:

  trace.jsonl   span ids unique; every parent_id resolves to a span in
                the same trace; durations and start times sane; the
                instrumented lifecycle actually present (compile,
                pipeline, pass, dispatch, kernel spans — or, when the
                metrics say zero compiles happened because the run
                warm-started from a cached ArtifactStore, store-load +
                dispatch + kernel spans); no compile span over
                --compile-budget-s (generous — it catches a
                pathological compile-time regression, not jitter).
  metrics.prom  every counter non-negative; per cache scope
                misses == compiles + store_hits + failures (each memory
                miss is served by exactly one lower tier, or raised); slot
                occupancy quantiles in (0, 1]; latency p50 <= p99; per
                (server, version) the latency histogram count equals
                netgen_requests_total (every dispatch observed exactly
                one per-version service time).

A third check spans BOTH files (`check_launches`): every
`netgen.kernel` span dispatched on the fusednet megakernel must record
exactly ONE Pallas launch (`launches` attr == 1 — the datapath's whole
point), and `netgen_kernel_launches_total{form="fusednet"}` must cover
every such dispatch round (warm-up and direct predictor calls may
launch outside a serving span, so the counter bounds the span count
from above). Skipped when the trace carries no fusednet traffic.

  PYTHONPATH=src python benchmarks/check_trace.py DIR \\
      [--compile-budget-s 300]

A fourth check (`check_explore`) gates the design-space explorer's
counting identities when a trace carries explorer traffic: per
explorer scope, `netgen_explore_candidates_total` ==
`..._pruned_total` + `..._measured_total` (every considered candidate
was either statically rejected or measured) and
`..._artifacts_total` == `..._measured_total` (every measured
candidate is backed by exactly one store artifact).

The checks are importable pure functions (`check_spans`,
`check_metrics`, `check_launches`, `check_explore`) so the telemetry
tests exercise the same gate CI runs.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from collections import defaultdict
from pathlib import Path

REQUIRED_SPANS = ("netgen.compile", "netgen.pipeline", "netgen.pass",
                  "netgen.dispatch", "netgen.kernel")
# a fully warm-started process (every artifact served from the
# ArtifactStore — CI's cached-store runs) legitimately never compiles,
# so its trace shows store loads + serving instead of the compile tree
WARM_REQUIRED_SPANS = ("netgen.store.load", "netgen.dispatch",
                       "netgen.kernel")


def check_spans(spans: list[dict], *, compile_budget_s: float = 300.0,
                require: tuple = REQUIRED_SPANS) -> list[str]:
    """Invariant violations (empty list == pass) for parsed span dicts."""
    errors: list[str] = []
    if not spans:
        return ["no spans in trace"]
    by_id: dict[int, dict] = {}
    for rec in spans:
        sid = rec.get("span_id")
        if sid in by_id:
            errors.append(f"duplicate span_id {sid}")
        by_id[sid] = rec
    for rec in spans:
        name = rec.get("name", "?")
        sid = rec.get("span_id")
        parent = rec.get("parent_id")
        if parent is not None:
            if parent not in by_id:
                errors.append(f"orphan span {name} (id={sid}): "
                              f"parent_id {parent} not in trace")
            elif by_id[parent].get("trace_id") != rec.get("trace_id"):
                errors.append(f"span {name} (id={sid}) crosses traces: "
                              f"parent {parent}")
        if not isinstance(rec.get("duration_s"), (int, float)) \
                or rec["duration_s"] < 0:
            errors.append(f"span {name} (id={sid}) has bad duration "
                          f"{rec.get('duration_s')!r}")
        if not isinstance(rec.get("start_unix"), (int, float)) \
                or rec["start_unix"] <= 0:
            errors.append(f"span {name} (id={sid}) has bad start_unix "
                          f"{rec.get('start_unix')!r}")
        if name == "netgen.compile" and rec.get("duration_s", 0) \
                > compile_budget_s:
            errors.append(
                f"compile span over budget: {rec['duration_s']:.1f}s "
                f"> {compile_budget_s:.0f}s ({rec.get('attrs')})")
    names = {rec.get("name") for rec in spans}
    for want in require:
        if want not in names:
            errors.append(f"expected span {want!r} missing from trace")
    return errors


_PROM_LINE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")


def parse_prometheus(text: str) -> list[tuple[str, dict, float]]:
    """(name, labels, value) triples from a text exposition."""
    out = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _PROM_LINE.match(line)
        if m is None:
            raise ValueError(f"unparseable exposition line: {line!r}")
        labels = {}
        if m.group("labels"):
            for part in re.findall(r'(\w+)="((?:[^"\\]|\\.)*)"',
                                   m.group("labels")):
                labels[part[0]] = part[1]
        out.append((m.group("name"), labels, float(m.group("value"))))
    return out


def check_metrics(samples: list[tuple[str, dict, float]]) -> list[str]:
    """Counter/histogram invariant violations (empty list == pass)."""
    errors: list[str] = []
    per_cache: dict[str, dict[str, float]] = defaultdict(dict)
    latency: dict[tuple, dict[str, float]] = defaultdict(dict)
    latency_counts: dict[tuple, float] = {}
    request_counts: dict[tuple, float] = {}
    # an idle server's occupancy summary legitimately exports 0-valued
    # quantiles (empty histogram): only gate scopes that saw traffic
    occ_counts = {labels.get("server"): value
                  for name, labels, value in samples
                  if name == "netgen_slot_occupancy_count"}
    for name, labels, value in samples:
        if name.endswith("_total") and value < 0:
            errors.append(f"negative counter {name}{labels}: {value}")
        if name == "netgen_slot_occupancy" and "quantile" in labels \
                and occ_counts.get(labels.get("server"), 0) > 0:
            if not 0.0 < value <= 1.0:
                errors.append(
                    f"slot occupancy quantile out of (0, 1]: "
                    f"{labels} -> {value}")
        cache = labels.get("cache")
        if cache is not None:
            if name == "netgen_cache_misses_total":
                per_cache[cache]["misses"] = value
            elif name == "netgen_cache_compiles_total":
                per_cache[cache]["compiles"] = value
            elif name == "netgen_cache_store_hits_total":
                per_cache[cache]["store_hits"] = value
            elif name == "netgen_cache_compile_failures_total":
                per_cache[cache]["failures"] = value
        if name == "netgen_predict_latency_seconds" and "quantile" in labels:
            key = (labels.get("server"), labels.get("version"))
            latency[key][labels["quantile"]] = value
        if name == "netgen_predict_latency_seconds_count":
            latency_counts[(labels.get("server"),
                            labels.get("version"))] = value
        if name == "netgen_requests_total":
            request_counts[(labels.get("server"),
                            labels.get("version"))] = value
    for cache, c in sorted(per_cache.items()):
        # failures: misses whose compile raised (a VerificationError from
        # the pre-backend analysis, a backend error) — counted so the
        # three lower-tier outcomes still sum to the misses exactly.
        if {"misses", "compiles", "store_hits"} <= set(c) and \
                c["misses"] != (c["compiles"] + c["store_hits"]
                                + c.get("failures", 0)):
            errors.append(
                f"cache {cache}: misses ({c['misses']:.0f}) != compiles "
                f"({c['compiles']:.0f}) + store_hits ({c['store_hits']:.0f})"
                f" + failures ({c.get('failures', 0):.0f})")
    for key, qs in sorted(latency.items()):
        if "0.5" in qs and "0.99" in qs and qs["0.5"] > qs["0.99"]:
            errors.append(f"latency p50 > p99 for server={key[0]} "
                          f"version={key[1]}: {qs['0.5']} > {qs['0.99']}")
    # every dispatched request produced exactly one per-version latency
    # observation — the identity that catches the whole-call-dt
    # misattribution bug (ISSUE 7): predict_many must observe each
    # version's own service time once, not the shared wall clock N times
    # (or zero times)
    for key in sorted(set(latency_counts) | set(request_counts)):
        n_lat = latency_counts.get(key, 0.0)
        n_req = request_counts.get(key, 0.0)
        if n_lat != n_req:
            errors.append(
                f"latency observations ({n_lat:.0f}) != requests "
                f"({n_req:.0f}) for server={key[0]} version={key[1]}")
    return errors


def check_launches(spans: list[dict],
                   samples: list[tuple[str, dict, float]]) -> list[str]:
    """The megakernel's launch-count contract (empty list == pass): a
    fusednet dispatch round is ONE Pallas launch. Each `netgen.kernel`
    span with attrs.form == "fusednet" must carry launches == 1, and
    the `netgen_kernel_launches_total{form="fusednet"}` counter must be
    at least the number of such rounds (predictor warm-ups launch
    outside any serving span, so equality is not required). No-op for
    traces without fusednet traffic."""
    errors: list[str] = []
    rounds = [rec for rec in spans
              if rec.get("name") == "netgen.kernel"
              and (rec.get("attrs") or {}).get("form") == "fusednet"]
    for rec in rounds:
        launches = (rec.get("attrs") or {}).get("launches")
        if launches != 1:
            errors.append(
                f"fusednet dispatch round (span_id="
                f"{rec.get('span_id')}) records launches={launches!r}, "
                f"expected exactly 1")
    total = sum(v for name, labels, v in samples
                if name == "netgen_kernel_launches_total"
                and labels.get("form") == "fusednet")
    if rounds and total < len(rounds):
        errors.append(
            f"{len(rounds)} fusednet dispatch rounds but "
            f"netgen_kernel_launches_total{{form=fusednet}} is only "
            f"{total:.0f}")
    return errors


def check_explore(samples: list[tuple[str, dict, float]]) -> list[str]:
    """The design-space explorer's counting identities (empty list ==
    pass), per `explorer=` scope: every unique candidate considered was
    either pruned pre-measurement by the shared legality checks or
    measured (`candidates == pruned + measured` — a candidate that
    silently vanished means the search lied about its coverage), and
    every measured candidate is backed by exactly one store artifact
    (`artifacts == measured`). No-op for traces without explorer
    traffic."""
    errors: list[str] = []
    short = {
        "netgen_explore_candidates_total": "candidates",
        "netgen_explore_pruned_total": "pruned",
        "netgen_explore_measured_total": "measured",
        "netgen_explore_artifacts_total": "artifacts",
    }
    per: dict[str, dict[str, float]] = defaultdict(dict)
    for name, labels, value in samples:
        scope = labels.get("explorer")
        if scope is not None and name in short:
            per[scope][short[name]] = value
    for scope, c in sorted(per.items()):
        cand = c.get("candidates", 0.0)
        pruned = c.get("pruned", 0.0)
        measured = c.get("measured", 0.0)
        if cand != pruned + measured:
            errors.append(
                f"explorer {scope}: candidates ({cand:.0f}) != pruned "
                f"({pruned:.0f}) + measured ({measured:.0f})")
        if c.get("artifacts", 0.0) != measured:
            errors.append(
                f"explorer {scope}: artifacts ({c.get('artifacts', 0.0):.0f})"
                f" != measured candidates ({measured:.0f}) — a measured "
                f"candidate must be backed by exactly one store artifact")
    return errors


def check_trace_dir(trace_dir, *, compile_budget_s: float = 300.0
                    ) -> list[str]:
    """All invariant violations for one --trace output directory."""
    trace_dir = Path(trace_dir)
    errors: list[str] = []
    samples: list[tuple[str, dict, float]] = []
    prom = trace_dir / "metrics.prom"
    if not prom.exists():
        errors.append(f"{prom} missing")
    else:
        try:
            samples = parse_prometheus(prom.read_text())
            errors += check_metrics(samples)
            errors += check_explore(samples)
        except ValueError as e:
            errors.append(str(e))
    # did this process compile anything, or warm-start off the store?
    compiles = sum(v for name, _, v in samples
                   if name == "netgen_cache_compiles_total")
    require = REQUIRED_SPANS if compiles > 0 else WARM_REQUIRED_SPANS
    jsonl = trace_dir / "trace.jsonl"
    if not jsonl.exists():
        errors.append(f"{jsonl} missing")
    else:
        spans = []
        for i, line in enumerate(jsonl.read_text().splitlines(), 1):
            try:
                spans.append(json.loads(line))
            except json.JSONDecodeError:
                errors.append(f"{jsonl}:{i}: not valid JSON")
        errors += check_spans(spans, compile_budget_s=compile_budget_s,
                              require=require)
        errors += check_launches(spans, samples)
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace_dir", help="directory written by --trace")
    ap.add_argument("--compile-budget-s", type=float, default=300.0,
                    help="fail if any netgen.compile span exceeds this")
    args = ap.parse_args()
    errors = check_trace_dir(args.trace_dir,
                             compile_budget_s=args.compile_budget_s)
    if errors:
        for e in errors:
            print(f"TRACE GATE: {e}", file=sys.stderr)
        sys.exit(1)
    print(f"trace gate passed: {args.trace_dir}")


if __name__ == "__main__":
    main()
