"""Logical-axis sharding rules (FSDP x TP x SP x EP x pod-DP).

Model code annotates tensors with *logical* axis names; the active rule set
maps those to physical mesh axes. Outside a `use_mesh` context every
annotation is a no-op, so the same model code runs single-device tests and
512-chip dry-runs unchanged.

Rules (defaults; see DESIGN.md §5):

  batch    -> ("pod", "data")   data parallel (pod axis joins on multi-pod)
  seq      -> ("model",)        sequence parallelism between layers
  vocab    -> ("model",)        vocab-sharded embedding / logits
  heads    -> ("model",)        attention-head tensor parallelism
  kv_heads -> ("model",)        (falls back to None when indivisible - GQA)
  ffn      -> ("model",)        MLP tensor parallelism
  fsdp     -> ("data",)         parameter FSDP axis
  experts  -> ("model",)        expert parallelism
  kv_seq   -> ("model",)        decode-time KV-cache sequence sharding

Divisibility guard: a logical axis silently drops to replicated when the
dimension is not divisible by the product of its mesh axes (e.g. 20 query
heads on a 16-way model axis); the fallback is recorded so the roofline
report can show where TP degraded.
"""
from __future__ import annotations

import contextlib
import math
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data", "model"),   # flattened token dim (MoE dispatch)
    "seq": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "fsdp": ("data",),
    "experts": ("model",),
    "kv_seq": ("model",),
    "state": (),
    None: (),
}

_ctx = threading.local()


def _state():
    if not hasattr(_ctx, "mesh"):
        _ctx.mesh, _ctx.rules, _ctx.fallbacks = None, dict(DEFAULT_RULES), []
    return _ctx


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    """Activate a mesh + logical rules for `shard`/`spec` calls within."""
    st = _state()
    prev = (st.mesh, st.rules, st.fallbacks)
    st.mesh = mesh
    st.rules = dict(DEFAULT_RULES)
    if rules:
        st.rules.update(rules)
    st.fallbacks = []
    try:
        yield
    finally:
        st.mesh, st.rules, st.fallbacks = prev


def active_mesh() -> Mesh | None:
    return _state().mesh


def fallbacks() -> list:
    """Logical axes that degraded to replicated (for the perf report)."""
    return list(_state().fallbacks)


def _axes_for(logical: str | None, dim: int, mesh: Mesh) -> tuple[str, ...] | None:
    st = _state()
    axes = st.rules.get(logical, ())
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    total = math.prod(mesh.shape[a] for a in axes)
    if dim % total != 0:
        # try a prefix of the axes (e.g. drop "pod" but keep "data")
        for cut in range(len(axes) - 1, 0, -1):
            sub = axes[:cut]
            if dim % math.prod(mesh.shape[a] for a in sub) == 0:
                st.fallbacks.append((logical, dim, axes, sub))
                return sub
        st.fallbacks.append((logical, dim, axes, None))
        return None
    return axes


def spec(shape: tuple[int, ...], logical: tuple[str | None, ...]) -> P:
    """PartitionSpec for `shape` under the active rules (None mesh -> P())."""
    mesh = _state().mesh
    if mesh is None:
        return P()
    assert len(shape) == len(logical), (shape, logical)
    parts = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        axes = _axes_for(name, dim, mesh) if name else None
        if axes and not (set(axes) & used):
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard(x: jax.Array, *logical: str | None) -> jax.Array:
    """Annotate activation x with a logical sharding constraint."""
    mesh = _state().mesh
    if mesh is None:
        return x
    s = spec(x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))


def named_sharding(shape: tuple[int, ...], logical: tuple[str | None, ...]) -> NamedSharding | None:
    mesh = _state().mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(shape, logical))
