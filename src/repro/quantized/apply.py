"""The paper's technique at LM scale: post-training weight quantization +
structural pruning of a trained checkpoint ("netgen for transformers").

What transfers from the paper (DESIGN.md §6): the WEIGHT-side ladder —
cast trained weights to integers (here: per-channel symmetric int8, the
TPU-native generalization of the paper's +/-9 integer cast) and prune
structurally-dead channels at specialization time. What does NOT transfer:
1-bit activations (paper L1/L2) — fine for a 10-class MLP, destroys LMs.

Two execution modes:
  * `quantize_tree` / fake-quant — weights stored int8+scale, dequantized
    at load: bit-exact accuracy evaluation of the quantized model on any
    backend (this is how the quality ladder is measured).
  * real int8 execution — `repro.kernels.quant_matmul` (MXU int8 path);
    demonstrated end-to-end in examples/quantize_lm.py.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


QUANT_MIN_SIZE = 1 << 14      # don't quantize tiny tensors (norms, biases)

# serving-path quantization allowlist: the big matmul weights only
import re as _re
_QUANT_NAMES = _re.compile(
    r"\['(wq|wk|wv|wo|wi|wg|in_proj|out_proj|head|tok)'\]$")


def _is_weight(path: str, x, min_size: int = QUANT_MIN_SIZE) -> bool:
    if x.ndim < 2 or x.size < min_size:
        return False
    # never quantize rotary/positional tables or optimizer state
    return not any(s in path for s in ("norm", "scale", "bias"))


def quantize_leaf(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-output-channel (last dim) symmetric int8."""
    amax = np.maximum(np.abs(x).reshape(-1, x.shape[-1]).max(axis=0), 1e-8)
    s = (amax / 127.0).astype(np.float32)
    q = np.clip(np.round(x / s), -127, 127).astype(np.int8)
    return q, s


def quantize_tree(params, *, min_size: int = QUANT_MIN_SIZE) -> tuple[dict, dict]:
    """Returns (quantized storage tree, stats). Leaves are either raw
    arrays (small tensors) or {"q": int8, "s": fp32 scales}."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    total_before = total_after = 0
    n_quant = 0
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        total_before += arr.nbytes
        if _is_weight(key, arr, min_size):
            q, s = quantize_leaf(arr)
            out.append({"q": q, "s": s})
            total_after += q.nbytes + s.nbytes
            n_quant += 1
        else:
            out.append(arr)
            total_after += arr.nbytes
    stats = {
        "bytes_before": total_before,
        "bytes_after": total_after,
        "compression": total_before / max(total_after, 1),
        "n_quantized": n_quant,
        "n_leaves": len(flat),
    }
    return jax.tree.unflatten(treedef, out), stats


def dequantize_tree(qtree, dtype=jnp.float32):
    """Fake-quant materialization: int8 storage -> float weights carrying
    the quantization error (the accuracy-evaluation path)."""
    def deq(leaf):
        if isinstance(leaf, dict) and set(leaf) == {"q", "s"}:
            return jnp.asarray(leaf["q"], jnp.float32) * jnp.asarray(leaf["s"])
        return jnp.asarray(leaf)

    return jax.tree.map(deq, qtree,
                        is_leaf=lambda l: isinstance(l, dict) and set(l) == {"q", "s"})


def abstract_quantized_params(cfg, *, min_size: int = QUANT_MIN_SIZE):
    """Abstract (ParamInfo) tree for the W8-specialized serving artifact:
    big weights become {"q": int8 ParamInfo, "s": fp32 scales} with the
    same logical sharding — drives allocation-free quantized dry-runs."""
    import jax.numpy as jnp
    from repro.models import api
    from repro.models.base import ParamInfo, is_info

    tree = api.abstract_params(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_info)
    out = []
    for path, info in flat:
        key = jax.tree_util.keystr(path)
        size = int(np.prod(info.shape))
        if (len(info.shape) >= 2 and size >= min_size
                and _QUANT_NAMES.search(key)):
            # per-(stack, out-channel) scales: (L, last) for stacked weights
            sshape = ((info.shape[0], info.shape[-1])
                      if len(info.shape) >= 3 else (info.shape[-1],))
            slogical = ((info.logical[0], info.logical[-1])
                        if len(info.shape) >= 3 else (info.logical[-1],))
            out.append({
                "q": ParamInfo(info.shape, jnp.int8, info.logical, init="zeros"),
                "s": ParamInfo(sshape, jnp.float32, slogical, init="ones"),
            })
        else:
            out.append(info)
    return jax.tree.unflatten(treedef, out)


def quantize_params_for_serving(cfg, params, *, min_size: int = QUANT_MIN_SIZE):
    """Materialized version of abstract_quantized_params: real int8+scales
    with per-(layer, out-channel) resolution for stacked weights."""
    import jax.numpy as jnp

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if (arr.ndim >= 2 and arr.size >= min_size
                and _QUANT_NAMES.search(key)):
            if arr.ndim >= 3:
                flatw = arr.reshape(arr.shape[0], -1, arr.shape[-1])
                amax = np.maximum(np.abs(flatw).max(axis=1), 1e-8)  # (L, last)
                s = (amax / 127.0).astype(np.float32)
                sb = s.reshape(arr.shape[0], *([1] * (arr.ndim - 2)), arr.shape[-1])
            else:
                amax = np.maximum(
                    np.abs(arr).reshape(-1, arr.shape[-1]).max(axis=0), 1e-8)
                s = (amax / 127.0).astype(np.float32)
                sb = s
            q = np.clip(np.round(arr / sb), -127, 127).astype(np.int8)
            out.append({"q": jnp.asarray(q), "s": jnp.asarray(s)})
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def prune_stats(params, threshold: float = 0.0) -> dict:
    """Structural zero analysis (paper L4 at LM scale): per weight matrix,
    the fraction of output channels with max |w| <= threshold — channels a
    specializing compiler deletes outright."""
    dead = total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        arr = np.asarray(leaf)
        if not _is_weight(jax.tree_util.keystr(path), arr):
            continue
        chan_max = np.abs(arr).reshape(-1, arr.shape[-1]).max(axis=0)
        dead += int((chan_max <= threshold).sum())
        total += arr.shape[-1]
    return {"dead_channels": dead, "total_channels": total,
            "dead_fraction": dead / max(total, 1)}
