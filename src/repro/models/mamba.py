"""Mamba2 LM: embedding -> L x (norm -> SSD mixer) -> norm -> head.

Attention-free; decode state is O(1) in sequence length, which is why the
long_500k cell runs for this family (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import embedding as emb_lib
from repro.layers import mamba2 as m2
from repro.layers import norms
from repro.models import runtime
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def abstract_params(cfg: ArchConfig) -> dict:
    L = cfg.n_layers
    return {
        "embed": emb_lib.embed_params(cfg),
        "layers": {
            "ln": norms.norm_params(cfg.norm, cfg.d_model, L),
            "mixer": m2.mamba_params(cfg, L),
        },
        "final_norm": norms.norm_params(cfg.norm, cfg.d_model),
    }


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    info = m2.ssm_cache_info(cfg, batch)

    def stack(i: ParamInfo) -> ParamInfo:
        return ParamInfo((cfg.n_layers,) + i.shape, i.dtype, (None,) + i.logical,
                         init="zeros")

    return jax.tree.map(stack, info, is_leaf=lambda x: isinstance(x, ParamInfo))


def backbone(cfg: ArchConfig, params: dict, h: jnp.ndarray, *,
             remat: str = "none", use_kernel: bool = False) -> jnp.ndarray:
    def body(carry, lp):
        h = carry
        hn = norms.apply_norm(cfg.norm, lp["ln"], h, eps=cfg.norm_eps)
        h = h + m2.mamba_mixer(cfg, lp["mixer"], hn, use_kernel=use_kernel)
        h = m2.shard_hidden(h)
        return h, None

    if remat == "full":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["layers"], **runtime.scan_kwargs())
    return norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps)


def forward(cfg: ArchConfig, params: dict, batch: dict, *, remat: str = "none",
            return_full_logits: bool = True) -> tuple[jnp.ndarray, dict]:
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    h = shard(h, "batch", "seq", None)
    h = backbone(cfg, params, h, remat=remat)
    logits = emb_lib.lm_head(cfg, params["embed"], h)
    return logits, {}


def prefill(cfg: ArchConfig, params: dict, batch: dict, cache: dict,
            *, remat: str = "none") -> tuple[jnp.ndarray, dict]:
    """Prefill for SSM: run the chunked scan and (re)build decode state.

    The decode state after prefill is obtained by running the mixers with
    state emission; for the dry-run cells we return the last-position
    logits and a cache advanced through the whole prompt."""
    tokens = batch["tokens"]
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    h = shard(h, "batch", "seq", None)

    def body(carry, xs):
        h = carry
        lp, cache_layer = xs
        hn = norms.apply_norm(cfg.norm, lp["ln"], h, eps=cfg.norm_eps)
        out, state = m2.mamba_mixer(cfg, lp["mixer"], hn, return_state=True)
        h = h + out
        h = m2.shard_hidden(h)
        new_cache_layer = {
            "conv": state["conv"].astype(cache_layer["conv"].dtype),
            "ssm": state["ssm"].astype(cache_layer["ssm"].dtype),
        }
        return h, new_cache_layer

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache),
                                **runtime.scan_kwargs())
    h = norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps)
    logits = emb_lib.lm_head(cfg, params["embed"], h[:, -1:, :])[:, 0]
    return logits, new_cache


def decode_step(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cache: dict,
                extras: dict | None = None) -> tuple[jnp.ndarray, dict]:
    batch = {"tokens": tokens}
    if extras:
        batch.update(extras)
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)

    def body(carry, xs):
        h = carry
        lp, cache_layer = xs
        hn = norms.apply_norm(cfg.norm, lp["ln"], h, eps=cfg.norm_eps)
        out, new_cache_layer = m2.mamba_decode_step(cfg, lp["mixer"], hn, cache_layer)
        return h + out, new_cache_layer

    h, new_cache = jax.lax.scan(body, h, (params["layers"], cache),
                                **runtime.scan_kwargs())
    h = norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps)
    logits = emb_lib.lm_head(cfg, params["embed"], h)[:, 0]
    return logits, new_cache
