"""Decoder-only transformer: dense (qwen/llama/gemma/musicgen), MoE
(granite/qwen3-moe), VLM backbone (qwen2-vl) — one implementation,
config-switched.

Layers are stacked and iterated with `lax.scan` (keeps the HLO small and
compile times flat in depth — essential for 80-layer dry-runs) with a
configurable remat policy on the block body. Hidden states are re-annotated
(batch x seq-SP) at every layer boundary.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_lib
from repro.layers import embedding as emb_lib
from repro.layers import mlp as mlp_lib
from repro.layers import moe as moe_lib
from repro.layers import norms
from repro.models import runtime
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def abstract_params(cfg: ArchConfig) -> dict:
    L = cfg.n_layers
    plus_one = cfg.name.startswith("gemma")
    p = {
        "embed": emb_lib.embed_params(cfg),
        "layers": {
            "ln_attn": norms.norm_params(cfg.norm, cfg.d_model, L, plus_one=plus_one),
            "attn": attn_lib.attn_params(cfg, L),
            "ln_mlp": norms.norm_params(cfg.norm, cfg.d_model, L, plus_one=plus_one),
        },
        "final_norm": norms.norm_params(cfg.norm, cfg.d_model, plus_one=plus_one),
    }
    if cfg.family == "moe":
        p["layers"]["moe"] = moe_lib.moe_params(cfg, L)
    else:
        p["layers"]["mlp"] = mlp_lib.mlp_params(cfg, L)
    return p


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """KV cache stacked over layers: (L, B, KV, S, hd)."""
    info = attn_lib.init_cache_info(cfg, batch, max_len)

    def stack(i: ParamInfo) -> ParamInfo:
        return ParamInfo((cfg.n_layers,) + i.shape, i.dtype, (None,) + i.logical,
                         init="zeros")

    return jax.tree.map(stack, info, is_leaf=lambda x: isinstance(x, ParamInfo))


def _block(cfg: ArchConfig, lp: dict, h, positions, cache_layer, cache_pos,
           causal: bool):
    """One transformer block. Returns (h, new_cache_layer, aux)."""
    plus_one = cfg.name.startswith("gemma")
    hn = norms.apply_norm(cfg.norm, lp["ln_attn"], h, eps=cfg.norm_eps,
                          plus_one=plus_one)
    a, new_cache = attn_lib.attention(
        cfg, lp["attn"], hn, positions, cache=cache_layer, cache_pos=cache_pos,
        causal=causal)
    h = h + a
    h = shard(h, "batch", "seq", None)
    hn = norms.apply_norm(cfg.norm, lp["ln_mlp"], h, eps=cfg.norm_eps,
                          plus_one=plus_one)
    aux = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}
    if cfg.family == "moe":
        m, aux = moe_lib.moe(cfg, lp["moe"], hn)
    else:
        m = mlp_lib.mlp(cfg, lp["mlp"], hn)
    h = h + m
    h = shard(h, "batch", "seq", None)
    return h, new_cache, aux


def backbone(
    cfg: ArchConfig,
    params: dict,
    h: jnp.ndarray,                  # (B, S, D) assembled input
    positions: jnp.ndarray,
    *,
    cache: dict | None = None,
    cache_pos: jnp.ndarray | None = None,
    remat: str = "none",             # none | full
) -> tuple[jnp.ndarray, dict | None, dict]:
    """Run all layers. Returns (h, new_cache, aux_losses)."""
    stacked = params["layers"]
    causal = True

    def body(carry, xs):
        h, lb, zl = carry
        lp, cache_layer = xs
        h, new_cache, aux = _block(cfg, lp, h, positions, cache_layer,
                                   cache_pos, causal)
        return (h, lb + aux["lb_loss"], zl + aux["z_loss"]), new_cache

    if remat == "full":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (stacked, cache)
    if cache is None:
        # scan needs a pytree with a leading L dim for every leaf; feed a
        # dummy zeros tree shaped (L,) when there is no cache.
        xs = (stacked, jnp.zeros((cfg.n_layers,), jnp.float32))

        def body_nocache(carry, xs):
            lp, _ = xs
            new_carry, _ = body(carry, (lp, None))
            return new_carry, None

        init = (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        (h, lb, zl), _ = jax.lax.scan(body_nocache, init, xs, **runtime.scan_kwargs())
        new_cache = None
    else:
        init = (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        (h, lb, zl), new_cache = jax.lax.scan(body, init, xs, **runtime.scan_kwargs())

    h = norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps,
                         plus_one=cfg.name.startswith("gemma"))
    return h, new_cache, {"lb_loss": lb / cfg.n_layers, "z_loss": zl / cfg.n_layers}


def _positions_for(cfg: ArchConfig, batch: dict, B: int, S: int):
    if cfg.pos == "mrope":
        pos = batch.get("positions")
        if pos is None:
            base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            return jnp.stack([base] * 3)               # (3, B, S)
        return pos.transpose(1, 0, 2)                  # (B, 3, S) -> (3, B, S)
    pos = batch.get("positions")
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return pos


def forward(cfg: ArchConfig, params: dict, batch: dict, *, remat: str = "none",
            return_full_logits: bool = True) -> tuple[jnp.ndarray, dict]:
    """Training/eval forward. Returns (logits, aux)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    h = shard(h, "batch", "seq", None)
    positions = _positions_for(cfg, batch, B, S)
    h, _, aux = backbone(cfg, params, h, positions, remat=remat)
    logits = emb_lib.lm_head(cfg, params["embed"], h)
    return logits, aux


def prefill(cfg: ArchConfig, params: dict, batch: dict, cache: dict,
            *, remat: str = "none") -> tuple[jnp.ndarray, dict]:
    """Prefill: full-sequence forward, fills `cache`, returns ONLY the
    last-position logits (B, V) — full (B, S, V) logits for 32k x 152k
    vocab would be ~300 GB and are never needed."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    h = shard(h, "batch", "seq", None)
    positions = _positions_for(cfg, batch, B, S)
    h, new_cache, _ = backbone(cfg, params, h, positions, cache=cache, remat=remat)
    last = h[:, -1:, :]
    logits = emb_lib.lm_head(cfg, params["embed"], last)[:, 0]
    return logits, new_cache


def decode_step(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cache: dict,
                extras: dict | None = None) -> tuple[jnp.ndarray, dict]:
    """One decode step. tokens: (B, 1); pos: (B,) current write index.
    Returns (logits (B, V), new cache)."""
    B = tokens.shape[0]
    batch = {"tokens": tokens}
    if extras:
        batch.update(extras)
    if cfg.modality == "vlm":
        batch.setdefault("pixel_embeds",
                         jnp.zeros((B, 1, cfg.d_model), cfg.cdtype()))
        batch.setdefault("pixel_mask", jnp.zeros((B, 1), bool))
    if cfg.modality == "audio":
        batch.setdefault("frame_embeds",
                         jnp.zeros((B, 1, cfg.d_model), cfg.cdtype()))
        batch.setdefault("positions", pos[:, None])
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    if cfg.pos == "mrope":
        positions = jnp.stack([pos[:, None]] * 3)       # (3, B, 1)
    else:
        positions = pos[:, None]                        # (B, 1)
    h, new_cache, _ = backbone(cfg, params, h, positions, cache=cache,
                               cache_pos=pos)
    logits = emb_lib.lm_head(cfg, params["embed"], h)[:, 0]
    return logits, new_cache
