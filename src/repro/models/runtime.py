"""Runtime flags threaded into model code (analysis-mode scan unrolling).

XLA's HloCostAnalysis counts a while-loop body ONCE, not x trip-count, so
the roofline's cost lowerings unroll the layer scans (on small-L configs)
to make every layer's flops/bytes/collectives visible. Production
lowerings keep scans rolled (small HLO, flat compile times).
"""
from __future__ import annotations

import contextlib
import threading

_ctx = threading.local()


def _on() -> bool:
    return getattr(_ctx, "unroll", False)


@contextlib.contextmanager
def unrolled_scans():
    prev = _on()
    _ctx.unroll = True
    try:
        yield
    finally:
        _ctx.unroll = prev


def scan_kwargs() -> dict:
    """kwargs for LAYER scans (not flash/SSD inner scans)."""
    return {"unroll": True} if _on() else {}


# -- generic named flags (perf-variant switches used by the hillclimb) ------

def _flags() -> dict:
    if not hasattr(_ctx, "flags"):
        _ctx.flags = {}
    return _ctx.flags


@contextlib.contextmanager
def with_flags(**kw):
    prev = dict(_flags())
    _flags().update(kw)
    try:
        yield
    finally:
        _ctx.flags = prev


def flag(name: str, default=None):
    return _flags().get(name, default)
