"""Zamba2-style hybrid: a Mamba2 backbone with a SHARED attention+MLP
block applied every `attn_every` layers (arXiv:2411.15242).

The shared block has ONE set of weights reused at every application site
(the paper's parameter-efficiency trick); its input is the concatenation
of the current hidden state with the original embedding, brought back to
d_model by a learned projection. Per-site LoRA adapters from the paper are
omitted (noted in DESIGN.md §7) — they do not change the distribution or
roofline structure.

Structure: n_layers mamba blocks in groups of `attn_every`; after each
group, the shared transformer block runs. The mamba stack uses lax.scan
per group (compile-time flat in depth); the shared-block applications are
a short unrolled loop (n_layers / attn_every sites).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import attention as attn_lib
from repro.layers import embedding as emb_lib
from repro.layers import mamba2 as m2
from repro.layers import mlp as mlp_lib
from repro.layers import norms
from repro.layers.common import wx
from repro.models import runtime
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def n_sites(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def abstract_params(cfg: ArchConfig) -> dict:
    L = cfg.n_layers
    return {
        "embed": emb_lib.embed_params(cfg),
        "layers": {
            "ln": norms.norm_params(cfg.norm, cfg.d_model, L),
            "mixer": m2.mamba_params(cfg, L),
        },
        "shared": {
            "in_proj": ParamInfo((2 * cfg.d_model, cfg.d_model), jnp.float32,
                                 ("fsdp", None)),
            "ln_attn": norms.norm_params(cfg.norm, cfg.d_model),
            "attn": attn_lib.attn_params(cfg),
            "ln_mlp": norms.norm_params(cfg.norm, cfg.d_model),
            "mlp": mlp_lib.mlp_params(cfg),
        },
        "final_norm": norms.norm_params(cfg.norm, cfg.d_model),
    }


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """SSM cache stacked over layers + KV cache stacked over shared sites."""
    ssm = m2.ssm_cache_info(cfg, batch)
    kv = attn_lib.init_cache_info(cfg, batch, max_len)
    S = n_sites(cfg)

    def stack(n):
        def f(i: ParamInfo) -> ParamInfo:
            return ParamInfo((n,) + i.shape, i.dtype, (None,) + i.logical,
                             init="zeros")
        return f

    return {
        "ssm": jax.tree.map(stack(cfg.n_layers), ssm,
                            is_leaf=lambda x: isinstance(x, ParamInfo)),
        "kv": jax.tree.map(stack(S), kv,
                           is_leaf=lambda x: isinstance(x, ParamInfo)),
    }


def _shared_block(cfg, sp, h, emb0, positions, cache_kv, cache_pos):
    """The shared attention+MLP block. Returns (h, new_kv_cache)."""
    x = jnp.concatenate([h, emb0], axis=-1)
    x = jnp.einsum("bse,ed->bsd", x, wx(sp["in_proj"], h.dtype))
    xn = norms.apply_norm(cfg.norm, sp["ln_attn"], x, eps=cfg.norm_eps)
    a, new_kv = attn_lib.attention(cfg, sp["attn"], xn, positions,
                                   cache=cache_kv, cache_pos=cache_pos)
    x = x + a
    xn = norms.apply_norm(cfg.norm, sp["ln_mlp"], x, eps=cfg.norm_eps)
    x = x + mlp_lib.mlp(cfg, sp["mlp"], xn)
    h = h + x
    return shard(h, "batch", "seq", None), new_kv


def _mamba_group(cfg, group_params, h, *, remat, group_cache=None,
                 decode=False, want_state=False):
    """Scan over `attn_every` mamba layers. Returns (h, new_group_cache)."""
    def body(carry, xs):
        h = carry
        lp, cache_layer = xs
        hn = norms.apply_norm(cfg.norm, lp["ln"], h, eps=cfg.norm_eps)
        if decode:
            out, new_cache = m2.mamba_decode_step(cfg, lp["mixer"], hn, cache_layer)
        elif want_state:
            out, state = m2.mamba_mixer(cfg, lp["mixer"], hn, return_state=True)
            new_cache = {
                "conv": state["conv"].astype(cache_layer["conv"].dtype),
                "ssm": state["ssm"].astype(cache_layer["ssm"].dtype),
            }
        else:
            out, new_cache = m2.mamba_mixer(cfg, lp["mixer"], hn), None
        h = h + out
        h = m2.shard_hidden(h)
        return h, new_cache

    if remat == "full":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if group_cache is None:
        dummy = jnp.zeros((cfg.attn_every,), jnp.float32)

        def body2(c, xs):
            lp, _ = xs
            h, _ = body(c, (lp, None))
            return h, None
        h, _ = jax.lax.scan(body2, h, (group_params, dummy),
                            **runtime.scan_kwargs())
        return h, None
    h, new_cache = jax.lax.scan(body, h, (group_params, group_cache),
                                **runtime.scan_kwargs())
    return h, new_cache


def _grouped(tree, n_groups: int):
    """Reshape stacked (L, ...) leaves to (n_groups, L/n_groups, ...)."""
    return jax.tree.map(
        lambda a: a.reshape((n_groups, a.shape[0] // n_groups) + a.shape[1:]), tree)


def forward(cfg: ArchConfig, params: dict, batch: dict, *, remat: str = "none",
            return_full_logits: bool = True) -> tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    h = shard(h, "batch", "seq", None)
    emb0 = h
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    groups = _grouped(params["layers"], n_sites(cfg))
    for g in range(n_sites(cfg)):
        gp = jax.tree.map(lambda a: a[g], groups)
        h, _ = _mamba_group(cfg, gp, h, remat=remat)
        h, _ = _shared_block(cfg, params["shared"], h, emb0, positions, None, None)
    h = norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps)
    logits = emb_lib.lm_head(cfg, params["embed"], h)
    return logits, {}


def prefill(cfg: ArchConfig, params: dict, batch: dict, cache: dict,
            *, remat: str = "none") -> tuple[jnp.ndarray, dict]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    h = shard(h, "batch", "seq", None)
    emb0 = h
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    groups = _grouped(params["layers"], n_sites(cfg))
    ssm_grouped = _grouped(cache["ssm"], n_sites(cfg))
    new_ssm, new_kv = [], []
    for g in range(n_sites(cfg)):
        gp = jax.tree.map(lambda a: a[g], groups)
        gc = jax.tree.map(lambda a: a[g], ssm_grouped)
        h, nc = _mamba_group(cfg, gp, h, remat=remat, group_cache=gc,
                             want_state=True)
        new_ssm.append(nc)
        kv_site = jax.tree.map(lambda a: a[g], cache["kv"])
        h, nkv = _shared_block(cfg, params["shared"], h, emb0, positions,
                               kv_site, None)
        new_kv.append(nkv)
    h = norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps)
    logits = emb_lib.lm_head(cfg, params["embed"], h[:, -1:, :])[:, 0]
    cache_out = {
        "ssm": jax.tree.map(lambda *xs: jnp.concatenate([x for x in xs]), *new_ssm)
        if len(new_ssm) > 1 else new_ssm[0],
        "kv": jax.tree.map(lambda *xs: jnp.stack(list(xs)), *new_kv),
    }
    return logits, cache_out


def decode_step(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cache: dict,
                extras: dict | None = None) -> tuple[jnp.ndarray, dict]:
    batch = {"tokens": tokens}
    if extras:
        batch.update(extras)
    B = tokens.shape[0]
    h = emb_lib.assemble_inputs(cfg, params["embed"], batch)
    emb0 = h
    positions = pos[:, None]
    groups = _grouped(params["layers"], n_sites(cfg))
    ssm_grouped = _grouped(cache["ssm"], n_sites(cfg))
    new_ssm, new_kv = [], []
    for g in range(n_sites(cfg)):
        gp = jax.tree.map(lambda a: a[g], groups)
        gc = jax.tree.map(lambda a: a[g], ssm_grouped)
        h, nc = _mamba_group(cfg, gp, h, remat="none", group_cache=gc, decode=True)
        new_ssm.append(nc)
        kv_site = jax.tree.map(lambda a: a[g], cache["kv"])
        h, nkv = _shared_block(cfg, params["shared"], h, emb0, positions,
                               kv_site, pos)
        new_kv.append(nkv)
    h = norms.apply_norm(cfg.norm, params["final_norm"], h, eps=cfg.norm_eps)
    logits = emb_lib.lm_head(cfg, params["embed"], h)[:, 0]
    cache_out = {
        "ssm": jax.tree.map(lambda *xs: jnp.concatenate(list(xs)), *new_ssm)
        if len(new_ssm) > 1 else new_ssm[0],
        "kv": jax.tree.map(lambda *xs: jnp.stack(list(xs)), *new_kv),
    }
    return logits, cache_out
