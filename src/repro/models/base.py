"""Model/config substrate shared by every architecture.

Key abstraction: each model declares its parameters *abstractly* as a
pytree of `ParamInfo(shape, dtype, logical, init)`. From that single
declaration we derive:
  * `init_params`   — materialized arrays (per-leaf folded RNG),
  * `abstract_state`— ShapeDtypeStructs for allocation-free dry-runs,
  * sharding specs  — via the logical axis names and the active mesh rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import sharding as shd


# ---------------------------------------------------------------------------
# Architecture / shape configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid
    modality: str = "text"      # text | vlm | audio
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0
    act: str = "swiglu"         # swiglu | geglu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    qkv_bias: bool = False
    tie_embeddings: bool = False
    scale_embedding: bool = False   # gemma: h *= sqrt(d_model)
    pos: str = "rope"           # rope | mrope | sin
    rope_theta: float = 1e6
    mrope_sections: tuple = ()  # (t, h, w) half-dims, sum == head_dim // 2
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_norm_topk: bool = True
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    # hybrid (zamba2): one shared attention+MLP block applied every k layers
    attn_every: int = 0
    param_dtype: str = "float32"    # master params (optimizer works in fp32)
    compute_dtype: str = "bfloat16"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        # channels passed through the causal conv: x, B, C
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    accum: int = 1               # gradient-accumulation microbatch steps


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train", accum=8),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k needs a sub-quadratic sequence path: SSM/hybrid only
    (DESIGN.md §6). Everything else runs everywhere (all archs are
    decoder-style; none are encoder-only)."""
    if shape.name == "long_500k":
        return cfg.family in ("ssm", "hybrid")
    return True


# ---------------------------------------------------------------------------
# Abstract parameter declaration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: tuple
    dtype: Any = jnp.float32
    logical: tuple = ()          # logical sharding per dim (None = replicated)
    init: str = "normal"         # normal | zeros | ones | uniform | custom
    scale: float = 1.0           # stddev multiplier for normal init
    fan: int = 0                 # index of the fan-in dim (1 for stacked (L, in, out))

    def sds(self) -> jax.ShapeDtypeStruct:
        sh = shd.named_sharding(self.shape, self.logical)
        return jax.ShapeDtypeStruct(self.shape, self.dtype, sharding=sh)


def is_info(x) -> bool:
    return isinstance(x, ParamInfo)


def tree_sds(tree):
    """Abstract tree -> ShapeDtypeStruct tree (with shardings if mesh active)."""
    return jax.tree.map(lambda i: i.sds(), tree, is_leaf=is_info)


def tree_specs(tree):
    """Abstract tree -> PartitionSpec tree under the active rules."""
    return jax.tree.map(
        lambda i: shd.spec(i.shape, i.logical), tree, is_leaf=is_info
    )


def tree_init(tree, key: jax.Array):
    """Materialize an abstract tree. Each leaf gets a path-folded key so the
    result is independent of traversal order and stable across refactors."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_info)

    def mk(path, info: ParamInfo, k):
        if info.init == "zeros":
            return jnp.zeros(info.shape, info.dtype)
        if info.init == "ones":
            return jnp.ones(info.shape, info.dtype)
        if info.init == "normal":
            fan_in = info.shape[info.fan] if info.shape else 1
            std = info.scale / np.sqrt(max(fan_in, 1))
            return (jax.random.normal(k, info.shape) * std).astype(info.dtype)
        if info.init == "uniform":
            return jax.random.uniform(
                k, info.shape, info.dtype, -info.scale, info.scale)
        raise ValueError(info.init)

    out = []
    for i, (path, info) in enumerate(leaves):
        kp = jax.random.fold_in(key, _path_hash(path))
        out.append(mk(path, info, kp))
    return jax.tree.unflatten(treedef, out)


def _path_hash(path) -> int:
    s = jax.tree_util.keystr(path)
    return int(np.uint32(hash(s) & 0xFFFFFFFF))


def count_params(tree) -> int:
    return sum(int(np.prod(i.shape)) for i in jax.tree.leaves(tree, is_leaf=is_info))
