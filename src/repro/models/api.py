"""Unified model API: family dispatch + losses.

Every architecture exposes the same five entry points:
  abstract_params(cfg)                  -> ParamInfo tree
  abstract_cache(cfg, batch, max_len)   -> ParamInfo tree (decode state)
  forward(cfg, params, batch)           -> (logits, aux)
  prefill(cfg, params, batch, cache)    -> (last_logits, cache)
  decode_step(cfg, params, tok, pos, c) -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import mamba, transformer, zamba
from repro.models.base import ArchConfig

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "ssm": mamba,
    "hybrid": zamba,
}

LB_WEIGHT = 0.01
Z_WEIGHT = 1e-3


def module_for(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def abstract_params(cfg: ArchConfig):
    return module_for(cfg).abstract_params(cfg)


def abstract_cache(cfg: ArchConfig, batch: int, max_len: int):
    return module_for(cfg).abstract_cache(cfg, batch, max_len)


def forward(cfg: ArchConfig, params, batch, *, remat: str = "none"):
    return module_for(cfg).forward(cfg, params, batch, remat=remat)


def prefill(cfg: ArchConfig, params, batch, cache, *, remat: str = "none"):
    return module_for(cfg).prefill(cfg, params, batch, cache, remat=remat)


def decode_step(cfg: ArchConfig, params, tokens, pos, cache, extras=None):
    return module_for(cfg).decode_step(cfg, params, tokens, pos, cache, extras)


def loss_fn(cfg: ArchConfig, params, batch, *, remat: str = "none"):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux = forward(cfg, params, batch, remat=remat)
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    mask = mask.astype(jnp.float32)

    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)                         # (B, S)
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    metrics = {"nll": loss}
    if aux:
        loss = loss + LB_WEIGHT * aux["lb_loss"] + Z_WEIGHT * aux["z_loss"]
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics
