"""Pallas TPU kernel: multiplication-free binary-activation matmul.

TPU adaptation of the paper's L5 "selected addends" rewrite: with
activations x in {0,1}, a dense layer is a *masked column sum*

    y[b, :] = sum_{k : x[b,k] == 1} w[k, :]

i.e. adds only — the select/accumulate runs on the VPU; no multiplier
(MXU) is engaged, mirroring the paper's removal of multiplier logic.

Three datapaths, in increasing bit-economy:
  * int8 activations (B, K)           — `binary_matmul_kernel`
  * bitpacked uint32 (B, K//32)       — `binary_matmul_packed_kernel`
    (32 activations per word: 8x less HBM->VMEM traffic than int8; the
    TPU analogue of the paper's single-bit wires — but the weights
    still travel as full int32 and the words are unpacked in-register
    back to a (bm, bk, bn) select)
  * fully bit-packed                  — `binary_matmul_planes_kernel`
    BOTH operands travel as bits: the int32 weight matrix is decomposed
    into signed bit-planes w = sum_b 2^b (pos_b - neg_b), each plane
    packed 32-lanes-per-uint32 along fan_in, and each output tile is

        y = sum_b 2^b (popcount(x & pos_b) - popcount(x & neg_b))

    — the XNOR/AND+popcount form of the BNN-on-FPGA line of work
    (Ertörer & Ünsalan). No in-register unpack: the inner reduction is
    over uint32 *words* (32x fewer elements than the packed kernel's
    bit-level select), and a P-plane layer moves 2P bits of weight per
    addend instead of 32.

Tiling: grid (B/bm, N/bn, K/bk) with the K axis innermost (sequential on
TPU), accumulating into the output block, which stays resident in VMEM
across the K sweep (revisited blocks are not re-fetched). Block sizes
are keyword knobs on every entry point so `repro.netgen.tune` can
search them per workload instead of trusting the defaults.

A fourth datapath, `binary_forward_planes`, fuses an ENTIRE planes-form
network — every layer's bit-plane weights resident in VMEM at once —
into one persistent launch: binarize+pack on entry, per-layer popcount
accumulate, strict step + repack *in-register* between layers (the
inter-layer activations never touch HBM), argmax fused at the end. The
grid runs over batch tiles only (and a leading model axis when the
input is a stacked (M, B, K) block), so Pallas's grid pipeline
double-buffers the input DMA while weights stay put.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------------------------
# int8-activation kernel
# --------------------------------------------------------------------------

def _binary_matmul_kernel(x_ref, w_ref, o_ref):
    """x: (bm, bk) int8 {0,1}; w: (bk, bn) int32; o: (bm, bn) int32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...]
    # Masked accumulate: select rows of w where the activation bit is set,
    # then reduce over k inside the tile. (bm, bk, bn) never materializes in
    # HBM — it is a VPU select feeding an add-reduce within VMEM.
    sel = jnp.where(x[:, :, None] != 0, w[None, :, :], 0)
    o_ref[...] += jnp.sum(sel, axis=1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def binary_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = x @ w with x in {0,1}. Pads to tile multiples; returns int32 (B, N)."""
    B, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(bm, _rup(B)), min(bn, _rup(N)), min(bk, _rup(K))
    Bp, Np, Kp = _pad_to(B, bm), _pad_to(N, bn), _pad_to(K, bk)
    xp = jnp.zeros((Bp, Kp), jnp.int8).at[:B, :K].set(x.astype(jnp.int8))
    wp = jnp.zeros((Kp, Np), jnp.int32).at[:K, :N].set(w.astype(jnp.int32))

    out = pl.pallas_call(
        _binary_matmul_kernel,
        grid=(Bp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.int32),
        interpret=interpret,
    )(xp, wp)
    return out[:B, :N]


# --------------------------------------------------------------------------
# bitpacked kernel: 32 activations per uint32 word
# --------------------------------------------------------------------------

def _binary_matmul_packed_kernel(xp_ref, w_ref, o_ref, *, bkw: int):
    """xp: (bm, bkw) uint32; w: (bkw*32, bn) int32; o: (bm, bn) int32."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xp = xp_ref[...]                       # (bm, bkw)
    w = w_ref[...]                         # (bkw*32, bn)
    bm = xp.shape[0]
    bn = w.shape[1]
    # Unpack 32 bits per word in-register, then masked-accumulate.
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (xp[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    bits = bits.reshape(bm, bkw * 32)      # (bm, bk) in {0,1}
    sel = jnp.where(bits[:, :, None] != 0, w[None, :, :], 0)
    o_ref[...] += jnp.sum(sel, axis=1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bkw", "interpret"))
def binary_matmul_packed(
    xp: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bkw: int = 8,          # K-tile in 32-bit words -> bk = 256 bits
    interpret: bool = True,
) -> jnp.ndarray:
    """y = unpack(xp) @ w. xp: uint32 (B, K//32); w: (K, N) int32."""
    B, KW = xp.shape
    K, N = w.shape
    assert KW * 32 == K, (xp.shape, w.shape)
    bm = min(bm, _rup(B))
    bn = min(bn, _rup(N))
    bkw = min(bkw, KW)
    Bp, Np, KWp = _pad_to(B, bm), _pad_to(N, bn), _pad_to(KW, bkw)
    xpp = jnp.zeros((Bp, KWp), jnp.uint32).at[:B, :KW].set(xp)
    wp = jnp.zeros((KWp * 32, Np), jnp.int32).at[:K, :N].set(w.astype(jnp.int32))

    out = pl.pallas_call(
        functools.partial(_binary_matmul_packed_kernel, bkw=bkw),
        grid=(Bp // bm, Np // bn, KWp // bkw),
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bkw * 32, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.int32),
        interpret=interpret,
    )(xpp, wp)
    return out[:B, :N]


# --------------------------------------------------------------------------
# bit-plane kernel: both operands packed, popcount accumulation
# --------------------------------------------------------------------------

def _binary_matmul_planes_kernel(xp_ref, pos_ref, neg_ref, o_ref, *,
                                 planes: int):
    """xp: (bm, bkw) uint32; pos/neg: (P, bkw, bn) uint32 bit-planes;
    o: (bm, bn) int32. Accumulates sum_b 2^b (popcount(x & pos_b) -
    popcount(x & neg_b)) over the word tile — the inner loop runs on
    words, never unpacking activations or weights to individual bits."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = xp_ref[...]                        # (bm, bkw) uint32
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    for b in range(planes):                # static unroll: P is tiny
        pos = pos_ref[b]                   # (bkw, bn) uint32
        neg = neg_ref[b]
        cp = jax.lax.population_count(x[:, :, None] & pos[None, :, :])
        cn = jax.lax.population_count(x[:, :, None] & neg[None, :, :])
        d = jnp.sum(cp.astype(jnp.int32) - cn.astype(jnp.int32), axis=1)
        acc = acc + (d << b)
    o_ref[...] += acc


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bkw", "interpret"))
def binary_matmul_planes(
    xp: jnp.ndarray,
    pos: jnp.ndarray,
    neg: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bkw: int = 8,          # K-tile in 32-bit words -> bk = 256 bits
    interpret: bool = True,
) -> jnp.ndarray:
    """y = unpack(xp) @ w for w = sum_b 2^b (unpack(pos_b) - unpack(neg_b)).

    xp: uint32 (B, KW); pos/neg: uint32 (P, KW, N) packed bit-planes
    (see `repro.netgen.plan.decompose_planes`). Returns int32 (B, N).
    Zero-padding any operand to tile multiples is exact: a zero word
    contributes zero popcount.
    """
    B, KW = xp.shape
    P, KW2, N = pos.shape
    assert KW == KW2 and pos.shape == neg.shape, (
        xp.shape, pos.shape, neg.shape)
    bm = min(bm, _rup(B))
    bn = min(bn, _rup(N))
    bkw = min(bkw, max(KW, 1))
    Bp, Np, KWp = _pad_to(B, bm), _pad_to(N, bn), _pad_to(KW, bkw)
    xpp = jnp.zeros((Bp, KWp), jnp.uint32).at[:B, :KW].set(xp)
    posp = jnp.zeros((P, KWp, Np), jnp.uint32).at[:, :KW, :N].set(pos)
    negp = jnp.zeros((P, KWp, Np), jnp.uint32).at[:, :KW, :N].set(neg)

    out = pl.pallas_call(
        functools.partial(_binary_matmul_planes_kernel, planes=P),
        grid=(Bp // bm, Np // bn, KWp // bkw),
        in_specs=[
            pl.BlockSpec((bm, bkw), lambda i, j, k: (i, k)),
            pl.BlockSpec((P, bkw, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((P, bkw, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Np), jnp.int32),
        interpret=interpret,
    )(xpp, posp, negp)
    return out[:B, :N]


# --------------------------------------------------------------------------
# whole-net megakernel: every layer fused into one persistent launch
# --------------------------------------------------------------------------

def _pack_bits_block(bits: jnp.ndarray, words: int) -> jnp.ndarray:
    """In-register repack: bool (bm, n) -> uint32 words (bm, words),
    zero-padding n up to words*32 (strict step: padding bits are 0)."""
    bm, n = bits.shape
    total = words * 32
    if n < total:
        bits = jnp.concatenate(
            [bits, jnp.zeros((bm, total - n), bits.dtype)], axis=1)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    b32 = bits.reshape(bm, words, 32).astype(jnp.uint32)
    return jnp.sum(b32 << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def _forward_planes_kernel(x_ref, *refs, threshold: int, layers, n_classes: int,
                           bkw, stacked: bool):
    """One batch tile through the whole net. x: (bm, K) raw uint8 (leading
    model axis of size 1 when stacked); per layer l, refs hold pos_l then
    neg_l uint32 (P_l, W_l, N_l) bit-planes, fully resident; o: (bm,) int32
    predicted class. Activations live in registers/VMEM for the whole
    sweep — the only HBM traffic per grid step is the input tile and the
    (bm,) prediction vector."""
    o_ref = refs[-1]
    plane_refs = refs[:-1]
    x = x_ref[...]
    if stacked:
        x = x[0]
    a = _pack_bits_block(x.astype(jnp.int32) > threshold, layers[0][1])
    acc = None
    for li, (P, W, N, out_words) in enumerate(layers):
        pos = plane_refs[2 * li][...]
        neg = plane_refs[2 * li + 1][...]
        if stacked:
            pos, neg = pos[0], neg[0]
        acc = jnp.zeros((a.shape[0], N), jnp.int32)
        ck = min(bkw, W) if bkw else W
        for c in range(0, W, ck):       # static lane tiling over words
            xw = a[:, c:c + ck]
            pw = pos[:, c:c + ck]
            nw = neg[:, c:c + ck]
            for b in range(P):          # static unroll: P is tiny
                cp = jax.lax.population_count(xw[:, :, None] & pw[b][None])
                cn = jax.lax.population_count(xw[:, :, None] & nw[b][None])
                d = jnp.sum(cp.astype(jnp.int32) - cn.astype(jnp.int32),
                            axis=1)
                acc = acc + (d << b)
        if out_words is not None:       # strict step + repack, in-register
            a = _pack_bits_block(acc > 0, out_words)
    # Slice to the real class count before argmax: a zero-padded class
    # column must never win when every real score is negative.
    out = jnp.argmax(acc[:, :n_classes], axis=-1).astype(jnp.int32)
    o_ref[...] = out[None, :] if stacked else out


@functools.partial(
    jax.jit, static_argnames=("threshold", "n_classes", "bm", "bkw",
                              "interpret"))
def binary_forward_planes(
    x: jnp.ndarray,
    *planes: jnp.ndarray,
    threshold: int,
    n_classes: int,
    bm: int = 32,
    bkw: int | None = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Whole-net forward in ONE pallas_call: raw uint8 images -> class ids.

    x: uint8 (B, K), or (M, B, K) for a stacked M-model plan. `planes`
    interleaves pos_0, neg_0, pos_1, neg_1, ... — uint32
    (P_l, W_l, N_l) packed bit-planes per layer ((M, P_l, W_l, N_l)
    when stacked), as produced by `ExecutionPlan.megakernel_view()`:
    each hidden fan_out is pre-padded so N_l == W_{l+1} * 32 and the
    in-kernel repack needs no bit shuffling. Returns int32 (B,) /
    (M, B).

    Grid is (B/bm,) (stacked: (M, B/bm), batch innermost so one model's
    weights stay resident across its batch sweep); the grid pipeline
    double-buffers the input-tile DMA against compute. `bkw` chunks the
    word axis of each popcount (bounding the (bm, ck, N) intermediate);
    None means whole-width.
    """
    assert planes and len(planes) % 2 == 0, len(planes)
    stacked = x.ndim == 3
    if stacked:
        M, B, K = x.shape
    else:
        B, K = x.shape
    pairs = list(zip(planes[0::2], planes[1::2]))
    layers = []
    for li, (pos, neg) in enumerate(pairs):
        assert pos.shape == neg.shape, (li, pos.shape, neg.shape)
        assert pos.ndim == (4 if stacked else 3), (li, pos.shape)
        P, W, N = pos.shape[-3:]
        if li + 1 < len(pairs):
            out_words = pairs[li + 1][0].shape[-2]
            assert N == out_words * 32, (li, N, out_words)
        else:
            out_words = None
            assert 1 <= n_classes <= N, (n_classes, N)
        layers.append((P, W, N, out_words))
    assert layers[0][1] * 32 >= K, (layers[0], K)
    bm = min(bm, _rup(B))
    Bp = _pad_to(B, bm)
    kern = functools.partial(
        _forward_planes_kernel, threshold=threshold, layers=tuple(layers),
        n_classes=n_classes, bkw=bkw, stacked=stacked)
    if stacked:
        xp = jnp.zeros((M, Bp, K), jnp.uint8).at[:, :B].set(
            x.astype(jnp.uint8))
        in_specs = [pl.BlockSpec((1, bm, K), lambda m, i: (m, i, 0))]
        for P, W, N, _ in layers:
            spec = pl.BlockSpec((1, P, W, N), lambda m, i: (m, 0, 0, 0))
            in_specs += [spec, spec]
        out = pl.pallas_call(
            kern,
            grid=(M, Bp // bm),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, bm), lambda m, i: (m, i)),
            out_shape=jax.ShapeDtypeStruct((M, Bp), jnp.int32),
            interpret=interpret,
        )(xp, *planes)
        return out[:, :B]
    xp = jnp.zeros((Bp, K), jnp.uint8).at[:B].set(x.astype(jnp.uint8))
    in_specs = [pl.BlockSpec((bm, K), lambda i: (i, 0))]
    for P, W, N, _ in layers:
        spec = pl.BlockSpec((P, W, N), lambda i: (0, 0, 0))
        in_specs += [spec, spec]
    out = pl.pallas_call(
        kern,
        grid=(Bp // bm,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.int32),
        interpret=interpret,
    )(xp, *planes)
    return out[:B]


def _rup(x: int, m: int = 8) -> int:
    """Round up to a small hardware-friendly multiple for tiny dims."""
    return max(m, ((x + m - 1) // m) * m)


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
