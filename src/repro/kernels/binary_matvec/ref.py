"""Pure-jnp oracle for the multiplication-free binary matmul.

Semantics: y[b, n] = sum_k x[b, k] * w[k, n] with x in {0, 1}.
The oracle is written as the masked column-sum (adds only) to document the
arithmetic identity the kernel exploits; numerically it equals the matmul.
"""
from __future__ import annotations

import jax.numpy as jnp


def binary_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, K) in {0,1} any int dtype; w: (K, N) int32. Returns int32 (B, N)."""
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    return x @ w


def binary_matmul_masked_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Adds-only form: y = sum of rows of w where the input bit is set."""
    mask = (x != 0)
    return jnp.sum(jnp.where(mask[:, :, None], w[None].astype(jnp.int32), 0), axis=1)


def pack_bits_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Pack binary (B, K) with K % 32 == 0 into uint32 (B, K // 32).
    Bit i of word j holds x[:, 32*j + i] (little-endian within the word)."""
    b, k = x.shape
    assert k % 32 == 0, k
    xr = (x != 0).astype(jnp.uint32).reshape(b, k // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(xr << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_bits_ref(xp: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of pack_bits_ref -> int8 (B, K)."""
    b, kw = xp.shape
    assert kw * 32 >= k
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (xp[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(b, kw * 32)[:, :k].astype(jnp.int8)


def pack_bool_ref(bits: jnp.ndarray, words: int) -> jnp.ndarray:
    """Pack a boolean (B, N) into uint32 (B, words), zero-padding N up
    to words*32 — the shared packer behind `step_pack_ref` and the
    input binarizer, so activations become words without ever taking
    an int8 form."""
    b, n = bits.shape
    kp = words * 32
    assert kp >= n, (n, words)
    if kp != n:
        bits = jnp.zeros((b, kp), bool).at[:, :n].set(bits)
    xr = bits.astype(jnp.uint32).reshape(b, words, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(xr << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def step_pack_ref(acc: jnp.ndarray, words: int) -> jnp.ndarray:
    """Fused strict step + repack: int32 accumulators (B, N) -> packed
    uint32 activation words (B, words) with bit i of word j = acc[:,
    32*j+i] > 0. The packed/bit-plane layer chains go through this
    between layers, so hidden activations never materialize as int8."""
    return pack_bool_ref(acc > 0, words)


def plane_matmul_ref(xp: jnp.ndarray, pos: jnp.ndarray,
                     neg: jnp.ndarray) -> jnp.ndarray:
    """jnp oracle for the bit-plane kernel: popcount-free reconstruction
    by unpacking both operands and running the integer matmul — the
    arithmetic identity the kernel must reproduce exactly."""
    b, kw = xp.shape
    p, kw2, n = pos.shape
    assert kw == kw2 and pos.shape == neg.shape
    x = unpack_bits_ref(xp, kw * 32).astype(jnp.int32)       # (B, K)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    w = jnp.zeros((kw * 32, n), jnp.int32)
    for b_i in range(p):
        pb = ((pos[b_i][:, None, :] >> shifts[None, :, None])
              & jnp.uint32(1)).reshape(kw * 32, n).astype(jnp.int32)
        nb = ((neg[b_i][:, None, :] >> shifts[None, :, None])
              & jnp.uint32(1)).reshape(kw * 32, n).astype(jnp.int32)
        w = w + ((pb - nb) << b_i)
    return x @ w
