"""Pure-jnp oracle for the multiplication-free binary matmul.

Semantics: y[b, n] = sum_k x[b, k] * w[k, n] with x in {0, 1}.
The oracle is written as the masked column-sum (adds only) to document the
arithmetic identity the kernel exploits; numerically it equals the matmul.
"""
from __future__ import annotations

import jax.numpy as jnp


def binary_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (B, K) in {0,1} any int dtype; w: (K, N) int32. Returns int32 (B, N)."""
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    return x @ w


def binary_matmul_masked_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Adds-only form: y = sum of rows of w where the input bit is set."""
    mask = (x != 0)
    return jnp.sum(jnp.where(mask[:, :, None], w[None].astype(jnp.int32), 0), axis=1)


def pack_bits_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Pack binary (B, K) with K % 32 == 0 into uint32 (B, K // 32).
    Bit i of word j holds x[:, 32*j + i] (little-endian within the word)."""
    b, k = x.shape
    assert k % 32 == 0, k
    xr = (x != 0).astype(jnp.uint32).reshape(b, k // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(xr << shifts[None, None, :], axis=-1, dtype=jnp.uint32)


def unpack_bits_ref(xp: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of pack_bits_ref -> int8 (B, K)."""
    b, kw = xp.shape
    assert kw * 32 >= k
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (xp[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(b, kw * 32)[:, :k].astype(jnp.int8)
