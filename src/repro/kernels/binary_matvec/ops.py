"""Public ops for the binary (multiplication-free) matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.binary_matvec import binary_matvec as _k
from repro.kernels.binary_matvec import ref as _ref

# Default to interpret mode (this container is CPU-only); on a real TPU
# deployment, set interpret=False via these wrappers.
_INTERPRET = True


def binary_matmul(x: jnp.ndarray, w: jnp.ndarray, **kw) -> jnp.ndarray:
    """y = x @ w, x in {0,1} (int8), w int — adds-only Pallas kernel."""
    kw.setdefault("interpret", _INTERPRET)
    return _k.binary_matmul(x, w, **kw)


def binary_matmul_packed(xp: jnp.ndarray, w: jnp.ndarray, **kw) -> jnp.ndarray:
    """y = unpack(xp) @ w for bitpacked activations (uint32 words)."""
    kw.setdefault("interpret", _INTERPRET)
    return _k.binary_matmul_packed(xp, w, **kw)


def binary_matmul_planes(xp: jnp.ndarray, pos: jnp.ndarray,
                         neg: jnp.ndarray, **kw) -> jnp.ndarray:
    """y = unpack(xp) @ w for w decomposed into packed signed bit-planes
    (pos/neg uint32 (P, KW, N)) — the fully bit-packed popcount kernel."""
    kw.setdefault("interpret", _INTERPRET)
    return _k.binary_matmul_planes(xp, pos, neg, **kw)


def binary_forward_planes(x: jnp.ndarray, *planes: jnp.ndarray,
                          **kw) -> jnp.ndarray:
    """Whole-net megakernel: raw uint8 (B, K) / (M, B, K) through every
    layer's resident bit-planes in ONE Pallas launch (binarize+pack,
    popcount accumulate, in-register step+repack, fused argmax). Plane
    arrays come from `ExecutionPlan.megakernel_view()`."""
    kw.setdefault("interpret", _INTERPRET)
    return _k.binary_forward_planes(x, *planes, **kw)


def pack_bits(x: jnp.ndarray) -> jnp.ndarray:
    """Pack binary activations 32-per-uint32 (pads K up to a /32 multiple)."""
    b, k = x.shape
    kp = ((k + 31) // 32) * 32
    if kp != k:
        x = jnp.zeros((b, kp), x.dtype).at[:, :k].set(x)
    return _ref.pack_bits_ref(x)


def step_pack(acc: jnp.ndarray, *, words: int) -> jnp.ndarray:
    """Fused strict step + repack: int32 accumulators (B, N) -> uint32
    activation words (B, words). The layer-to-layer hop of the packed
    and bit-plane datapaths: no int8 activation ever materializes."""
    return _ref.step_pack_ref(acc, words)


def binarize_pack(x_uint8: jnp.ndarray, *, threshold: int,
                  words: int) -> jnp.ndarray:
    """Binarize raw uint8 inputs against `threshold` straight into packed
    uint32 words (B, words) — the packed chains' entry point."""
    return _ref.pack_bool_ref(x_uint8.astype(jnp.int32) > threshold, words)
