"""Public op for the fused whole-network MLP kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_mlp import fused_mlp as _k

_INTERPRET = True


def fused_mlp_predict(
    x_uint8: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, *, threshold: int = 128, **kw
) -> jnp.ndarray:
    kw.setdefault("interpret", _INTERPRET)
    return _k.fused_mlp_predict(x_uint8, w1, w2, threshold=threshold, **kw)
