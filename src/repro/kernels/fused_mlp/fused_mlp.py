"""Pallas TPU kernel: the whole paper network in ONE kernel launch.

The paper's FPGA artifact is a clockless combinational circuit: the entire
784-500-10 network evaluates with no intermediate storage, latency equal to
gate propagation delay. The TPU analogue is whole-network fusion: a single
`pallas_call` whose grid tiles only the batch; both weight matrices are
pinned in VMEM, and the binarize -> layer1 -> step -> layer2 -> argmax
chain executes without any HBM round-trip for intermediates.

VMEM budget (paper-sized net): w1 784x512 int32 = 1.6 MB, w2 512x16 int32
= 32 KB, one batch tile 256x784 int8 = 0.2 MB — comfortably inside the
~16 MB VMEM of a TPU core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_mlp_kernel(x_ref, w1_ref, w2_ref, o_ref, *, threshold: int):
    x = (x_ref[...].astype(jnp.int32) > threshold).astype(jnp.int32)  # (bm, K)
    w1 = w1_ref[...]                                                  # (K, H)
    w2 = w2_ref[...]                                                  # (H, O)
    hi = jax.lax.dot(x, w1, preferred_element_type=jnp.int32)
    ho = (hi > 0).astype(jnp.int32)                                   # MSB step
    fi = jax.lax.dot(ho, w2, preferred_element_type=jnp.int32)
    o_ref[...] = jnp.argmax(fi, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("threshold", "bm", "interpret"))
def fused_mlp_predict(
    x_uint8: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    *,
    threshold: int = 128,
    bm: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Predictions for a batch, whole net in one launch. Returns int32 (B,)."""
    B, K = x_uint8.shape
    K2, H = w1.shape
    H2, O = w2.shape
    assert K == K2 and H == H2, (x_uint8.shape, w1.shape, w2.shape)
    bm = min(bm, max(8, B))
    Bp = ((B + bm - 1) // bm) * bm
    xp = jnp.zeros((Bp, K), jnp.uint8).at[:B].set(x_uint8.astype(jnp.uint8))

    out = pl.pallas_call(
        functools.partial(_fused_mlp_kernel, threshold=threshold),
        grid=(Bp // bm,),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((K, H), lambda i: (0, 0)),   # whole w1 resident
            pl.BlockSpec((H, O), lambda i: (0, 0)),   # whole w2 resident
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.int32),
        interpret=interpret,
    )(xp, w1.astype(jnp.int32), w2.astype(jnp.int32))
    return out[:B]
