"""Pure-jnp oracle for the fused whole-network MLP kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_mlp_predict_ref(
    x_uint8: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, *, threshold: int = 128
) -> jnp.ndarray:
    """Whole paper network: binarize -> int matmul -> step -> int matmul ->
    argmax. x: (B, n_in) uint8; w1: (n_in, H) int32; w2: (H, n_out) int32.
    Returns int32 predictions (B,)."""
    x = (x_uint8.astype(jnp.int32) > threshold).astype(jnp.int32)
    hi = x @ w1.astype(jnp.int32)
    ho = (hi > 0).astype(jnp.int32)
    fi = ho @ w2.astype(jnp.int32)
    return jnp.argmax(fi, axis=-1).astype(jnp.int32)
