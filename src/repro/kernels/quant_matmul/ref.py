"""Pure-jnp oracle for the W8A8 quantized matmul.

Semantics: y = (x_q @ w_q) * sx * sw[None, :]
  x_q int8 (M, K), per-tensor activation scale sx (scalar fp32)
  w_q int8 (K, N), per-output-channel scale sw (N,) fp32
Accumulation in int32 (exact), dequant in fp32.
"""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(
    x_q: jnp.ndarray, w_q: jnp.ndarray, sx: jnp.ndarray, sw: jnp.ndarray
) -> jnp.ndarray:
    acc = jnp.dot(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * sx * sw[None, :]


def quantize_act_ref(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization of activations."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    s = amax / 127.0
    q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quantize_weight_ref(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-output-channel int8 quantization of weights (K, N)."""
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)   # (N,)
    s = amax / 127.0
    q = jnp.clip(jnp.round(w / s[None, :]), -127, 127).astype(jnp.int8)
    return q, s.astype(jnp.float32)
