"""Public ops for the W8A8 quantized matmul kernel.

`qlinear` is the end-to-end op used by `repro.quantized`: quantize the
activation on the fly (per-tensor symmetric), run the int8 kernel against
pre-quantized weights, dequantize in the fused epilogue.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.quant_matmul import quant_matmul as _k
from repro.kernels.quant_matmul import ref as _ref

_INTERPRET = True


def quant_matmul(x_q, w_q, sx, sw, **kw) -> jnp.ndarray:
    kw.setdefault("interpret", _INTERPRET)
    return _k.quant_matmul(x_q, w_q, sx, sw, **kw)


def quantize_act(x: jnp.ndarray):
    return _ref.quantize_act_ref(x)


def quantize_weight(w: jnp.ndarray):
    return _ref.quantize_weight_ref(w)


def qlinear(x: jnp.ndarray, w_q: jnp.ndarray, sw: jnp.ndarray, **kw) -> jnp.ndarray:
    """fp activation in, fp out; weights already int8 + per-channel scales."""
    x_q, sx = _ref.quantize_act_ref(x)
    y = quant_matmul(x_q, w_q, sx, sw, **kw)
    return y.astype(x.dtype)
