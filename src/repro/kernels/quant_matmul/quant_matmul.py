"""Pallas TPU kernel: W8A8 integer matmul with fused dequant epilogue.

Generalization of the paper's L3 (integer-weight) optimization to the TPU:
the MXU executes int8 x int8 -> int32 at up to 2x the bf16 rate on real
TPUs, and int8 weights halve HBM traffic vs bf16 — the same two wins
(cheaper arithmetic, smaller storage) the paper buys on the FPGA.

Tiling: grid (M/bm, N/bn, K/bk), K innermost (sequential); int32
accumulator lives in a VMEM scratch block across the K sweep; the fp32
dequant (per-tensor activation scale x per-channel weight scale) is fused
into the epilogue on the last K step, so the int32 accumulator never
touches HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        sx = sx_ref[0]
        sw = sw_ref[...]                       # (bn,)
        o_ref[...] = acc_ref[...].astype(jnp.float32) * sx * sw[None, :]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(
    x_q: jnp.ndarray,
    w_q: jnp.ndarray,
    sx: jnp.ndarray,
    sw: jnp.ndarray,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """y = (x_q @ w_q) * sx * sw. x_q int8 (M,K); w_q int8 (K,N); fp32 out."""
    M, K = x_q.shape
    K2, N = w_q.shape
    assert K == K2 and sw.shape == (N,), (x_q.shape, w_q.shape, sw.shape)
    bm, bn, bk = min(bm, _rup(M)), min(bn, _rup(N)), min(bk, _rup(K))
    Mp, Np, Kp = _pad(M, bm), _pad(N, bn), _pad(K, bk)
    xp = jnp.zeros((Mp, Kp), jnp.int8).at[:M, :K].set(x_q)
    wp = jnp.zeros((Kp, Np), jnp.int8).at[:K, :N].set(w_q)
    swp = jnp.zeros((Np,), jnp.float32).at[:N].set(sw)
    sx = jnp.asarray(sx, jnp.float32).reshape((1,))

    out = pl.pallas_call(
        _quant_matmul_kernel,
        grid=(Mp // bm, Np // bn, Kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1,), lambda i, j, k: (0,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(xp, wp, sx, swp)
    return out[:M, :N]


def _rup(x: int, m: int = 8) -> int:
    return max(m, ((x + m - 1) // m) * m)


def _pad(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
