"""Pallas TPU kernel: Mamba2 SSD chunked scan.

The SSD (state-space duality) decomposition splits the linear recurrence
into (i) a quadratic intra-chunk term — an MXU-friendly (Q x Q) masked
"attention" — and (ii) a tiny inter-chunk state recurrence carried in VMEM
scratch across sequential grid steps. This is the TPU-native shape of the
algorithm: the FLOP-dense part lands on the MXU with hardware-aligned
(Q, N, P) tiles, while the serial dependency is a (N, P) carry that never
leaves VMEM.

Grid: (B*H, L/Q) — the chunk axis is last, i.e. innermost/sequential on
TPU, so the scratch state persists across the chunk sweep of each (b, h)
program and is reset when a new (b, h) begins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_ref):
    """Blocks (leading grid dim squeezed):
      x (1, Q, P) | dt (1, Q) | a (1,) | b/c (1, Q, N)
      y (1, Q, P) | sfin (1, N, P) | scratch s (N, P) fp32
    """
    q = pl.program_id(1)
    nq = pl.num_programs(1)

    @pl.when(q == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    xq = x_ref[0].astype(jnp.float32)          # (Q, P)
    dtq = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0].astype(jnp.float32)           # scalar (decay rate < 0)
    bq = b_ref[0].astype(jnp.float32)          # (Q, N)
    cq = c_ref[0].astype(jnp.float32)          # (Q, N)

    da = dtq * a
    cum = jnp.cumsum(da)                       # (Q,)
    Q = dtq.shape[0]
    tri = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    lmat = jnp.where(tri, jnp.exp(cum[:, None] - cum[None, :]), 0.0)

    # intra-chunk quadratic term (two MXU matmuls)
    scores = jax.lax.dot(cq, bq.T, preferred_element_type=jnp.float32) * lmat
    y = jax.lax.dot(scores, xq * dtq[:, None], preferred_element_type=jnp.float32)

    # inter-chunk: carried state contribution
    s_prev = s_ref[...]
    y = y + jax.lax.dot(
        cq * jnp.exp(cum)[:, None], s_prev, preferred_element_type=jnp.float32
    )
    y_ref[0] = y.astype(y_ref.dtype)

    # state update for the next chunk
    decay_to_end = jnp.exp(cum[-1] - cum)
    s_new = jnp.exp(cum[-1]) * s_prev + jax.lax.dot(
        (bq * (dtq * decay_to_end)[:, None]).T, xq, preferred_element_type=jnp.float32
    )
    s_ref[...] = s_new

    @pl.when(q == nq - 1)
    def _emit_state():
        sfin_ref[0] = s_new.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jnp.ndarray,      # (BH, L, P)
    dt: jnp.ndarray,     # (BH, L)
    a: jnp.ndarray,      # (BH,)  per-(batch,head) decay rate (A broadcast)
    b: jnp.ndarray,      # (BH, L, N)
    c: jnp.ndarray,      # (BH, L, N)
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (BH, L, P), s_final (BH, N, P) fp32)."""
    BH, L, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nq = L // chunk

    y, sfin = pl.pallas_call(
        _ssd_kernel,
        grid=(BH, nq),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda i, q: (i, q, 0)),
            pl.BlockSpec((1, chunk), lambda i, q: (i, q)),
            pl.BlockSpec((1,), lambda i, q: (i,)),
            pl.BlockSpec((1, chunk, N), lambda i, q: (i, q, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, q: (i, q, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, P), lambda i, q: (i, q, 0)),
            pl.BlockSpec((1, N, P), lambda i, q: (i, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((BH, L, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, sfin
