"""Pure-jnp oracles for the Mamba2 SSD (state-space duality) scan.

Semantics (per batch b, head h; head dim P, state dim N):

    S_0 = S_init (or zeros)
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * B_t^T x_t        (N, P)
    y_t = C_t S_t                                              (P,)

with A_h < 0 (continuous-time decay), dt_t > 0, and B/C shared across the
heads of a group. Two oracles:

  * `ssd_sequential_ref` — the exact recurrence via lax.scan (ground truth)
  * `ssd_chunked_ref`    — the SSD chunked algorithm (quadratic intra-chunk
    "attention" + inter-chunk state recurrence), the algorithm the Pallas
    kernel implements; validates the chunk math against the recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_sequential_ref(x, dt, a, b, c, s_init=None):
    """x: (L, P); dt: (L,); a: scalar < 0; b, c: (L, N). Returns (y (L, P),
    s_final (N, P)). fp32 math."""
    x, dt, b, c = (t.astype(jnp.float32) for t in (x, dt, b, c))
    L, P = x.shape
    N = b.shape[-1]
    s0 = jnp.zeros((N, P), jnp.float32) if s_init is None else s_init.astype(jnp.float32)

    def step(s, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * a)
        s = decay * s + dtt * (bt[:, None] * xt[None, :])
        return s, ct @ s

    s_final, y = jax.lax.scan(step, s0, (x, dt, b, c))
    return y, s_final


def ssd_chunked_ref(x, dt, a, b, c, chunk: int = 64, s_init=None):
    """Chunked SSD, same signature/semantics as ssd_sequential_ref."""
    x, dt, b, c = (t.astype(jnp.float32) for t in (x, dt, b, c))
    L, P = x.shape
    N = b.shape[-1]
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk
    xc = x.reshape(nc, chunk, P)
    dtc = dt.reshape(nc, chunk)
    bc = b.reshape(nc, chunk, N)
    cc = c.reshape(nc, chunk, N)
    s0 = jnp.zeros((N, P), jnp.float32) if s_init is None else s_init.astype(jnp.float32)

    def per_chunk(s_prev, inp):
        xq, dtq, bq, cq = inp                     # (Q,P) (Q,) (Q,N) (Q,N)
        da = dtq * a                              # (Q,) <= 0
        cum = jnp.cumsum(da)                      # (Q,)
        # intra-chunk: masked decay matrix  Lmat[t,s] = exp(cum_t - cum_s), t>=s
        diff = cum[:, None] - cum[None, :]
        lmat = jnp.where(
            jnp.tril(jnp.ones((dtq.shape[0],) * 2, bool)), jnp.exp(diff), 0.0
        )
        scores = (cq @ bq.T) * lmat               # (Q, Q)
        y = scores @ (xq * dtq[:, None])          # (Q, P)
        # inter-chunk: contribution of the carried state
        y = y + (cq * jnp.exp(cum)[:, None]) @ s_prev
        # state update: decay to end of chunk
        decay_to_end = jnp.exp(cum[-1] - cum)     # (Q,)
        s_new = jnp.exp(cum[-1]) * s_prev + (
            (bq * (dtq * decay_to_end)[:, None]).T @ xq
        )                                          # (N, P)
        return s_new, y

    s_final, yc = jax.lax.scan(per_chunk, s0, (xc, dtc, bc, cc))
    return yc.reshape(L, P), s_final


def ssd_batched_ref(x, dt, a_per_head, b, c, chunk: int = 64, s_init=None):
    """Batched/multi-head oracle.
    x: (B, L, H, P); dt: (B, L, H); a: (H,); b, c: (B, L, G, N) with H % G == 0.
    Returns y (B, L, H, P), s_final (B, H, N, P)."""
    B, L, H, P = x.shape
    G = b.shape[2]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2)   # (B, L, H, N)
    ch = jnp.repeat(c, rep, axis=2)

    def one(bi, hi):
        s0 = None if s_init is None else s_init[bi, hi]
        return ssd_chunked_ref(
            x[bi, :, hi], dt[bi, :, hi], a_per_head[hi], bh[bi, :, hi], ch[bi, :, hi],
            chunk=chunk, s_init=s0,
        )

    ys, ss = [], []
    for bi in range(B):
        yb, sb = [], []
        for hi in range(H):
            y, s = one(bi, hi)
            yb.append(y)
            sb.append(s)
        ys.append(jnp.stack(yb, axis=1))       # (L, H, P)
        ss.append(jnp.stack(sb, axis=0))       # (H, N, P)
    return jnp.stack(ys), jnp.stack(ss)
