"""Public op for the Mamba2 SSD scan kernel.

`ssd` takes the model-layout tensors (batch, length, heads, ...) used by
`repro.layers.mamba2`, flattens (B, H) into the kernel's program axis,
broadcasts group-shared B/C to heads, and restores the layout after.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan import ssd_scan as _k

_INTERPRET = True


def ssd(
    x: jnp.ndarray,        # (B, L, H, P)
    dt: jnp.ndarray,       # (B, L, H)
    a_per_head: jnp.ndarray,  # (H,) negative decay rates
    b: jnp.ndarray,        # (B, L, G, N)
    c: jnp.ndarray,        # (B, L, G, N)
    *,
    chunk: int = 64,
    interpret: bool | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B, L, H, P), s_final (B, H, N, P))."""
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    interpret = _INTERPRET if interpret is None else interpret

    xf = x.transpose(0, 2, 1, 3).reshape(B * H, L, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, L)
    af = jnp.tile(a_per_head, (B,))
    bf = jnp.repeat(b, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, L, N)
    cf = jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3).reshape(B * H, L, N)

    y, s = _k.ssd_scan(xf, dtf, af, bf, cf, chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, L, P).transpose(0, 2, 1, 3)
    s = s.reshape(B, H, N, P)
    return y, s
