"""Weight-access helper supporting quantized (int8 + per-channel scale)
parameter leaves — the paper's integer-weight specialization threaded
through the LM serving path.

A parameter leaf is either a plain array or `{"q": int8, "s": fp32}`
(per-output-channel scales over the LAST dim). `wx(w, dtype)` returns the
compute-dtype weight either way; on the quantized path the int8 tensor is
what streams from HBM (half of bf16, quarter of fp32), and XLA fuses the
convert+scale into the consuming matmul on TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def is_q(w) -> bool:
    return isinstance(w, dict) and set(w.keys()) == {"q", "s"}


def wx(w, dtype) -> jnp.ndarray:
    """Materialize a weight in compute dtype (dequantizing if needed)."""
    if is_q(w):
        return (w["q"].astype(jnp.float32) * w["s"]).astype(dtype)
    return w.astype(dtype)
