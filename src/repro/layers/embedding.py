"""Token embedding, LM head, and input assembly for text/vlm/audio."""
from __future__ import annotations

import jax.numpy as jnp

from repro.layers import rotary
from repro.layers.common import is_q
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def embed_params(cfg: ArchConfig) -> dict:
    p = {
        "tok": ParamInfo((cfg.vocab, cfg.d_model), jnp.float32,
                         ("vocab", "fsdp"), scale=1.0),
    }
    if not cfg.tie_embeddings:
        p["head"] = ParamInfo((cfg.d_model, cfg.vocab), jnp.float32,
                              ("fsdp", "vocab"))
    return p


def embed(cfg: ArchConfig, p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B, S) -> (B, S, D) in compute dtype."""
    tok = p["tok"]
    if is_q(tok):
        rows = jnp.take(tok["q"], tokens, axis=0).astype(jnp.float32)
        h = (rows * tok["s"]).astype(cfg.cdtype())
    else:
        h = jnp.take(tok.astype(cfg.cdtype()), tokens, axis=0)
    if cfg.scale_embedding:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def lm_head(cfg: ArchConfig, p: dict, h: jnp.ndarray) -> jnp.ndarray:
    """h (B, S, D) -> logits (B, S, V) (vocab-sharded)."""
    w = p["tok"] if cfg.tie_embeddings else p["head"]
    if is_q(w):
        if cfg.tie_embeddings:
            # w = q * s with per-d_model scales: fold s into h, matmul int8^T
            logits = jnp.einsum("bsd,vd->bsv", h * w["s"].astype(h.dtype),
                                w["q"].astype(h.dtype))
        else:
            logits = jnp.einsum(
                "bsd,dv->bsv", h,
                (w["q"].astype(jnp.float32) * w["s"]).astype(h.dtype))
    else:
        wm = w.T if cfg.tie_embeddings else w
        logits = jnp.einsum("bsd,dv->bsv", h, wm.astype(h.dtype))
    return shard(logits, "batch", "seq", "vocab")


def assemble_inputs(cfg: ArchConfig, p: dict, batch: dict) -> jnp.ndarray:
    """Build the backbone input (B, S, D) per modality.

    text : embed(tokens)
    vlm  : embed(tokens) with image-position slots overwritten by the stub
           frontend's precomputed patch embeddings (`pixel_embeds`,
           `pixel_mask`), per spec ([vlm] = backbone only)
    audio: precomputed EnCodec frame embeddings from the stub frontend are
           added to the (coarse) token embedding, plus sinusoidal positions
    """
    if cfg.modality == "text":
        return embed(cfg, p, batch["tokens"])
    if cfg.modality == "vlm":
        h = embed(cfg, p, batch["tokens"])
        pe = batch["pixel_embeds"].astype(h.dtype)          # (B, S, D) stub
        mask = batch["pixel_mask"][:, :, None]              # (B, S, 1) bool
        return jnp.where(mask, pe, h)
    if cfg.modality == "audio":
        h = embed(cfg, p, batch["tokens"])
        h = h + batch["frame_embeds"].astype(h.dtype)       # stub frontend
        if cfg.pos == "sin":
            B, S = batch["tokens"].shape
            pos = batch.get("positions")
            if pos is None:
                pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            h = h + rotary.sinusoidal_embedding(pos, cfg.d_model).astype(h.dtype)
        return h
    raise ValueError(cfg.modality)
