"""Rotary position embeddings: standard RoPE, multimodal M-RoPE (Qwen2-VL),
and sinusoidal absolute embeddings (MusicGen-style)."""
from __future__ import annotations

import jax.numpy as jnp


def _freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply RoPE. x: (B, S, H, hd); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = _freqs(x.shape[-1], theta)                       # (half,)
    ang = positions[:, :, None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]                        # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
          sections: tuple) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE. positions: (3, B, S) — (temporal, h, w)
    indices; `sections` are half-dim section lengths summing to hd//2.
    Each frequency band takes its angle from the section's position id."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = _freqs(x.shape[-1], theta)                       # (half,)
    # Select, per frequency index, which of the 3 position streams drives it.
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )                                                        # (half,)
    pos = positions.astype(jnp.float32)                      # (3, B, S)
    ang = jnp.zeros(pos.shape[1:] + (half,), jnp.float32)    # (B, S, half)
    for k in range(len(sections)):
        ang_k = pos[k][:, :, None] * freqs[None, None, :]
        ang = jnp.where(sec_id[None, None, :] == k, ang_k, ang)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_embedding(positions: jnp.ndarray, d_model: int,
                         max_scale: float = 10_000.0) -> jnp.ndarray:
    """Absolute sinusoidal embeddings. positions: (B, S) -> (B, S, D)."""
    half = d_model // 2
    freqs = 1.0 / (max_scale ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, :, None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
