"""Mamba2 mixer (SSD — state-space duality), train and decode paths.

Block structure (arXiv:2405.21060):
  in_proj: d -> [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
  causal conv1d (width 4) over [x, B, C]; silu
  SSD scan over chunks (Pallas kernel / chunked jnp ref)
  gated RMSNorm: norm(y * silu(z)); out_proj: d_inner -> d

Decode keeps (conv_state (B, conv_dim, W-1), ssm_state (B, H, N, P)) and
advances the recurrence one token at a time — O(1) per token, which is why
the long_500k cell runs only for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import wx
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def mamba_params(cfg: ArchConfig, n_layers: int | None = None) -> dict:
    d = cfg.d_model
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = cfg.conv_dim
    proj_out = 2 * di + 2 * G * N + H
    L = () if n_layers is None else (n_layers,)
    nl = (None,) * len(L)
    fan = len(L)
    return {
        "in_proj": ParamInfo(L + (d, proj_out), jnp.float32, nl + ("fsdp", "ffn"), fan=fan),
        "conv_w": ParamInfo(L + (cfg.conv_width, conv_dim), jnp.float32,
                            nl + (None, "ffn"), scale=0.5, fan=fan),
        "conv_b": ParamInfo(L + (conv_dim,), jnp.float32, nl + ("ffn",), init="zeros"),
        # A stored as log(-A): a = -exp(a_log); dt bias for softplus
        "a_log": ParamInfo(L + (H,), jnp.float32, nl + (None,), init="zeros"),
        "dt_bias": ParamInfo(L + (H,), jnp.float32, nl + (None,), init="zeros"),
        "d_skip": ParamInfo(L + (H,), jnp.float32, nl + (None,), init="ones"),
        "norm_scale": ParamInfo(L + (di,), jnp.float32, nl + (None,), init="ones"),
        "out_proj": ParamInfo(L + (di, d), jnp.float32, nl + ("ffn", "fsdp"), fan=fan),
    }


def ssm_cache_info(cfg: ArchConfig, batch: int) -> dict:
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    return {
        "conv": ParamInfo((batch, cfg.conv_width - 1, cfg.conv_dim), jnp.float32,
                          ("batch", None, "ffn"), init="zeros"),
        "ssm": ParamInfo((batch, H, N, P), jnp.float32,
                         ("batch", "heads", None, None), init="zeros"),
    }


def shard_hidden(h: jnp.ndarray) -> jnp.ndarray:
    """Layer-boundary hidden annotation for SSM stacks, respecting the
    ssm_shard flag (seq-SP by default; replicated-d under heads mode so
    the mixer's channel sharding doesn't bounce layouts every layer)."""
    from repro.models import runtime as _rt
    if _rt.flag("ssm_shard", "mixed") == "heads":
        return shard(h, "batch", None, None)
    # "mixed": seq-sharded hidden between layers (SP activation savings),
    # heads/channels inside the mixer (one resharding per layer boundary).
    return shard(h, "batch", "seq", None)


def _split_proj(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, x, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1)
    return z, x, b, c, dt


def _gated_norm(p, y: jnp.ndarray, z: jnp.ndarray, eps: float) -> jnp.ndarray:
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    return (gf * (var + eps) ** -0.5 * p["norm_scale"]).astype(y.dtype)


def mamba_mixer(
    cfg: ArchConfig, p: dict, xin: jnp.ndarray, *, chunk: int = 128,
    use_kernel: bool = False, return_state: bool = False,
):
    """Training/prefill path. xin: (B, S, D) -> (B, S, D).
    With return_state=True also returns the decode cache {conv, ssm}
    advanced through the whole sequence (used by prefill)."""
    B, S, D = xin.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    dt_ = xin.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", xin, wx(p["in_proj"], dt_))
    z, xbc_x, bmat, cmat, dt_raw = _split_proj(cfg, zxbcdt)

    # causal conv over [x, B, C] channels
    xbc = jnp.concatenate([xbc_x, bmat, cmat], axis=-1)          # (B, S, conv_dim)
    # sharding choice (hillclimb flag "ssm_shard"): the SSD recurrence is
    # SEQUENTIAL over seq but fully parallel over channels/heads — sharding
    # channels over the model axis keeps the chunk scan local to a device;
    # seq sharding forces per-chunk gathers (see EXPERIMENTS.md §Perf).
    from repro.models import runtime as _rt
    _heads_mode = _rt.flag("ssm_shard", "mixed") in ("heads", "mixed")
    xbc = (shard(xbc, "batch", None, "ffn") if _heads_mode
           else shard(xbc, "batch", "seq", None))
    conv_w = p["conv_w"].astype(dt_)                             # (W, conv_dim)
    W = conv_w.shape[0]
    pads = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    conv = sum(pads[:, i : i + S, :] * conv_w[i][None, None, :] for i in range(W))
    conv = conv + p["conv_b"].astype(dt_)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dt_)
    x, bmat, cmat = jnp.split(conv, [di, di + G * N], axis=-1)

    xh = x.reshape(B, S, H, P)
    bh = bmat.reshape(B, S, G, N)
    ch = cmat.reshape(B, S, G, N)
    if _heads_mode:
        xh = shard(xh, "batch", None, "heads", None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (H,)

    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, s_fin = ssd_ops.ssd(xh, dt.astype(dt_), a, bh, ch, chunk=chunk)
    else:
        y, s_fin = _ssd_chunked_batch(
            xh.astype(jnp.float32), dt, a,
            bh.astype(jnp.float32), ch.astype(jnp.float32), chunk=chunk)
        y = y.astype(dt_)
    y = y + xh * p["d_skip"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, wx(p["out_proj"], dt_))
    if not return_state:
        return out
    W = cfg.conv_width
    conv_state = xbc[:, S - (W - 1):, :].astype(jnp.float32)    # (B, W-1, C)
    return out, {"conv": conv_state, "ssm": s_fin}


def _ssd_chunked_batch(x, dt, a, b, c, *, chunk: int):
    """Chunk-sequential SSD (fp32). x: (B,S,H,P); dt: (B,S,H); a: (H,);
    b/c: (B,S,G,N). Returns (y (B,S,H,P), s_final (B,H,N,P)).

    Chunks are processed by a lax.scan with a CHECKPOINTED body: the
    quadratic intra-chunk tensors (Q x Q per head) exist for one chunk at
    a time in both forward and backward (autodiff residuals are the chunk
    inputs only, recomputed blockwise in the backward pass). A fully
    batched-over-chunks einsum would materialize B*S*Q*H floats
    (terabytes at the assigned shapes). Pure jnp (XLA path); the Pallas
    kernel implements the same decomposition for TPU."""
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bh = jnp.repeat(b, rep, axis=2)                  # (B,S,H,N)
    ch = jnp.repeat(c, rep, axis=2)
    if S % chunk != 0:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    # (nc, B, Q, ...) scan layout
    xq = jnp.moveaxis(x.reshape(B, nc, chunk, H, P), 1, 0)
    dq = jnp.moveaxis(dt.reshape(B, nc, chunk, H), 1, 0)
    bq = jnp.moveaxis(bh.reshape(B, nc, chunk, H, N), 1, 0)
    cq = jnp.moveaxis(ch.reshape(B, nc, chunk, H, N), 1, 0)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_body(s_prev, inp):
        xc, dc, bc, cc = inp                          # (B,Q,H,P) (B,Q,H) ...
        da = dc * a[None, None, :]                    # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)
        lmat = jnp.where(tri[None, :, :, None],
                         jnp.exp(cum[:, :, None, :] - cum[:, None, :, :]), 0.0)
        scores = jnp.einsum("bqhs,bkhs->bqkh", cc, bc) * lmat   # (B,Q,Q,H)
        y = jnp.einsum("bqkh,bkhp->bqhp", scores, xc * dc[..., None])
        y = y + jnp.einsum("bqhs,bhsp->bqhp",
                           cc * jnp.exp(cum)[..., None], s_prev)
        decay_end = jnp.exp(cum[:, -1:, :] - cum)               # (B,Q,H)
        s_new = s_prev * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bqhs,bqhp->bhsp", bc * (dc * decay_end)[..., None], xc)
        return s_new, y

    body = jax.checkpoint(chunk_body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    s0 = jnp.zeros((B, H, N, P), jnp.float32)
    s_final, ys = jax.lax.scan(body, s0, (xq, dq, bq, cq))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, P)
    return y[:, :S], s_final


def mamba_decode_step(
    cfg: ArchConfig, p: dict, xin: jnp.ndarray, cache: dict,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode. xin: (B, 1, D); cache: {conv (B,W-1,C), ssm
    (B,H,N,P)}. Returns (out (B, 1, D), new cache). O(1) in sequence."""
    B, S, D = xin.shape
    assert S == 1
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    dt_ = xin.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", xin, wx(p["in_proj"], dt_))
    z, xbc_x, bmat, cmat, dt_raw = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xbc_x, bmat, cmat], axis=-1)[:, 0]   # (B, conv_dim)

    conv_state = cache["conv"].astype(dt_)                      # (B, W-1, C)
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B, W, C)
    conv_w = p["conv_w"].astype(dt_)                            # (W, C)
    conv = jnp.einsum("bwc,wc->bc", window, conv_w) + p["conv_b"].astype(dt_)
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(dt_)
    new_conv_state = window[:, 1:, :]

    x, bmat, cmat = jnp.split(conv, [di, di + G * N], axis=-1)
    xh = x.reshape(B, H, P).astype(jnp.float32)
    bh = jnp.repeat(bmat.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    ch = jnp.repeat(cmat.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    s = cache["ssm"]                                            # (B,H,N,P) fp32
    decay = jnp.exp(dt * a[None, :])                            # (B,H)
    s_new = s * decay[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", bh, dt, xh)
    y = jnp.einsum("bhn,bhnp->bhp", ch, s_new)                  # (B,H,P)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(dt_)
    y = _gated_norm(p, y, z, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, wx(p["out_proj"], dt_))
    return out, {"conv": new_conv_state.astype(cache["conv"].dtype), "ssm": s_new}
