"""Mixture-of-Experts block: top-k router + sort-based dispatch + EP.

Dispatch is gather/scatter based (argsort by expert id, capacity-bounded),
not one-hot einsum: at train_4k scale (1M tokens) a dense dispatch tensor
(tokens x experts x capacity) would be ~100s of GB, while sort-dispatch is
O(tokens * k). Experts are sharded over the model axis (EP); token->expert
routing crosses shards as an all-to-all inserted by GSPMD at the sharding
boundary between token-sharded and expert-sharded tensors.

Aux losses: load-balancing (Switch-style) + router z-loss, returned for the
trainer to weight.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def moe_params(cfg: ArchConfig, n_layers: int | None = None) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = () if n_layers is None else (n_layers,)
    nl = (None,) * len(L)
    fan = len(L)
    return {
        "router": ParamInfo(L + (d, E), jnp.float32, nl + ("fsdp", None), fan=fan),
        "wi": ParamInfo(L + (E, d, f), jnp.float32, nl + ("experts", "fsdp", None),
                        fan=fan + 1),
        "wg": ParamInfo(L + (E, d, f), jnp.float32, nl + ("experts", "fsdp", None),
                        fan=fan + 1),
        "wo": ParamInfo(L + (E, f, d), jnp.float32, nl + ("experts", None, "fsdp"),
                        fan=fan + 1),
    }


def moe(cfg: ArchConfig, p: dict, x: jnp.ndarray,
        *, capacity_factor: float = 1.25) -> tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (out (B, S, D), aux losses dict)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    dt = x.dtype
    from repro.models import runtime as _rt0
    if _rt0.flag("moe_impl") == "shardmap":
        from repro.layers.moe_shardmap import moe_shardmap
        from repro.parallel import sharding as _shd
        if _shd.active_mesh() is not None:
            return moe_shardmap(cfg, p, x, capacity_factor=capacity_factor)
    xt = x.reshape(T, D)
    # hillclimb flag "moe_token_shard": spread routing/sort/dispatch over
    # ALL mesh axes (tokens % (data*model) == 0 at the assigned shapes);
    # default leaves tokens batch-sharded (data only) and the dispatch
    # work gets replicated across the model axis by GSPMD.
    from repro.models import runtime as _rt
    _tok_all = _rt.flag("moe_token_shard", False)
    if _tok_all:
        xt = shard(xt, "tokens", None)

    # ---- router (fp32) ----
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)              # (T, K)
    if cfg.moe_norm_topk:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # aux: load-balance (mean prob * mean assignment fraction) + z-loss.
    # NOTE: assignment counts via scatter-add, NOT a (T, K, E) one-hot —
    # the one-hot materializes T*K*E floats (134 GB/device at train_4k
    # shapes; see EXPERIMENTS.md §Perf, moe hillclimb).
    me = jnp.mean(probs, axis=0)                                  # (E,)
    counts = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0)
    ce = counts / T                                               # (E,)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- sort-based dispatch ----
    capacity = int(max(1, capacity_factor * T * K / E))
    capacity = min(capacity, T)
    flat_expert = expert_ids.reshape(-1)                          # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)    # (T*K,)
    flat_gate = gate_vals.reshape(-1).astype(jnp.float32)

    if _tok_all:
        flat_expert = shard(flat_expert, "tokens")
        flat_token = shard(flat_token, "tokens")
        flat_gate = shard(flat_gate, "tokens")
    order = jnp.argsort(flat_expert, stable=True)                 # group by expert
    se, stok, sgate = flat_expert[order], flat_token[order], flat_gate[order]
    # position of each routed pair within its expert's queue
    ones = jnp.ones_like(se, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
    pos_in_expert = pos_in_expert - seg_start[se]
    keep = pos_in_expert < capacity

    slot = se.astype(jnp.int32) * capacity + pos_in_expert        # (T*K,)
    slot = jnp.where(keep, slot, E * capacity)                    # overflow bin
    # gather tokens into expert buffers (E*capacity(+1 overflow), D)
    buf_tok = jnp.zeros((E * capacity + 1,), jnp.int32).at[slot].set(
        stok, mode="drop")
    buf_tok_used = buf_tok[: E * capacity]
    if _rt.flag("moe_expert_aligned", False):
        # hillclimb: align the gather's INDEX operand with the expert
        # sharding (contiguous expert blocks on the flat dim) so each
        # device gathers only its experts' rows; GSPMD then moves the
        # (T, D) source (all-gather, ~1 GB) instead of all-reducing the
        # (E*cap, D) result (~10.7 GB) — see EXPERIMENTS.md §Perf.
        buf_tok_used = shard(buf_tok_used.reshape(E, capacity),
                             "experts", None).reshape(E * capacity)
    xe = jnp.take(xt, buf_tok_used, axis=0).reshape(E, capacity, D)
    xe = shard(xe, "experts", None, None)

    # ---- expert FFN (swiglu) ----
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))        # (E, cap, D)
    ye = shard(ye, "experts", None, None)

    # ---- combine: scatter-add back to tokens, weighted by gates ----
    yflat = ye.reshape(E * capacity, D)
    contrib = yflat[jnp.where(keep, slot, 0)] * (
        sgate * keep.astype(jnp.float32))[:, None].astype(dt)     # (T*K, D)
    out = jnp.zeros((T, D), dt).at[stok].add(contrib, mode="drop")
    out = out.reshape(B, S, D)
    return out, {"lb_loss": lb_loss, "z_loss": z_loss}
