"""Flash-style chunked causal attention (online softmax) with a
memory-efficient custom VJP, pure JAX.

Why: a materialized (B, H, S, S) score tensor at the assigned shapes is
petabytes (qwen2-72b @ 32k: 32x64x32768^2 fp32 ~ 8.8 PB), and plain
autodiff through a scanned flash forward would save per-block
probabilities — S^2 memory again. So:

  * forward: q/k tiles with running (max, sum, acc) carries — the standard
    FlashAttention recurrence as lax.scan; saves only (q, k, v, out, lse).
  * backward: two recomputation passes (dk/dv: outer scan over KV blocks;
    dq: outer scan over query blocks), each emitting stacked block results
    — no indexed accumulation, no S^2 residuals.

GQA-aware: K/V stay (B, KV, T, hd); query heads are grouped (KV, rep) and
the repeat happens inside block einsums — expanded K/V never exist.

The dry-run lowers THIS path (XLA ops are visible to cost_analysis; a
Pallas kernel would be opaque to the roofline extraction — DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def flash_attention(q, k, v, *, causal: bool = True,
                    q_blk: int = 512, k_blk: int = 1024):
    """q: (B, H, S, hd); k/v: (B, KV, T, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    q_blk = min(q_blk, S)
    k_blk = min(k_blk, T)
    assert S % q_blk == 0 and T % k_blk == 0, (S, q_blk, T, k_blk)
    return _flash(causal, q_blk, k_blk, q, k, v)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _blocks(x, n, blk, axis_seq=3):
    """(B, G, R, S, hd) -> (n, B, G, R, blk, hd) [or KV variants]."""
    shp = x.shape
    x = x.reshape(shp[:axis_seq] + (n, blk) + shp[axis_seq + 1:])
    return jnp.moveaxis(x, axis_seq, 0)


def _flash_fwd_impl(causal, q_blk, k_blk, q, k, v):
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    nq, nk = S // q_blk, T // k_blk
    scale = hd ** -0.5

    qg = q.reshape(B, KV, rep, S, hd)
    qs = _blocks(qg, nq, q_blk)                     # (nq,B,KV,rep,Q,hd)
    ks = _blocks(k, nk, k_blk, axis_seq=2)          # (nk,B,KV,K,hd)
    vs = _blocks(v, nk, k_blk, axis_seq=2)

    def q_step(_, qi_idx):
        qi, iq = qi_idx

        def kv_step(carry, kj_idx):
            acc, m, l = carry
            (kj, vj), jk = kj_idx
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qi, kj).astype(jnp.float32) * scale
            if causal:
                qpos = iq * q_blk + jax.lax.broadcasted_iota(
                    jnp.int32, (q_blk, k_blk), 0)
                kpos = jk * k_blk + jax.lax.broadcasted_iota(
                    jnp.int32, (q_blk, k_blk), 1)
                s = jnp.where((kpos <= qpos)[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(qi.dtype), vj)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros(qi.shape, qi.dtype)
        m0 = jnp.full(qi.shape[:-1], NEG_INF, jnp.float32)
        l0 = jnp.zeros(qi.shape[:-1], jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      ((ks, vs), jnp.arange(nk)))
        l = jnp.maximum(l, 1e-30)
        out = acc / l[..., None].astype(acc.dtype)
        lse = m + jnp.log(l)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs: (nq,B,KV,rep,Q,hd) -> (B,H,S,hd); lse -> (B,KV,rep,S)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, rep, S, hd).reshape(B, H, S, hd)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KV, rep, S)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash(causal, q_blk, k_blk, q, k, v):
    out, _ = _flash_fwd_impl(causal, q_blk, k_blk, q, k, v)
    return out


def _flash_fwd(causal, q_blk, k_blk, q, k, v):
    out, lse = _flash_fwd_impl(causal, q_blk, k_blk, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_blk, k_blk, res, dout):
    q, k, v, out, lse = res
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    rep = H // KV
    nq, nk = S // q_blk, T // k_blk
    scale = hd ** -0.5

    qg = q.reshape(B, KV, rep, S, hd)
    dog = dout.reshape(B, KV, rep, S, hd)
    og = out.reshape(B, KV, rep, S, hd)
    # D_i = rowsum(dout * out)  (B,KV,rep,S)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), axis=-1)

    qs = _blocks(qg, nq, q_blk)
    dos = _blocks(dog, nq, q_blk)
    ks = _blocks(k, nk, k_blk, axis_seq=2)
    vs = _blocks(v, nk, k_blk, axis_seq=2)
    lses = _blocks(lse[..., None], nq, q_blk)[..., 0]    # (nq,B,KV,rep,Q)
    deltas = _blocks(delta[..., None], nq, q_blk)[..., 0]

    def mask_for(iq, jk):
        qpos = iq * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
        kpos = jk * k_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
        return kpos <= qpos

    def p_block(qi, kj, lse_i, iq, jk):
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qi, kj).astype(jnp.float32) * scale
        if causal:
            s = jnp.where(mask_for(iq, jk)[None, None, None], s, NEG_INF)
        return jnp.exp(s - lse_i[..., None])             # (B,KV,rep,Q,K)

    # ---- pass 1: dk/dv (outer over kv blocks, inner sums over q blocks)
    def kv_outer(_, kj_idx):
        (kj, vj), jk = kj_idx

        def q_inner(carry, qi_idx):
            dk_j, dv_j = carry
            (qi, doi, lse_i, dl_i), iq = qi_idx
            p = p_block(qi, kj, lse_i, iq, jk)
            dv_j = dv_j + jnp.einsum("bgrqk,bgrqd->bgkd",
                                     p.astype(doi.dtype), doi)
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", doi, vj).astype(jnp.float32)
            ds = p * (dp - dl_i[..., None]) * scale
            dk_j = dk_j + jnp.einsum("bgrqk,bgrqd->bgkd",
                                     ds.astype(qi.dtype), qi)
            return (dk_j, dv_j), None

        z = jnp.zeros(kj.shape, kj.dtype)
        (dk_j, dv_j), _ = jax.lax.scan(
            q_inner, (z, jnp.zeros(vj.shape, vj.dtype)),
            ((qs, dos, lses, deltas), jnp.arange(nq)))
        return None, (dk_j, dv_j)

    _, (dks, dvs) = jax.lax.scan(kv_outer, None, ((ks, vs), jnp.arange(nk)))
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, KV, T, hd)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, KV, T, hd)

    # ---- pass 2: dq (outer over q blocks, inner sums over kv blocks)
    def q_outer(_, qi_idx):
        (qi, doi, lse_i, dl_i), iq = qi_idx

        def kv_inner(dq_i, kj_idx):
            (kj, vj), jk = kj_idx
            p = p_block(qi, kj, lse_i, iq, jk)
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", doi, vj).astype(jnp.float32)
            ds = p * (dp - dl_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bgrqk,bgkd->bgrqd",
                                     ds.astype(kj.dtype), kj)
            return dq_i, None

        dq_i, _ = jax.lax.scan(kv_inner, jnp.zeros(qi.shape, qi.dtype),
                               ((ks, vs), jnp.arange(nk)))
        return None, dq_i

    _, dqs = jax.lax.scan(q_outer, None, ((qs, dos, lses, deltas), jnp.arange(nq)))
    dq = jnp.moveaxis(dqs, 0, 3).reshape(B, KV, rep, S, hd).reshape(B, H, S, hd)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_ref(q, k, v, *, causal=True):
    """Dense oracle for tests (small shapes only)."""
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    kr = jnp.repeat(k, H // KV, axis=1)
    vr = jnp.repeat(v, H // KV, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32) * hd ** -0.5
    if causal:
        qi = jax.lax.broadcasted_iota(jnp.int32, (S, T), 0)
        ki = jax.lax.broadcasted_iota(jnp.int32, (S, T), 1)
        s = jnp.where((ki <= qi)[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr)
