"""Multi-head attention with GQA/MQA, RoPE/M-RoPE, and a KV cache.

Sharding strategy (annotated via logical axes, DESIGN.md §5):
  * projections: weights (d -> heads*hd) sharded fsdp x heads-TP;
  * attention core: heads sharded over the model axis when the head count
    divides it; otherwise the *query sequence* is sharded (the divisibility
    fallback in parallel.sharding handles GQA head counts like 20 or 24
    that don't divide a 16-way model axis);
  * decode KV cache: sequence dim sharded over the model axis
    (flash-decode style) so a 32k-token cache for 128 sequences fits.

The cache layout is (B, KV, S_max, hd); `pos` is a per-sequence int32
write index, enabling batched continuous decoding in the serving engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers import rotary
from repro.layers.common import wx
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard

NEG_INF = -2.0e38
FLASH_MIN_SEQ = 2048   # dense path below this (smoke tests, short prompts)


def attn_params(cfg: ArchConfig, n_layers: int | None = None) -> dict:
    """Abstract attention params; leading n_layers dim when stacked."""
    H, KV, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    L = () if n_layers is None else (n_layers,)
    nl = (None,) * len(L)
    fan = len(L)
    p = {
        "wq": ParamInfo(L + (d, H, hd), jnp.float32, nl + ("fsdp", "heads", None), fan=fan),
        "wk": ParamInfo(L + (d, KV, hd), jnp.float32, nl + ("fsdp", "kv_heads", None), fan=fan),
        "wv": ParamInfo(L + (d, KV, hd), jnp.float32, nl + ("fsdp", "kv_heads", None), fan=fan),
        "wo": ParamInfo(L + (H, hd, d), jnp.float32, nl + ("heads", None, "fsdp"), fan=fan),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamInfo(L + (H, hd), jnp.float32, nl + ("heads", None), init="zeros")
        p["bk"] = ParamInfo(L + (KV, hd), jnp.float32, nl + ("kv_heads", None), init="zeros")
        p["bv"] = ParamInfo(L + (KV, hd), jnp.float32, nl + ("kv_heads", None), init="zeros")
    return p


def init_cache_info(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Abstract KV cache for one attention site (stacked over sites by the
    caller). Sequence dim sharded over the model axis (kv_seq)."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.cdtype()
    return {
        "k": ParamInfo((batch, KV, max_len, hd), dt,
                       ("batch", "kv_heads", "kv_seq", None), init="zeros"),
        "v": ParamInfo((batch, KV, max_len, hd), dt,
                       ("batch", "kv_heads", "kv_seq", None), init="zeros"),
    }


def _project(x, w, b=None):
    """(B, S, D) x (D, H, hd) -> (B, S, H, hd) in compute dtype."""
    y = jnp.einsum("bsd,dhk->bshk", x, wx(w, x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def _repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, KV, S, hd) -> (B, H, S, hd) by repeating each kv head."""
    kv = k.shape[1]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=1)


def attention(
    cfg: ArchConfig,
    p: dict,
    x: jnp.ndarray,                 # (B, S, D)
    positions: jnp.ndarray,         # (B, S) int32, or (3, B, S) for mrope
    *,
    cache: dict | None = None,      # {"k","v"} (B, KV, S_max, hd)
    cache_pos: jnp.ndarray | None = None,  # (B,) write index for decode
    causal: bool = True,
) -> tuple[jnp.ndarray, dict | None]:
    """Returns (out (B, S, D), updated cache or None)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = _project(x, p["wq"], p.get("bq"))            # (B, S, H, hd)
    k = _project(x, p["wk"], p.get("bk"))            # (B, S, KV, hd)
    v = _project(x, p["wv"], p.get("bv"))

    if cfg.pos == "rope":
        pos2d = positions
        q = rotary.rope(q, pos2d, cfg.rope_theta)
        k = rotary.rope(k, pos2d, cfg.rope_theta)
    elif cfg.pos == "mrope":
        q = rotary.mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = rotary.mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    # cfg.pos == "sin": absolute embeddings added at the input; nothing here.

    q = q.transpose(0, 2, 1, 3)                      # (B, H, S, hd)
    k = k.transpose(0, 2, 1, 3)                      # (B, KV, S, hd)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None:
        if cache_pos is not None:
            # decode: scatter this step's K/V at each sequence's position
            if S != 1:
                raise ValueError("cache_pos decode expects S == 1")
            ck, cv = cache["k"], cache["v"]
            from repro.models import runtime
            if runtime.flag("cache_update", "where") == "scatter":
                # hillclimb variant: true scatter touches only the written
                # row (the `where` select streams the whole cache twice)
                bidx = jnp.arange(B)
                ck = ck.at[bidx, :, cache_pos, :].set(k[:, :, 0, :].astype(ck.dtype))
                cv = cv.at[bidx, :, cache_pos, :].set(v[:, :, 0, :].astype(cv.dtype))
            else:
                idx = cache_pos[:, None, None, None]     # (B,1,1,1)
                seq_iota = jax.lax.broadcasted_iota(jnp.int32, ck.shape, 2)
                ck = jnp.where(seq_iota == idx, k.astype(ck.dtype), ck)
                cv = jnp.where(seq_iota == idx, v.astype(cv.dtype), cv)
            k_full, v_full = ck, cv
            kv_len = ck.shape[2]
            new_cache = {"k": ck, "v": cv}
            # attention mask: only positions <= cache_pos are valid
            valid = jax.lax.broadcasted_iota(jnp.int32, (B, 1, 1, kv_len), 3) <= (
                cache_pos[:, None, None, None])
        else:
            # prefill: write the computed K/V into the cache buffer
            ck = jnp.zeros_like(cache["k"]).at[:, :, :S, :].set(k.astype(cache["k"].dtype))
            cv = jnp.zeros_like(cache["v"]).at[:, :, :S, :].set(v.astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            k_full, v_full, kv_len, valid = k, v, S, None
    else:
        k_full, v_full, kv_len, valid = k, v, S, None

    q = shard(q, "batch", "heads", "seq", None)
    k_full = shard(k_full, "batch", "kv_heads", "kv_seq" if cache is not None else "seq", None)
    v_full = shard(v_full, "batch", "kv_heads", "kv_seq" if cache is not None else "seq", None)

    if valid is None and causal and S >= FLASH_MIN_SEQ:
        # long-sequence path: flash-style chunked attention — a dense
        # (B, H, S, S) score tensor at the assigned shapes is petabytes.
        from repro.layers.flash import flash_attention
        ctx = flash_attention(q, k_full, v_full, causal=True)
    else:
        from repro.models import runtime as _rt
        if _rt.flag("attn_impl", "grouped") == "repeat":
            # legacy path (hillclimb A/B): materializing the GQA head
            # repeat makes GSPMD replicate the seq-sharded KV cache —
            # see EXPERIMENTS.md §Perf (qwen2-72b decode).
            kr = _repeat_kv(k_full, H)               # (B, H, T, hd)
            vr = _repeat_kv(v_full, H)
            scale = hd ** -0.5
            scores = jnp.einsum("bhsk,bhtk->bhst", q, kr).astype(jnp.float32) * scale
            if valid is not None:
                scores = jnp.where(valid, scores, NEG_INF)
            elif causal and S > 1:
                qi = jax.lax.broadcasted_iota(jnp.int32, (S, kv_len), 0)
                ki = jax.lax.broadcasted_iota(jnp.int32, (S, kv_len), 1)
                scores = jnp.where((ki <= qi)[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            ctx = jnp.einsum("bhst,bhtk->bhsk", probs, vr)
        else:
            # grouped GQA: query heads reshaped (KV, rep); K/V consumed in
            # their stored layout — no repeat, cache stays seq-sharded and
            # the softmax/PV contractions reduce over the model axis.
            rep = H // KV
            qg = q.reshape(B, KV, rep, S, hd)
            scale = hd ** -0.5
            scores = jnp.einsum("bgrsk,bgtk->bgrst", qg, k_full)
            scores = scores.astype(jnp.float32) * scale   # (B,KV,rep,S,T)
            if valid is not None:
                scores = jnp.where(valid[:, :, None], scores, NEG_INF)
            elif causal and S > 1:
                qi = jax.lax.broadcasted_iota(jnp.int32, (S, kv_len), 0)
                ki = jax.lax.broadcasted_iota(jnp.int32, (S, kv_len), 1)
                scores = jnp.where((ki <= qi)[None, None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            ctx = jnp.einsum("bgrst,bgtk->bgrsk", probs, v_full)
            ctx = ctx.reshape(B, H, S, hd)
    ctx = ctx.transpose(0, 2, 1, 3)                  # (B, S, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", ctx, wx(p["wo"], x.dtype))
    return out, new_cache
