"""Feed-forward blocks: SwiGLU (llama/qwen), GeGLU (gemma), GELU (musicgen)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import wx
from repro.models.base import ArchConfig, ParamInfo
from repro.parallel.sharding import shard


def mlp_params(cfg: ArchConfig, n_layers: int | None = None) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    L = () if n_layers is None else (n_layers,)
    nl = (None,) * len(L)
    fan = len(L)
    p = {
        "wi": ParamInfo(L + (d, f), jnp.float32, nl + ("fsdp", "ffn"), fan=fan),
        "wo": ParamInfo(L + (f, d), jnp.float32, nl + ("ffn", "fsdp"), fan=fan),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["wg"] = ParamInfo(L + (d, f), jnp.float32, nl + ("fsdp", "ffn"), fan=fan)
    return p


def mlp(cfg: ArchConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, wx(p["wi"], dt))
    if cfg.act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, wx(p["wg"], dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    elif cfg.act == "geglu":
        g = jnp.einsum("bsd,df->bsf", x, wx(p["wg"], dt))
        h = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(dt) * h
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(dt)
    else:
        raise ValueError(cfg.act)
    # TP: the ffn dim owns the model axis inside the block (seq is re-sharded
    # at layer boundaries by the caller — Megatron-style SP <-> TP handoff).
    h = shard(h, "batch", None, "ffn")
    return jnp.einsum("bsf,fd->bsd", h, wx(p["wo"], dt))
