"""Normalization layers (fp32 statistics regardless of compute dtype)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.base import ParamInfo


def rmsnorm_params(d: int, n_layers: int | None = None, *, plus_one: bool = False):
    shape = (d,) if n_layers is None else (n_layers, d)
    # gemma parameterizes scale as (1 + w) with w init 0; others init 1.
    return {"scale": ParamInfo(shape, jnp.float32,
                               (None,) * len(shape),
                               init="zeros" if plus_one else "ones")}


def layernorm_params(d: int, n_layers: int | None = None):
    shape = (d,) if n_layers is None else (n_layers, d)
    return {
        "scale": ParamInfo(shape, jnp.float32, (None,) * len(shape), init="ones"),
        "bias": ParamInfo(shape, jnp.float32, (None,) * len(shape), init="zeros"),
    }


def norm_params(kind: str, d: int, n_layers: int | None = None, *, plus_one=False):
    if kind == "rmsnorm":
        return rmsnorm_params(d, n_layers, plus_one=plus_one)
    if kind == "layernorm":
        return layernorm_params(d, n_layers)
    raise ValueError(kind)


def apply_norm(kind: str, p: dict, x: jnp.ndarray, *, eps: float,
               plus_one: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        xn = xf * (var + eps) ** -0.5
        scale = p["scale"] + 1.0 if plus_one else p["scale"]
        return (xn * scale).astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        xn = (xf - mu) * (var + eps) ** -0.5
        return (xn * p["scale"] + p["bias"]).astype(x.dtype)
    raise ValueError(kind)
