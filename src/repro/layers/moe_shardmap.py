"""MoE dispatch with explicit all-to-all (shard_map), bypassing GSPMD.

Why this exists (EXPERIMENTS.md §Perf cell 3): GSPMD lowers the
token(data)->expert(model) `jnp.take` as mask + ALL-REDUCE of the full
(E*cap, D) expert buffer (~21 GB/layer/microbatch at qwen3-30B train_4k,
227 s of ICI time per step). The classic Switch decomposition moves only
the routed tokens: each device routes its local tokens, buckets them by
destination model-rank, and a single `all_to_all` over the model axis
delivers them to the experts' owners (payload ~= T*K*D/chips).

Manual collectives over BOTH mesh axes; expert weights arrive sharded
over the model axis (E_loc = E/mp experts per rank; fsdp on the weight
D/F dims is all-gathered locally, mirroring the GSPMD FSDP pattern).
Differentiable end-to-end (all_to_all / all_gather are linear).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.base import ArchConfig
from repro.parallel import sharding as shd


def _bucket_by_dest(ids, gates, xt, *, n_dest, cap, e_loc):
    """Group routed (token, expert) pairs into per-destination buckets.
    ids/gates: (T*K,), xt: (T, D). Returns send buffers:
      xs   (n_dest, cap, D)   token vectors
      meta (n_dest, cap, 3)   [local_expert, gate, src_row] (-1 pad)
    """
    TK = ids.shape[0]
    T, D = xt.shape
    dest = ids // e_loc                                   # (TK,)
    order = jnp.argsort(dest, stable=True)
    d_s, ids_s = dest[order], ids[order]
    gates_s = gates[order]
    src_s = (jnp.arange(TK, dtype=jnp.int32) // (TK // T))[order]

    pos = jnp.arange(TK, dtype=jnp.int32)
    seg_start = jnp.searchsorted(d_s, jnp.arange(n_dest, dtype=d_s.dtype),
                                 side="left")
    pos_in_dest = pos - seg_start[d_s]
    keep = pos_in_dest < cap
    slot = jnp.where(keep, d_s.astype(jnp.int32) * cap + pos_in_dest,
                     n_dest * cap)

    xs = jnp.zeros((n_dest * cap + 1, D), xt.dtype).at[slot].set(
        jnp.take(xt, src_s, axis=0), mode="drop")[:-1]
    rows3 = jnp.stack([(ids_s % e_loc).astype(jnp.float32), gates_s,
                       src_s.astype(jnp.float32)], axis=-1)     # (TK, 3)
    meta = jnp.full((n_dest * cap + 1, 3), -1.0, jnp.float32).at[slot].set(
        rows3, mode="drop")[:-1]
    return xs.reshape(n_dest, cap, D), meta.reshape(n_dest, cap, 3)


def moe_shardmap(cfg: ArchConfig, p: dict, x: jnp.ndarray,
                 *, capacity_factor: float = 1.25):
    """Drop-in for layers.moe.moe() when a mesh with (data, model) axes is
    active. x: (B, S, D) batch-sharded over data. Returns (out, aux)."""
    mesh = shd.active_mesh()
    assert mesh is not None and "model" in mesh.shape
    mp = mesh.shape["model"]
    E, K, D = cfg.n_experts, cfg.experts_per_token, cfg.d_model
    e_loc = E // mp

    def body(xb, rw, wi, wg, wo):
        # xb (B_loc, S, D) replicated over model; weights (E_loc, D, F)
        B_loc, S, _ = xb.shape
        midx = jax.lax.axis_index("model")
        T_all = B_loc * S
        T_loc = T_all // mp
        xt_all = xb.reshape(T_all, D)
        xt = jax.lax.dynamic_slice_in_dim(xt_all, midx * T_loc, T_loc)

        # local routing
        logits = xt.astype(jnp.float32) @ rw.astype(jnp.float32)   # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)
        if cfg.moe_norm_topk:
            gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        me = jnp.mean(probs, axis=0)
        counts = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(1.0)
        lb = E * jnp.sum(me * (counts / T_loc))
        zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        lb = jax.lax.pmean(jax.lax.pmean(lb, "model"), "data")
        zl = jax.lax.pmean(jax.lax.pmean(zl, "model"), "data")

        cap = int(max(1, capacity_factor * T_loc * K / mp))
        xs, meta = _bucket_by_dest(
            expert_ids.reshape(-1), gate_vals.reshape(-1).astype(jnp.float32),
            xt, n_dest=mp, cap=cap, e_loc=e_loc)

        # the all-to-all: tokens travel to their experts' owners
        xr = jax.lax.all_to_all(xs, "model", split_axis=0, concat_axis=0,
                                tiled=False)
        mr = jax.lax.all_to_all(meta, "model", split_axis=0, concat_axis=0,
                                tiled=False)
        # xr: (mp, cap, D) rows from each source rank; local experts only
        xr_f = xr.reshape(mp * cap, D)
        le = mr.reshape(mp * cap, 3)[:, 0]                # local expert or -1
        valid = le >= 0

        # bucket received rows by local expert (same trick, local)
        le_key = jnp.where(valid, le, float(e_loc)).astype(jnp.int32)
        le_s, order = jax.lax.sort(
            (le_key, jnp.arange(le_key.shape[0], dtype=jnp.int32)), num_keys=1)
        rows_s = jnp.take(xr_f, order, axis=0)
        # per-local-expert capacity: mean + 2x imbalance headroom
        cap_e = int(max(1, 2 * mp * cap // e_loc))
        pos = jnp.arange(mp * cap, dtype=jnp.int32)
        seg = jnp.searchsorted(le_s, jnp.arange(e_loc, dtype=jnp.int32),
                               side="left")
        pie = pos - seg[jnp.clip(le_s, 0, e_loc - 1)]
        slot = jnp.where(le_s < e_loc, le_s * cap_e + pie, e_loc * cap_e)
        xe = jnp.zeros((e_loc * cap_e + 1, D), xr_f.dtype).at[slot].set(
            rows_s, mode="drop")[:-1].reshape(e_loc, cap_e, D)

        # expert FFN (swiglu)
        dt = xb.dtype
        h = jnp.einsum("ecd,edf->ecf", xe, wi.astype(dt))
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
        ye = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))  # (e_loc, cap_e, D)

        # un-bucket: back to received-row order, then all_to_all home
        ye_f = ye.reshape(e_loc * cap_e, D)
        take = jnp.where(slot < e_loc * cap_e, slot, 0)
        back = jnp.where((valid[order] & (slot < e_loc * cap_e))[:, None],
                         jnp.take(ye_f, take, axis=0), 0.0).astype(dt)
        # invert the sort permutation
        inv = jnp.zeros_like(order).at[order].set(
            jnp.arange(order.shape[0], dtype=order.dtype))
        y_recv_order = jnp.take(back, inv, axis=0).reshape(mp, cap, D)
        y_home = jax.lax.all_to_all(y_recv_order, "model", split_axis=0,
                                    concat_axis=0, tiled=False)
        # combine at the source: weighted scatter-add by original token row
        y_home_f = y_home.reshape(mp * cap, D)
        meta_home = meta.reshape(mp * cap, 3)
        src = meta_home[:, 2].astype(jnp.int32)
        gts = meta_home[:, 1]
        ok = meta_home[:, 0] >= 0
        out_my = jnp.zeros((T_loc, D), dt).at[jnp.where(ok, src, 0)].add(
            jnp.where(ok[:, None], y_home_f * gts[:, None].astype(dt), 0.0),
            mode="drop")

        # reassemble the full local-batch tokens across model ranks
        out_all = jax.lax.all_gather(out_my, "model", axis=0, tiled=True)
        return out_all.reshape(B_loc, S, D), lb, zl

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    xspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None, None)
    in_specs = (xspec, P(None, None), P("model", None, None),
                P("model", None, None), P("model", None, None))
    out_specs = (xspec, P(), P())
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
    else:  # jax <= 0.4.x: experimental home, replication check named check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        mapped = _shard_map(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
    out, lb, zl = mapped(x, p["router"].astype(jnp.float32),
                         p["wi"], p["wg"], p["wo"])
    return out, {"lb_loss": lb, "z_loss": zl}
