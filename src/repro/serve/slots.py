"""Fixed-capacity slot batching, shared across serving runtimes.

Both servers in this repo batch the same way: requests are padded into
fixed-size slot blocks so every served function sees exactly one batch
shape and one jit trace stays live per model. The LM engine
(`repro.serve.engine`) slots token batches; the netgen predictor server
(`repro.netgen.serve`) slots uint8 image batches. This module holds the
shared mechanics and deliberately depends on numpy only, so the netgen
side can import it without pulling in the LM model stack.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

__all__ = ["pad_slots", "stack_requests"]


def pad_slots(x: np.ndarray, capacity: int) -> tuple[np.ndarray, int]:
    """Pad a request batch into a fixed-capacity slot block (leading axis).

    Padding rows are zeros; the returned int is the number of valid
    leading rows. Raises when the batch exceeds the capacity — chunking
    policy belongs to the caller.
    """
    x = np.asarray(x)
    n = x.shape[0]
    if n > capacity:
        raise ValueError(f"batch of {n} exceeds slot capacity {capacity}")
    if n == capacity:
        return x, n
    pad = np.zeros((capacity - n,) + x.shape[1:], dtype=x.dtype)
    return np.concatenate([x, pad], axis=0), n


def stack_requests(rows: Sequence[np.ndarray]) -> np.ndarray:
    """Stack single requests (each one feature vector) into a batch.

    The admission side of an online serving engine holds individual
    requests; the dispatch side wants one (B, features) array to pad
    into a slot block. Rows must agree in shape and dtype — a mixed
    batch would silently upcast and defeat the servers' strict uint8
    validation.
    """
    if not rows:
        raise ValueError("no requests to stack")
    first = np.asarray(rows[0])
    for r in rows[1:]:
        r = np.asarray(r)
        if r.shape != first.shape or r.dtype != first.dtype:
            raise ValueError(
                f"requests disagree in shape/dtype: {first.shape}/"
                f"{first.dtype} vs {r.shape}/{r.dtype}")
    return np.stack([np.asarray(r) for r in rows], axis=0)
