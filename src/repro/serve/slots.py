"""Fixed-capacity slot batching, shared across serving runtimes.

Both servers in this repo batch the same way: requests are padded into
fixed-size slot blocks so every served function sees exactly one batch
shape and one jit trace stays live per model. The LM engine
(`repro.serve.engine`) slots token batches; the netgen predictor server
(`repro.netgen.serve`) slots uint8 image batches. This module holds the
shared mechanics and deliberately depends on numpy only, so the netgen
side can import it without pulling in the LM model stack.
"""
from __future__ import annotations

import numpy as np

__all__ = ["pad_slots"]


def pad_slots(x: np.ndarray, capacity: int) -> tuple[np.ndarray, int]:
    """Pad a request batch into a fixed-capacity slot block (leading axis).

    Padding rows are zeros; the returned int is the number of valid
    leading rows. Raises when the batch exceeds the capacity — chunking
    policy belongs to the caller.
    """
    x = np.asarray(x)
    n = x.shape[0]
    if n > capacity:
        raise ValueError(f"batch of {n} exceeds slot capacity {capacity}")
    if n == capacity:
        return x, n
    pad = np.zeros((capacity - n,) + x.shape[1:], dtype=x.dtype)
    return np.concatenate([x, pad], axis=0), n
