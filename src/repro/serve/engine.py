"""Batched serving engine: prefill + decode loop over a shared cache.

The engine serves fixed-capacity batches: requests are padded into slots,
prefilled together, then decoded step-by-step with per-slot positions and
stop handling (greedy or temperature sampling). This is the runtime behind
the `decode_*` dry-run cells; `serve_step` (one token for the whole batch)
is the unit that gets lowered/compiled for the mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.base import ArchConfig, tree_init


# The fixed-slot batching mechanics live in repro.serve.slots (numpy
# only, importable without the model stack); re-exported here because
# this engine is where the pattern originates.
from repro.serve.slots import pad_slots  # noqa: F401


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


def make_serve_step(cfg: ArchConfig):
    """serve_step(params, cache, tokens(B,1), pos(B,)) -> (next (B,1), cache).
    Greedy argmax inside the step (sampling handled by the engine loop)."""

    def serve_step(params, cache, tokens, pos):
        logits, cache = api.decode_step(cfg, params, tokens, pos, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    return serve_step


class Engine:
    def __init__(self, cfg: ArchConfig, params, sc: ServeConfig):
        self.cfg, self.params, self.sc = cfg, params, sc
        self._prefill = jax.jit(
            lambda p, b, c: api.prefill(cfg, p, b, c))
        self._step = jax.jit(make_serve_step(cfg))

    def generate(self, prompts: np.ndarray, extras: dict | None = None
                 ) -> np.ndarray:
        """prompts: (B, P) int32 token ids (uniform length; engine-level
        batching pads upstream). Returns (B, max_new_tokens)."""
        B, P = prompts.shape
        sc = self.sc
        cache = tree_init(
            api.abstract_cache(self.cfg, B, sc.max_len), jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update(extras)
        logits, cache = self._prefill(self.params, batch, cache)

        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out = [np.asarray(toks)]
        pos = jnp.full((B,), P, jnp.int32)
        alive = np.ones((B,), bool)
        for _ in range(sc.max_new_tokens - 1):
            toks, cache = self._step(self.params, cache, toks, pos)
            pos = pos + 1
            t_np = np.asarray(toks)
            if sc.eos_id >= 0:
                alive &= (t_np[:, 0] != sc.eos_id)
                t_np = np.where(alive[:, None], t_np, sc.eos_id)
            out.append(t_np)
        return np.concatenate(out, axis=1)
