"""Fault-tolerant checkpointing: atomic, mesh-independent, resharding.

Layout (one directory per step):

    <root>/step_<N>/
        meta.json        tree paths, shapes, dtypes, step, user metadata
        arrays.npz       one entry per leaf (path-keyed)

Write protocol: serialize into `<root>/.tmp-step_<N>`, fsync, then
os.rename -> crash-safe (a partially-written checkpoint is never visible
under its final name). Restore is mesh-independent: arrays are loaded on
host then `device_put` against the CURRENT mesh's NamedShardings, so a run
checkpointed on one topology restarts on another (elastic scaling).

At real multi-host scale each host writes only its addressable shards;
the single-process layout here keeps the same interface (save/restore take
the global tree) so the swap is local to this module.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np

from repro.models.base import is_info, tree_sds


def _paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves], treedef


def save(root: str, step: int, state, *, metadata: dict | None = None) -> str:
    """Atomically persist `state` (a pytree of arrays) for `step`."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = os.path.join(root, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    pairs, _ = _paths(state)
    arrays = {k: np.asarray(v) for k, v in pairs}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "time": time.time(),
        "keys": [k for k, _ in pairs],
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore(path: str, abstract_state):
    """Load a checkpoint into the structure of `abstract_state`
    (ParamInfo tree or array tree), resharded onto the active mesh."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}

    sds_tree = (tree_sds(abstract_state)
                if any(is_info(l) for l in jax.tree.leaves(
                    abstract_state, is_leaf=is_info))
                else abstract_state)
    pairs, treedef = _paths(sds_tree)
    out = []
    for key, sds in pairs:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.asarray(data[key], dtype=sds.dtype)
        if tuple(arr.shape) != tuple(sds.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != {sds.shape}")
        sharding = getattr(sds, "sharding", None)
        if sharding is not None:
            out.append(jax.device_put(arr, sharding))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_")]
    return max(steps) if steps else None


class CheckpointManager:
    """keep-last-N manager with emergency-save support."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    def save(self, step: int, state, *, metadata=None, tag: str = "") -> str:
        path = save(self.root, step, state,
                    metadata={**(metadata or {}), "tag": tag})
        self._gc()
        return path

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.root)
                       if d.startswith("step_"))
        doomed = steps[: -self.keep] if self.keep > 0 else []
        for s in doomed:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"))

    def restore_latest(self, abstract_state):
        s = latest_step(self.root)
        if s is None:
            return None, None
        path = os.path.join(self.root, f"step_{s:08d}")
        return s, restore(path, abstract_state)
