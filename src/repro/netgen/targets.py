"""Target registry: every execution backend as a first-class object.

A `Target` unifies what used to be ad-hoc knowledge spread across
`backends/` and its callers: the compile entry point, the artifact kind
(callable predictor / text source / cost report), the declared options
the bracket syntax accepts, and the optional multi-net (stacked) form
used by the serving layer. Targets are addressed by the same
`name[opt=value,...]` item syntax as pipeline passes:

    jnp                      jitted adds-only predictor (the oracle)
    pallas[interpret=false]  per-layer binary_matvec TPU kernel chain
    fused                    single-launch whole-net kernel (2-layer)
    verilog[style=legacy]    the paper's combinational module source
    cost                     IR walk -> logic-cell estimate vs Figure 7

`resolve_target` parses an item string (or takes a bare name plus an
opts dict), validates options against the target's declaration, and
returns (Target, opts). `target_string` renders the canonical form that
keys the ArtifactStore. `list_targets` enumerates the registry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

from repro.netgen.pipeline import check_opt_string, parse_item, render_opts

__all__ = [
    "Target", "get_target", "list_targets", "register_target",
    "resolve_target", "target_string",
]


@dataclasses.dataclass(frozen=True)
class Target:
    """One execution target. `compile` maps (circuit, **opts) to the
    artifact; `kind` says what that artifact is ("callable", "text",
    "report"); `opts` declares the accepted options as (name, type)
    pairs; `compile_multi`, when present, builds the stacked multi-net
    dispatch (a stacked `repro.netgen.plan.ExecutionPlan` plus the same
    declared opts -> callable); `wants_pass_trace` asks the Session
    driver to hand the pipeline's per-pass circuit trace to `compile`
    as `_pass_trace`; `wants_tuner` asks every compile entry point
    (single and multi) to receive the caller's `repro.netgen.tune
    .KernelTuner` as `_tuner` — how `Session(tune_store=...)` threads
    persisted tuning records into `tuned=true` kernel builds; and
    `wants_analysis` asks the driver to hand its pre-backend
    `repro.netgen.analysis.RangeAnalysis` to `compile` as `_analysis`,
    so width-consuming backends (verilog, cost) emit the proven widths
    instead of re-deriving them."""
    name: str
    kind: str
    description: str
    compile: Callable
    opts: tuple = ()                       # ((opt_name, type), ...)
    compile_multi: Callable | None = None
    wants_pass_trace: bool = False
    wants_tuner: bool = False
    wants_analysis: bool = False

    @property
    def callable(self) -> bool:
        return self.kind == "callable"


_REGISTRY: dict[str, Target] = {}


def register_target(target: Target) -> Target:
    _REGISTRY[target.name] = target
    return target


def get_target(name: str) -> Target:
    t = _REGISTRY.get(name)
    if t is None:
        raise ValueError(
            f"unknown target {name!r} (registered: "
            f"{', '.join(sorted(_REGISTRY))})")
    return t


def list_targets() -> tuple[Target, ...]:
    """Every registered target, sorted by name."""
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def resolve_target(target, extra_opts: Mapping | None = None
                   ) -> tuple[Target, dict]:
    """Resolve a target reference into (Target, validated opts).

    `target` is a Target, a bare name, or an item string with bracketed
    options ("verilog[style=legacy]"); `extra_opts` (e.g. keyword
    arguments of `compile_net`) are merged on top and validated the same
    way. Unknown targets, unknown options, and ill-typed option values
    raise ValueError.
    """
    if isinstance(target, Target):
        t, opts = target, {}
    else:
        name, opts = parse_item(str(target))
        t = get_target(name)
    merged = dict(opts)
    for k, v in (extra_opts or {}).items():
        if k in merged and merged[k] != v:
            raise ValueError(
                f"option {k!r} given twice for target {t.name!r}: "
                f"{merged[k]!r} in the target string vs {v!r} as a keyword")
        merged[k] = v
    declared = dict(t.opts)
    for k, v in merged.items():
        if k not in declared:
            raise ValueError(
                f"unknown option {k!r} for target {t.name!r} "
                f"(declared: {', '.join(sorted(declared)) or 'none'})")
        want = declared[k]
        if want is bool and not isinstance(v, bool):
            raise ValueError(
                f"option {k!r} of target {t.name!r} wants true/false, "
                f"got {v!r}")
        if want is int and (isinstance(v, bool) or not isinstance(v, int)):
            raise ValueError(
                f"option {k!r} of target {t.name!r} wants an integer, "
                f"got {v!r}")
        if want is str:
            if not isinstance(v, str):
                raise ValueError(
                    f"option {k!r} of target {t.name!r} wants a string, "
                    f"got {v!r}")
            check_opt_string(v, f"option {k!r} of target {t.name!r}")
    return t, merged


def target_string(target: Target, opts: Mapping) -> str:
    """Canonical `name[k=v,...]` form — one axis of the store key."""
    return f"{target.name}{render_opts(opts)}"


# ---------------------------------------------------------------------------
# Built-in targets (imports deferred to keep jax off the parse path)
# ---------------------------------------------------------------------------

def _compile_jnp(circuit, **opts):
    from repro.netgen.backends.jnp import compile_jnp
    return compile_jnp(circuit, **opts)


def _compile_jnp_multi(plan, **opts):
    from repro.netgen.backends.jnp import compile_jnp_multi
    return compile_jnp_multi(plan, **opts)


def _compile_pallas(circuit, **opts):
    from repro.netgen.backends.pallas import compile_pallas
    return compile_pallas(circuit, **opts)


def _compile_pallas_multi(plan, **opts):
    from repro.netgen.backends.pallas import compile_pallas_multi
    return compile_pallas_multi(plan, **opts)


def _compile_fused(circuit, **opts):
    from repro.netgen.backends.pallas import compile_fused
    return compile_fused(circuit, **opts)


def _compile_verilog(circuit, **opts):
    from repro.netgen.backends.verilog import emit_verilog
    return emit_verilog(circuit, **opts)


def _compile_cost(circuit, **opts):
    from repro.netgen.backends.cost import compile_cost
    return compile_cost(circuit, **opts)


register_target(Target(
    name="jnp", kind="callable",
    description="jitted adds-only predictor, weights as XLA literals "
                "(the oracle backend)",
    compile=_compile_jnp, compile_multi=_compile_jnp_multi))
register_target(Target(
    name="pallas", kind="callable",
    description="per-layer binary_matvec TPU kernel chain "
                "(interpret-mode on CPU; packed=true chains bit-packed "
                "activations end to end, planes=true additionally "
                "decomposes weights into packed bit-planes accumulated "
                "by popcount, fusednet=true runs the whole planes-form "
                "net as ONE persistent megakernel launch — any depth, "
                "stacked or single, weights resident and activations "
                "never leaving VMEM — tuned=true grid-searches the form "
                "and the bm/bn/bkw block sizes per plan shape and "
                "persists the winner; explored=true resolves the "
                "design-space explorer's persisted winner for the plan "
                "shape when one exists, see Session.explore)",
    compile=_compile_pallas,
    opts=(("interpret", bool), ("packed", bool), ("planes", bool),
          ("fusednet", bool), ("tuned", bool), ("explored", bool),
          ("bm", int), ("bn", int), ("bkw", int)),
    compile_multi=_compile_pallas_multi, wants_tuner=True))
register_target(Target(
    name="fused", kind="callable",
    description="single-launch whole-net Pallas kernel (2-layer only; "
                "tuned=true searches the bm batch tile)",
    compile=_compile_fused,
    opts=(("interpret", bool), ("tuned", bool), ("bm", int)),
    wants_tuner=True))
register_target(Target(
    name="verilog", kind="text",
    description="the paper's clockless combinational Verilog module",
    compile=_compile_verilog,
    opts=(("module_name", str), ("style", str), ("addend", bool)),
    wants_analysis=True))
register_target(Target(
    name="cost", kind="report",
    description="logic-cell estimate of the circuit vs paper Figure 7",
    compile=_compile_cost, wants_pass_trace=True, wants_analysis=True))
