"""Frontend: lower a quantized feed-forward stack into the circuit IR.

Accepts any of:
  * a `repro.core.quantize.QuantizedNet` (any depth — the class holds a
    tuple of integer weight matrices),
  * any object with `.weights` (sequence of 2-D int arrays) and
    `.input_threshold`,
  * a bare sequence of 2-D integer arrays (threshold passed separately).

Lowering mirrors the paper's network shape (Fig. 6) generalized to N
layers: one InputCompare per input component, then per dense layer one
WeightedSum per unit, with a SignStep after every layer except the last,
and a single Argmax over the last layer's accumulators. No optimization
happens here — zero weights become zero-weight terms, dead units become
empty consumers — so the pass pipeline's statistics see the true dense
cost. Run `repro.netgen.passes` to optimize.
"""
from __future__ import annotations

import numpy as np

from repro.netgen.graph import (
    Argmax, Circuit, InputCompare, SignStep, Term, WeightedSum,
)

DEFAULT_INPUT_THRESHOLD = 128  # paper §III.B pixel cutoff


def _validate_threshold(thr) -> int:
    """The pixel threshold must be an integer inside the uint8 domain
    where `pixel > threshold` is a real comparator: thr >= 255 can never
    fire and thr < 0 always fires, so every InputCompare lowered from
    such a value would be a silent constant — reject loudly instead.
    """
    if isinstance(thr, bool) or not isinstance(
            thr, (int, np.integer)):
        raise TypeError(
            f"input_threshold must be an integer, got {thr!r} "
            f"({type(thr).__name__}); pixels are compared as raw uint8")
    thr = int(thr)
    if not 0 <= thr < 255:
        raise ValueError(
            f"input_threshold {thr} is outside the uint8 comparator "
            "domain [0, 255): `pixel > 255` can never fire and a negative "
            "threshold always fires, so the lowered InputCompare would be "
            "a constant (the paper's cutoff is 128)")
    return thr


def _extract_weights(net, input_threshold):
    if hasattr(net, "weights"):
        ws = [np.asarray(w) for w in net.weights]
    elif hasattr(net, "w1") and hasattr(net, "w2"):
        ws = [np.asarray(net.w1), np.asarray(net.w2)]
    else:
        ws = [np.asarray(w) for w in net]
    # explicit caller threshold wins over the net's attribute
    thr = input_threshold
    if thr is None:
        thr = getattr(net, "input_threshold", None)
    if thr is None:
        thr = DEFAULT_INPUT_THRESHOLD
    thr = _validate_threshold(thr)
    if not ws:
        raise ValueError("no weight matrices to lower")
    for w in ws:
        if w.ndim != 2:
            raise ValueError(f"weights must be 2-D, got {w.shape}")
        if not np.issubdtype(w.dtype, np.integer):
            raise ValueError(
                f"netgen lowers *quantized* nets; got dtype {w.dtype} "
                "(run repro.core.quantize first)")
    for a, b in zip(ws, ws[1:]):
        if a.shape[1] != b.shape[0]:
            raise ValueError(f"layer shape mismatch: {a.shape} -> {b.shape}")
    return ws, int(thr)


def lower(net, *, input_threshold: int | None = None) -> Circuit:
    """Lower a quantized N-layer stack into a Circuit. See module doc."""
    ws, thr = _extract_weights(net, input_threshold)
    n_in = ws[0].shape[0]

    nodes: list = []
    nid = 0

    def fresh() -> int:
        nonlocal nid
        nid += 1
        return nid - 1

    acts: list[int] = []  # node ids of the current activation vector
    for i in range(n_in):
        node = InputCompare(id=fresh(), pixel=i, threshold=thr)
        nodes.append(node)
        acts.append(node.id)

    depth = len(ws)
    for layer, w in enumerate(ws, start=1):
        sums: list[int] = []
        for j in range(w.shape[1]):
            terms = tuple(
                Term(weight=int(w[i, j]), src=acts[i]) for i in range(w.shape[0]))
            node = WeightedSum(id=fresh(), terms=terms, layer=layer)
            nodes.append(node)
            sums.append(node.id)
        if layer < depth:
            steps: list[int] = []
            for s in sums:
                node = SignStep(id=fresh(), src=s)
                nodes.append(node)
                steps.append(node.id)
            acts = steps
        else:
            acts = sums

    out = Argmax(id=fresh(), srcs=tuple(acts))
    nodes.append(out)
    circuit = Circuit(
        n_inputs=n_in, input_threshold=thr, nodes=tuple(nodes), output=out.id)
    circuit.validate()
    return circuit
