"""Session API: compile once per content, persist artifacts across processes.

This module is the compiler's front door (the ISSUE-3 redesign):

  compile_artifact — the full driver: frontend -> declarative
      `PipelineSpec` -> `Target`, returning an `Artifact` that carries
      the optimized circuit, per-pass stats, a logic-cell estimate,
      wall-clock timings, and the content-address it lives under.

  ArtifactStore — a persistent, content-addressed artifact directory.
      The key is sha256 over `QuantizedNet.digest()` x
      `PipelineSpec.fingerprint()` x the canonical target string — every
      axis is stable across processes and machines, so a SECOND process
      pointed at the same directory warm-starts: the optimized circuit
      is reloaded from flat integer arrays (`graph.circuit_to_arrays`,
      no pickle) and the predictor is rebuilt from it without re-running
      the frontend or any pass. Writes are atomic (temp dir + rename),
      so concurrent processes can share one store.

  Session — the object users hold: an in-memory tier (the serving
      layer's `CompileCache`) over an optional `ArtifactStore`, plus an
      optional persistent kernel-tuning store and a background compile
      queue.

      session = Session(store=ArtifactStore("~/.cache/netgen"),
                        tune_store="~/.cache/netgen-tune")
      art = session.compile(qnet, target="pallas[tuned=true]")
      art(images)                   # callable artifact
      print(art.report())           # pass savings + cell estimate
      handle = session.compile_async(qnet2, target="pallas")
      ...                           # keep serving while it compiles
      handle.result()               # the Artifact, store now warm

  Tuning records (`repro.netgen.tune`) ride the same lifecycle as
  artifacts: `tuned=true` targets receive the session's `KernelTuner`,
  whose store is consulted before any measurement — including when an
  artifact is REBUILT from the ArtifactStore in a fresh process, so a
  warm process performs zero compiles AND zero tuning measurements.

`repro.netgen.compile_net` remains as a deprecated shim routed through a
default Session.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import os
import shutil
import threading
import time
import uuid
import weakref
from pathlib import Path

import numpy as np

from repro.core.quantize import weights_digest
from repro.netgen import analysis as _analysis
from repro.netgen import telemetry
from repro.netgen.backends.cost import CellCounts, CostReport, logic_cells
from repro.netgen.frontend import _extract_weights, lower
from repro.netgen.graph import (
    Circuit, circuit_from_arrays, circuit_to_arrays,
)
from repro.netgen.passes import CircuitOps, PassStats
from repro.netgen.pipeline import PipelineSpec
from repro.netgen.targets import resolve_target, target_string

__all__ = [
    "Artifact", "ArtifactStore", "Session", "StoreStats", "artifact_key",
    "compile_artifact", "compile_resolved",
]

_FORMAT = "netgen-artifact-v1"
_SOURCE_FINGERPRINT: str | None = None


def _source_fingerprint() -> str:
    """sha256 over the netgen package sources (plus the quantize module
    that defines digest semantics), computed once per process. Folded
    into every artifact key so a store can NEVER serve circuits
    optimized by older compiler code — editing any pass or backend
    invalidates all persisted artifacts, the same invariant the CI
    cache key enforces externally."""
    global _SOURCE_FINGERPRINT
    if _SOURCE_FINGERPRINT is None:
        h = hashlib.sha256()
        pkg = Path(__file__).parent
        files = sorted(pkg.rglob("*.py"))
        files.append(pkg.parent / "core" / "quantize.py")
        for f in files:
            h.update(f.name.encode())
            h.update(f.read_bytes())
        _SOURCE_FINGERPRINT = h.hexdigest()
    return _SOURCE_FINGERPRINT


def _validate_batch(x, n_inputs: int) -> None:
    """Reject non-uint8 or wrongly-shaped predictor input with a clear
    error instead of silently mis-binarizing (a float image batch would
    compare scaled values against the integer pixel threshold)."""
    dtype = getattr(x, "dtype", None)
    if dtype is None or np.dtype(dtype) != np.uint8:
        raise TypeError(
            f"compiled predictors take raw uint8 images, got dtype={dtype!r} "
            "(binarization happens inside the circuit; do not pre-scale)")
    shape = tuple(getattr(x, "shape", ()))
    if len(shape) != 2 or shape[1] != n_inputs:
        raise ValueError(
            f"expected a (batch, {n_inputs}) uint8 image batch, "
            f"got shape {shape}")


def artifact_key(digest: str, spec: PipelineSpec, target: str) -> str:
    """The store's content address: net digest x pipeline fingerprint x
    canonical target string x netgen source fingerprint, hashed. Every
    axis is process-stable; the source axis retires stale artifacts
    whenever the compiler itself changes (a spec string names WHICH
    passes run, not their implementation)."""
    h = hashlib.sha256()
    h.update(f"{_FORMAT}:{_source_fingerprint()}:{digest}:"
             f"{spec.fingerprint()}:{target}".encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Artifact
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Artifact:
    """One compilation result, self-describing enough to persist.

    `artifact` is the target's product (jitted callable / Verilog text /
    CostReport); `cost` is the logic-cell estimate of the final circuit
    (every target gets one — the `cost` target's artifact additionally
    breaks it down per pass); `source` says where this object
    originated: "compile" (built in this process) or "store" (reloaded
    from disk). Memory-tier hits return the same object, source
    unchanged. For callable targets `plan_form` records which
    ExecutionPlan datapath the predictor executes ("dense" or "packed"
    — see `repro.netgen.plan`); it persists with the artifact and
    `plan()` re-lowers the circuit into that exact form.

    `analysis` is the range-analysis proof summary computed pre-backend
    by `compile_resolved` (see `repro.netgen.analysis.proof_summary`):
    how many accumulators were proven to fit their emitted widths, the
    maximum |accumulator| and width, per-layer widths, slack bits, and
    int32 kernel-accumulation safety. It persists in `meta.json` and
    reloads with the artifact, so a warm-started process still knows
    what was proven about the circuit it is serving.
    """
    digest: str
    pipeline: str              # canonical PipelineSpec string
    target: str                # canonical target string (with options)
    kind: str                  # "callable" | "text" | "report"
    key: str                   # ArtifactStore content address
    circuit: Circuit
    pass_stats: tuple
    cost: CellCounts
    timings: dict
    source: str
    artifact: object
    plan_form: str | None = None   # "dense" | "packed" for callables
    analysis: dict | None = None   # range-analysis proof summary

    @property
    def backend(self) -> str:
        """Base target name (pre-Session `CompiledNet` compatibility)."""
        return self.target.partition("[")[0]

    def plan(self):
        """The layer-structured ExecutionPlan this predictor executes,
        re-lowered from the optimized circuit in the recorded form
        (what the serving layer stacks for multi-net dispatch)."""
        if self.kind != "callable":
            raise TypeError(
                f"{self.backend} artifacts have no execution plan "
                f"(kind: {self.kind})")
        from repro.netgen.plan import lower_circuit
        return lower_circuit(self.circuit, form=self.plan_form or "dense")

    def __call__(self, x_uint8):
        if not callable(self.artifact):
            raise TypeError(
                f"{self.backend} artifact is not callable (use .artifact)")
        _validate_batch(x_uint8, self.circuit.n_inputs)
        return self.artifact(x_uint8)

    def report(self) -> str:
        """Per-pass savings table, the final cell estimate, and the
        range-analysis proof summary when one was recorded."""
        lines = [s.row() for s in self.pass_stats]
        lines.append(self.cost.row())
        if self.analysis:
            lines.append(_analysis.summary_row(self.analysis))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Compile driver
# ---------------------------------------------------------------------------

def compile_artifact(net, *, target="jnp", pipeline=None,
                     input_threshold: int | None = None,
                     **target_opts) -> Artifact:
    """Frontend -> pipeline -> target, uncached. `net` is anything the
    frontend accepts; `pipeline` anything `PipelineSpec.coerce` accepts
    (None -> "default"); `target` a name or `name[opt=...]` string."""
    spec = PipelineSpec.coerce(pipeline)
    tgt, opts = resolve_target(target, target_opts)
    ws, thr = _extract_weights(net, input_threshold)
    return compile_resolved(ws, thr, weights_digest(ws, thr), spec, tgt, opts)


def compile_resolved(ws, thr: int, digest: str, spec: PipelineSpec,
                     tgt, opts: dict, tuner=None) -> Artifact:
    """The compile driver proper, for callers (the cache tiers) that
    already extracted/canonicalized the inputs while computing the
    content address — weights are not re-copied or re-hashed here.
    `tuner` reaches targets that declare `wants_tuner` (as `_tuner`),
    so `tuned=true` kernel builds hit the session's persistent tuning
    records instead of re-measuring."""
    tstring = target_string(tgt, opts)
    tel = telemetry.get_registry()

    with tel.span("netgen.compile", target=tstring,
                  pipeline=spec.spec_string(), digest=digest[:12]):
        t0 = time.perf_counter()
        with tel.span("netgen.lower"):
            circuit = lower(ws, input_threshold=thr)
        t_lower = time.perf_counter()

        trace: list | None = [] if tgt.wants_pass_trace else None
        circuit, stats = spec.run(
            circuit, observe=(lambda name, c: trace.append((name, c)))
            if trace is not None else None)
        t_passes = time.perf_counter()

        # Pre-backend range analysis: prove every accumulator fits its
        # inferred width before any backend bakes those widths into
        # Verilog, cell counts, or kernel dtypes. Strict mode
        # (NETGEN_VERIFY, on in tests/CI) raises on a violation; prod
        # counts it and compiles anyway, matching the pipeline policy.
        with tel.span("netgen.analysis"):
            ranges, diags = _analysis.analyze(circuit, stage="pre-backend",
                                              collect=True)
            if diags:
                tel.counter("netgen_verify_failures_total",
                            phase="compile").inc(len(diags))
                if _analysis.strict_verify():
                    raise _analysis.VerificationError(diags)
            summary = _analysis.proof_summary(circuit, ranges)
        t_analysis = time.perf_counter()

        kwargs = dict(opts)
        if tgt.wants_pass_trace:
            kwargs["_pass_trace"] = tuple(trace)
        if tgt.wants_tuner:
            kwargs["_tuner"] = tuner
        if tgt.wants_analysis:
            kwargs["_analysis"] = ranges
        with tel.span("netgen.backend", target=tstring):
            raw = tgt.compile(circuit, **kwargs)
        t_backend = time.perf_counter()

    tel.histogram("netgen_compile_seconds", target=tgt.name).observe(
        t_backend - t0)
    timings = {
        "lower_s": t_lower - t0,
        "passes_s": t_passes - t_lower,
        "analysis_s": t_analysis - t_passes,
        "backend_s": t_backend - t_analysis,
        "total_s": t_backend - t0,
    }
    plan_form = None
    if tgt.kind == "callable":
        # tuned=true backends choose the datapath at build time and
        # stamp it on the predictor; explicit options say it up front
        plan_form = getattr(raw, "plan_form", None) or (
            "planes" if opts.get("planes")
            else "packed" if opts.get("packed") else "dense")
        if tel.profile:
            # roofline inputs per compiled artifact: flops/bytes from
            # XLA's cost analysis at a canonical sample batch. Persists
            # with the artifact (timings live in meta.json) and lands
            # in BENCH_netgen.json via telemetry.summary().
            prof = telemetry.jit_cost(raw, (8, circuit.n_inputs))
            if prof is not None:
                timings["cost_analysis"] = prof
                tel.gauge("netgen_artifact_flops",
                          target=tgt.name).set(prof["flops"])
                tel.gauge("netgen_artifact_bytes",
                          target=tgt.name).set(prof["bytes_accessed"])
    return Artifact(
        plan_form=plan_form,
        digest=digest,
        pipeline=spec.spec_string(),
        target=tstring,
        kind=tgt.kind,
        key=artifact_key(digest, spec, tstring),
        circuit=circuit,
        pass_stats=stats,
        cost=logic_cells(circuit, analysis=ranges),
        timings=timings,
        source="compile",
        artifact=raw,
        analysis=summary,
    )


# ---------------------------------------------------------------------------
# Persistent store
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StoreStats:
    """Point-in-time snapshot of one store's telemetry counters (the
    live values are atomic `telemetry.Counter`s labelled with the
    store's scope; this dataclass is the read API)."""
    saves: int = 0
    loads: int = 0          # get() found and rebuilt an artifact
    misses: int = 0         # get() found nothing under the key
    corrupt: int = 0        # unreadable entries evicted and re-missed
    gc_evictions: int = 0   # entries removed by gc() size/count bounds
    load_seconds: float = 0.0

    def row(self) -> str:
        return (f"store: {self.saves} saves, {self.loads} loads, "
                f"{self.misses} misses, {self.gc_evictions} gc evictions, "
                f"{self.load_seconds * 1e3:.1f} ms loading")


class ArtifactStore:
    """Content-addressed on-disk artifact directory (see module doc).

    Layout: `<root>/<key>/meta.json` (digest, pipeline, target, pass
    stats, cell estimate, timings), `circuit.npz` (the optimized circuit
    as flat integer arrays), and `artifact.txt` for text targets.
    Callable artifacts are rebuilt from the stored circuit on load —
    the frontend and every pass are skipped, which is where compile time
    lives. Puts are atomic; a key that already exists is left alone.

    Size bounds: `max_entries` / `max_bytes` cap the store; `gc()`
    evicts least-recently-used entries (by meta.json mtime, which
    `get()` refreshes on every successful load) until both bounds hold.
    `put()` runs gc automatically when a bound is configured, so a
    long-lived store — the CI cache, a shared developer directory —
    cannot grow without limit. Unbounded by default.
    """

    def __init__(self, root, *, max_entries: int | None = None,
                 max_bytes: int | None = None, tuner=None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        if max_bytes is not None and max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        self.root = Path(root).expanduser()
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        # Rebuilding a tuned=true callable re-invokes its backend, which
        # consults this tuner's store — a warm-started artifact must not
        # re-measure block sizes the first process already searched.
        self.tuner = tuner
        self._tel = telemetry.get_registry()
        scope = telemetry.new_scope("store")
        self._c_saves = self._tel.counter(
            "netgen_store_saves_total", store=scope)
        self._c_loads = self._tel.counter(
            "netgen_store_loads_total", store=scope)
        self._c_misses = self._tel.counter(
            "netgen_store_misses_total", store=scope)
        self._c_corrupt = self._tel.counter(
            "netgen_store_corrupt_total", store=scope)
        self._c_gc = self._tel.counter(
            "netgen_store_gc_evictions_total", store=scope)
        self._h_load = self._tel.histogram(
            "netgen_store_load_seconds", store=scope)

    @property
    def stats(self) -> StoreStats:
        """Snapshot of the store's counters (atomic; safe to read while
        other threads load/put)."""
        return StoreStats(
            saves=int(self._c_saves.value),
            loads=int(self._c_loads.value),
            misses=int(self._c_misses.value),
            corrupt=int(self._c_corrupt.value),
            gc_evictions=int(self._c_gc.value),
            load_seconds=float(self._h_load.sum))

    def _dir(self, key: str) -> Path:
        return self.root / key

    def __contains__(self, key: str) -> bool:
        return (self._dir(key) / "meta.json").exists()

    def __len__(self) -> int:
        return sum(1 for _ in self.keys())

    def keys(self) -> list[str]:
        return sorted(
            p.name for p in self.root.iterdir()
            if (p / "meta.json").exists())

    def put(self, artifact: Artifact) -> None:
        """Persist one artifact under its content address (atomic; a
        concurrent writer of the same key wins harmlessly)."""
        final = self._dir(artifact.key)
        if (final / "meta.json").exists():
            return
        tmp = self.root / f".tmp-{artifact.key[:16]}-{uuid.uuid4().hex[:8]}"
        tmp.mkdir()
        try:
            meta = {
                "format": _FORMAT,
                "digest": artifact.digest,
                "pipeline": artifact.pipeline,
                "target": artifact.target,
                "kind": artifact.kind,
                "pass_stats": [
                    {"name": s.name,
                     "before": s.before.as_dict(),
                     "after": s.after.as_dict()}
                    for s in artifact.pass_stats],
                "cost": artifact.cost.as_dict(),
                "timings": artifact.timings,
                "plan_form": artifact.plan_form,
                "analysis": artifact.analysis,
                "created_unix": time.time(),
            }
            if artifact.kind == "text":
                (tmp / "artifact.txt").write_text(artifact.artifact)
            elif artifact.kind == "report":
                meta["cost_report"] = artifact.artifact.as_dict()
            buf = io.BytesIO()
            np.savez_compressed(buf, **circuit_to_arrays(artifact.circuit))
            (tmp / "circuit.npz").write_bytes(buf.getvalue())
            with open(tmp / "meta.json", "w") as f:
                json.dump(meta, f, indent=1)
            try:
                os.rename(tmp, final)
            except OSError:
                if not (final / "meta.json").exists():
                    raise
                shutil.rmtree(tmp, ignore_errors=True)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._c_saves.inc()
        if self.max_entries is not None or self.max_bytes is not None:
            self.gc()

    def gc(self) -> list[str]:
        """Evict least-recently-used entries until the configured
        size/count bounds hold; returns the evicted keys (oldest
        first). Recency is meta.json mtime — refreshed by `get()` —
        so a warm-started artifact outlives a never-reused one. A
        no-op (empty list) when no bound is configured."""
        if self.max_entries is None and self.max_bytes is None:
            return []
        entries = []                 # (mtime, key, bytes)
        for p in self.root.iterdir():
            if p.name.startswith(".tmp-"):
                continue             # an in-flight put(), not an entry
            meta = p / "meta.json"
            try:
                mtime = meta.stat().st_mtime
                size = sum(
                    f.stat().st_size for f in p.iterdir() if f.is_file())
            except OSError:
                continue             # concurrently evicted mid-scan
            entries.append((mtime, p.name, size))
        entries.sort()
        count = len(entries)
        total = sum(size for _, _, size in entries)
        evicted: list[str] = []
        while entries and (
                (self.max_entries is not None and count > self.max_entries)
                or (self.max_bytes is not None and total > self.max_bytes)):
            _, key, size = entries.pop(0)
            shutil.rmtree(self._dir(key), ignore_errors=True)
            evicted.append(key)
            count -= 1
            total -= size
        self._c_gc.inc(len(evicted))
        return evicted

    def get(self, key: str) -> Artifact | None:
        """Load and rebuild the artifact stored under `key` (None when
        absent). Rebuilding a callable target re-invokes only the
        backend on the already-optimized circuit. A corrupt or
        unreadable entry (truncated JSON, bad npz, stale format) is
        treated as a miss and evicted from disk, so the caller falls
        back to a recompile whose `put` re-creates it — a cache tier
        must never turn bit-rot into a hard failure."""
        d = self._dir(key)
        meta_path = d / "meta.json"
        if not meta_path.exists():
            self._c_misses.inc()
            return None
        t0 = time.perf_counter()
        with self._tel.span("netgen.store.load", key=key[:12]) as sp:
            try:
                art = self._load(d, key)
            except Exception:
                shutil.rmtree(d, ignore_errors=True)
                self._c_corrupt.inc()
                self._c_misses.inc()
                sp.set_attr("outcome", "corrupt")
                return None
            sp.set_attr("outcome", "hit" if art is not None else "miss")
        if art is None:
            self._c_misses.inc()
            return None
        dt = time.perf_counter() - t0
        art.timings["load_s"] = dt
        self._c_loads.inc()
        self._h_load.observe(dt)
        try:
            os.utime(meta_path)      # refresh LRU recency for gc()
        except OSError:
            pass
        return art

    def _load(self, d: Path, key: str) -> Artifact | None:
        with open(d / "meta.json") as f:
            meta = json.load(f)
        if meta.get("format") != _FORMAT:
            return None
        with np.load(d / "circuit.npz") as z:
            circuit = circuit_from_arrays(z)
        tgt, opts = resolve_target(meta["target"])
        if meta["kind"] == "text":
            raw = (d / "artifact.txt").read_text()
        elif meta["kind"] == "report":
            raw = CostReport.from_dict(meta["cost_report"])
        else:
            if tgt.wants_tuner:
                opts = {**opts, "_tuner": self.tuner}
            raw = tgt.compile(circuit, **opts)
            # a tuned=true rebuild may legitimately pick a different
            # datapath than the original process (different device kind,
            # evicted tuning record): trust what was actually built over
            # the stored meta, or plan() would describe the wrong form
            meta["plan_form"] = getattr(raw, "plan_form",
                                        meta.get("plan_form"))
        stats = tuple(
            PassStats(name=s["name"],
                      before=CircuitOps(**s["before"]),
                      after=_ops_from_dict(s["after"]))
            for s in meta["pass_stats"])
        cost = meta["cost"]
        return Artifact(
            digest=meta["digest"],
            pipeline=meta["pipeline"],
            target=meta["target"],
            kind=meta["kind"],
            key=key,
            circuit=circuit,
            pass_stats=stats,
            cost=CellCounts(
                **{k: v for k, v in cost.items() if k != "total"}),
            timings=dict(meta["timings"]),
            source="store",
            artifact=raw,
            plan_form=meta.get("plan_form"),
            analysis=meta.get("analysis"),
        )


def _ops_from_dict(d: dict) -> CircuitOps:
    return CircuitOps(**d)


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------

def _shutdown_executor(executor) -> None:
    """weakref.finalize callback — module-level so the finalizer holds
    no reference back to the Session (which would keep it alive)."""
    executor.shutdown(wait=True, cancel_futures=True)


class Session:
    """The compiler's stateful front door: an in-memory LRU tier (the
    serving layer's `CompileCache`) over an optional persistent
    `ArtifactStore`, plus the kernel-tuning tier (`tune_store`) and a
    background compile queue (`compile_async`). `capacity=0` disables
    in-memory retention (every compile still reads/writes the store
    when one is configured). `tune_store` points `tuned=true` kernel
    builds at a persistent `repro.netgen.tune.TuneStore` directory;
    without it the process-wide in-memory tuner is used.

    Sessions are context managers (`with Session(...) as s:`); exiting
    calls `shutdown()`. A session that is simply dropped is safe too:
    the async executor is tied to the object with a weakref finalizer,
    so its worker threads are joined at GC or interpreter exit."""

    def __init__(self, *, store=None, capacity: int = 64, tune_store=None):
        from repro.netgen.serve import CacheCounters, CompileCache
        from repro.netgen.tune import KernelTuner, TuneStore
        if store is not None and not isinstance(store, ArtifactStore):
            store = ArtifactStore(store)
        self.store = store
        if tune_store is not None and not isinstance(tune_store, TuneStore):
            tune_store = TuneStore(tune_store)
        self.tuner = KernelTuner(store=tune_store) if tune_store is not None \
            else None
        if store is not None and self.tuner is not None \
                and store.tuner is None:
            # don't re-wire a shared store another session already
            # attached its tuner to — first configuration wins
            store.tuner = self.tuner
        self._executor = None
        self._executor_lock = threading.Lock()
        self._finalizer = None
        if capacity > 0:
            self.cache: "CompileCache | None" = CompileCache(
                capacity, store=store, tuner=self.tuner)
            self._counters = None
        else:
            self.cache = None
            self._counters = CacheCounters(telemetry.new_scope("session"))

    def compile(self, net, *, target="jnp", pipeline="default",
                input_threshold: int | None = None, **target_opts) -> Artifact:
        """Compile `net` for `target` under `pipeline`, reusing the
        memory tier and the store when they already hold the artifact."""
        if self.cache is not None:
            return self.cache.get_or_compile(
                net, backend=target, passes=pipeline,
                input_threshold=input_threshold, **target_opts)
        # uncached session: store tier only
        spec = PipelineSpec.coerce(pipeline)
        tgt, opts = resolve_target(target, target_opts)
        ws, thr = _extract_weights(net, input_threshold)
        digest = weights_digest(ws, thr)
        key = artifact_key(digest, spec, target_string(tgt, opts))
        self._counters.misses.inc()
        if self.store is not None:
            art = self.store.get(key)
            if art is not None:
                self._counters.store_hits.inc()
                return art
        t0 = time.perf_counter()
        try:
            art = compile_resolved(ws, thr, digest, spec, tgt, opts,
                                   tuner=self.tuner)
        except BaseException:
            self._counters.failures.inc()
            raise
        self._counters.compiles.inc()
        self._counters.compile_seconds.observe(time.perf_counter() - t0)
        if self.store is not None:
            self.store.put(art)
        return art

    def compile_async(self, net, *, target="jnp", pipeline="default",
                      input_threshold: int | None = None, **target_opts):
        """Queue `compile` on the session's background executor and
        return a `concurrent.futures.Future` resolving to the Artifact.

        The ROADMAP's session-level async compile queue: kick off the
        expensive specializations early (`handle = compile_async(...)`),
        keep serving, and by the time a `NetServer.register` asks for
        the same content it hits the warm memory tier / ArtifactStore
        instead of blocking on a cold compile. The queue is small and
        daemonic (two workers — compiles are CPU-bound passes, not I/O
        fan-out); `CompileCache` is thread-safe, so a concurrent sync
        compile of the same key coalesces rather than racing."""
        import concurrent.futures

        with self._executor_lock:
            if self._executor is None:
                self._executor = concurrent.futures.ThreadPoolExecutor(
                    max_workers=2, thread_name_prefix="netgen-compile")
                # the executor's workers are non-daemon threads; a
                # caller that forgets shutdown() must not hang (or leak
                # threads at) interpreter exit, so tie the executor's
                # lifetime to the Session object — weakref.finalize runs
                # both at GC and atexit
                self._finalizer = weakref.finalize(
                    self, _shutdown_executor, self._executor)
        return self._executor.submit(
            self.compile, net, target=target, pipeline=pipeline,
            input_threshold=input_threshold, **target_opts)

    def engine(self, *, target: str = "jnp", pipeline=None,
               slot_capacity: int = 256, warmup: bool = True,
               max_batch_delay: float = 0.002, max_queue_depth: int = 4096):
        """Build an async online `ServingEngine` over this session: the
        engine's `NetServer` compiles through this session's memory tier
        and persistent store, so `register` warm-starts from artifacts a
        previous process (or a `compile_async` kicked off earlier)
        already produced. See `repro.netgen.engine` for the admission /
        continuous-slot-batching semantics and the SLO knobs."""
        from repro.netgen.engine import ServingEngine

        return ServingEngine(
            session=self, target=target, pipeline=pipeline,
            slot_capacity=slot_capacity, warmup=warmup,
            max_batch_delay=max_batch_delay,
            max_queue_depth=max_queue_depth)

    def explore(self, net=None, *, nets=None, space=None,
                objective="latency", strategy: str = "anneal",
                budget: int = 24, seed: int = 0, batch: int = 256,
                reps: int = 2, cells_weight: float = 0.01,
                interpret: bool | None = None,
                input_threshold: int | None = None):
        """Jointly search pipeline x datapath x tile sizes for `net`
        (or a `nets` mapping — the ladder-depth axis) and return an
        `ExplorationReport` (see `repro.netgen.explore`).

        Every evaluation compiles through this session — artifacts land
        in the memory tier and the `ArtifactStore` — and the finished
        search persists through the session's `TuneStore`, so a second
        process with the same stores replays the exploration with zero
        compiles and zero measurements. The winner also publishes the
        `pallas-explored` datapath record `pallas[explored=true]` (and
        the serving layer's stacked dispatch) resolve by plan
        signature."""
        from repro.netgen.explore import Explorer

        return Explorer(
            self, net=net, nets=nets, space=space, objective=objective,
            strategy=strategy, budget=budget, seed=seed, batch=batch,
            reps=reps, cells_weight=cells_weight, interpret=interpret,
            input_threshold=input_threshold).run()

    def shutdown(self, wait: bool = True) -> None:
        """Stop the async compile executor (idempotent; queued compiles
        finish when `wait`)."""
        with self._executor_lock:
            executor, self._executor = self._executor, None
            finalizer, self._finalizer = self._finalizer, None
        if finalizer is not None:
            finalizer.detach()
        if executor is not None:
            executor.shutdown(wait=wait)

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, et, ev, tb) -> None:
        self.shutdown()

    def stats(self):
        """Hit/miss/compile counters (memory tier's when one exists)."""
        if self.cache is not None:
            return self.cache.stats()
        return self._counters.snapshot()

    def store_stats(self) -> StoreStats | None:
        return None if self.store is None else self.store.stats

    def tune_stats(self):
        """The tuner's hit/measurement counters (None without a
        tune_store; see `repro.netgen.tune.TuneStats`)."""
        return None if self.tuner is None else self.tuner.stats
