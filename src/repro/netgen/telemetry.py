"""`netgen.telemetry` — metrics, tracing, and profiling for the compiler.

The paper's central claim is a *measured* one (FPGA inference beats the
i7 software baseline), and every layer grown on top of the reproduction
— the compile cache, the artifact/tune stores, the stacked serving
dispatch — justifies itself with numbers. This module is the one place
those numbers live: a zero-dependency (stdlib-only), thread-safe
registry of

  Counter     monotonically increasing value (int or float seconds),
              atomic under its own lock — the backing store for every
              `*Stats` snapshot in the package (CacheStats, StoreStats,
              TuneStats, NetServer.dispatch_counts), so counters shared
              across threads can never lose increments.
  Gauge       last-written value (e.g. flops of a compiled artifact).
  Histogram   latency/occupancy observations with EXACT percentiles
              (nearest-rank p50/p95/p99 over a bounded window of the
              most recent observations; count/sum are all-time).
  Span        nested wall-clock trace spans with structured attributes.
              Parentage is per-thread (a thread-local stack), so spans
              opened on a worker thread root their own trace. Finished
              spans land in a bounded ring buffer.

Metrics are ALWAYS live — they are the package's stats backbone and
cost one lock + one add per update, invisible next to a kernel dispatch
— while *tracing* is opt-in: `enable()` turns span recording on,
`disable()` turns it back off, and a disabled `span()` returns a shared
no-op context, so the serving path pays ~nothing when nobody is
looking (asserted in `benchmarks/bench_netgen_serve.py`).

Exporters:

  report()           human table: every counter/gauge, histogram
                     count/mean/p50/p95/p99, span totals by name
  prometheus()       Prometheus text exposition (counters, gauges, and
                     summary-style histograms with quantile labels) —
                     point a scrape at a file or serve the string
  export_jsonl(path) one JSON object per finished span (trace_id /
                     span_id / parent_id / name / start / duration /
                     attrs) — `benchmarks/check_trace.py` gates CI on
                     the invariants of this file
  summary()          a JSON-stable dict of everything, folded into
                     `BENCH_netgen.json` by `benchmarks/run.py`

Profiling hook: `jit_cost(fn, shape)` lowers a jitted callable at a
sample shape and returns XLA's cost analysis (flops / bytes accessed)
— the roofline inputs for a compiled artifact. jax is imported lazily
and every failure degrades to None; with `enable(profile=True)` the
Session driver records it per compiled artifact automatically
(`Artifact.timings["cost_analysis"]`, plus flops/bytes gauges).

Instrumented span tree (what a trace of one request lifecycle nests):

    netgen.compile          target, pipeline, digest
      netgen.lower
      netgen.pipeline       pipeline string
        netgen.pass         per pass: terms/nodes before -> after
      netgen.analysis       pre-backend range analysis + proof summary
      netgen.backend
    netgen.engine.batch     one formed batch (engine, versions, rows) —
                            opened on the batcher thread, so it roots
                            its own trace and parents the dispatch
      netgen.dispatch       path=single|stacked|sharded|fallback
        netgen.kernel       one per jitted call (slot round)
    netgen.store.load       artifact rebuilt from disk
    netgen.tune.search      candidates, winner, measure seconds
    netgen.explore          one design-space search (strategy,
                            objective, budget, best, pruned, measured)
                            — parents its evaluations' compile spans

Serving metrics: `netgen_predict_latency_seconds{server,version}`
records per-version SERVICE time and `netgen_requests_total` counts one
increment per dispatch call per version — `benchmarks/check_trace.py`
gates latency count == request count.
`netgen_kernel_launches_total{form}` counts Pallas kernel launches per
datapath form (`kernel_launches(form)` is the accessor backends use):
the per-layer chains record depth launches per call (times M for the
lax.map multi dispatch) while the fusednet megakernel records exactly
ONE per call — `benchmarks/check_trace.py` gates that every fusednet
`netgen.kernel` dispatch-round span carries launches == 1. The online engine
(`repro.netgen.engine`) adds, per `engine=` scope:
`netgen_engine_submitted/completed/batches_total`,
`netgen_engine_rejected_total{reason=queue_full|deadline|closed}`, the
`netgen_engine_queue_depth` gauge, and the
`netgen_engine_queue_wait_seconds` / `netgen_engine_batch_rows`
histograms — queue wait is recorded separately from service time, so
SLO analysis can split time-in-queue from time-on-kernel.

Static-analysis metrics (`repro.netgen.analysis`):
`netgen_verify_failures_total{phase=pipeline|compile}` counts invariant
violations the verifier observed (prod compiles count-and-continue;
strict mode raises instead — see NETGEN_VERIFY);
`netgen_tune_rejected_total{tuner}` counts tile candidates the tuner
skipped as statically illegal or duplicate kernels, without spending a
measurement; `netgen_stack_incompat_total{server,reason}` counts
version sets the NetServer diagnosed as unstackable, labelled with the
first failing check (e.g. stack.depth, stack.classes, stack.build).

Design-space explorer metrics (`repro.netgen.explore`), per
`explorer=` scope: `netgen_explore_candidates_total` (unique points
considered) == `netgen_explore_pruned_total` (rejected pre-measurement
by the shared legality checks) + `netgen_explore_measured_total`
(objective evaluations), and `netgen_explore_artifacts_total` (the
store artifact backing each evaluation) == measured —
`benchmarks/check_trace.py` gates both identities.
`netgen_explore_accepted_total` counts acceptance-trace accepts and
`netgen_explore_replays_total` warm replays served from a persisted
record (zero measurements); `netgen_explored_resolved_total{outcome}`
counts `pallas[explored=true]` record lookups (hit / miss).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import math
import threading
import time
from collections import deque
from typing import Mapping

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "SpanRecord", "counter",
    "disable", "enable", "export_jsonl", "gauge", "get_registry",
    "histogram", "jit_cost", "kernel_launches", "new_scope", "prometheus",
    "report", "reset", "span", "summary", "timed",
]

_TRACE_FORMAT = "netgen-trace-v1"


# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic counter; `inc` is atomic (per-counter lock), so the
    `*Stats` mutation paths are race-free without their owners' locks."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: Mapping):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Last-written value (settable, also `add` for running levels)."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: Mapping):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def add(self, n=1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Histogram:
    """Observations with exact nearest-rank percentiles.

    The sample window is bounded (`window` most recent observations,
    default 65536) so a long-lived server cannot grow without limit;
    percentiles are exact over that window, `count`/`sum` are all-time.
    """

    __slots__ = ("name", "labels", "_lock", "_values", "_count", "_sum")

    def __init__(self, name: str, labels: Mapping, window: int = 65536):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._values: deque = deque(maxlen=window)
        self._count = 0
        self._sum = 0.0

    def observe(self, v) -> None:
        v = float(v)
        with self._lock:
            self._values.append(v)
            self._count += 1
            self._sum += v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile over the retained window;
        `q` in (0, 1] (0.5 -> p50). 0.0 on an empty histogram."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {q}")
        with self._lock:
            xs = sorted(self._values)
        if not xs:
            return 0.0
        return xs[max(math.ceil(q * len(xs)) - 1, 0)]

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p95(self) -> float:
        return self.percentile(0.95)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "p50": self.p50, "p95": self.p95, "p99": self.p99}

    def reset(self) -> None:
        with self._lock:
            self._values.clear()
            self._count = 0
            self._sum = 0.0


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span, as exported to the JSONL trace."""
    trace_id: int
    span_id: int
    parent_id: int | None
    name: str
    start_unix: float
    duration_s: float
    attrs: dict
    thread: str
    error: str | None = None

    def as_dict(self) -> dict:
        d = {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "start_unix": self.start_unix,
            "duration_s": self.duration_s,
            "attrs": self.attrs,
            "thread": self.thread,
        }
        if self.error is not None:
            d["error"] = self.error
        return d


class _NullSpan:
    """Shared no-op context returned while tracing is disabled: the hot
    path allocates nothing and `set_attr` vanishes."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return False

    def set_attr(self, key, value) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: context manager that records itself into the
    registry's ring buffer on exit. Parentage comes from the thread's
    span stack, so nesting follows lexical `with` structure per thread."""

    __slots__ = ("_reg", "name", "attrs", "trace_id", "span_id",
                 "parent_id", "start_unix", "_t0")

    def __init__(self, reg: "Registry", name: str, attrs: dict):
        self._reg = reg
        self.name = name
        self.attrs = attrs

    def set_attr(self, key, value) -> None:
        self.attrs[key] = value

    def __enter__(self):
        reg = self._reg
        self.span_id = reg._next_id()
        stack = reg._stack()
        if stack:
            parent = stack[-1]
            self.parent_id = parent.span_id
            self.trace_id = parent.trace_id
        else:
            self.parent_id = None
            self.trace_id = self.span_id
        stack.append(self)
        self.start_unix = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        duration = time.perf_counter() - self._t0
        stack = self._reg._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:              # exited out of order: still unwind
            stack.remove(self)
        self._reg._record(SpanRecord(
            trace_id=self.trace_id,
            span_id=self.span_id,
            parent_id=self.parent_id,
            name=self.name,
            start_unix=self.start_unix,
            duration_s=duration,
            attrs=dict(self.attrs),
            thread=threading.current_thread().name,
            error=None if et is None else et.__name__,
        ))
        return False


class _Timed:
    """`timed()` context: observes elapsed seconds into a histogram on
    exit and exposes it as `.elapsed` (what the benches read back)."""

    __slots__ = ("_hist", "_t0", "elapsed")

    def __init__(self, hist: Histogram):
        self._hist = hist
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        self.elapsed = time.perf_counter() - self._t0
        self._hist.observe(self.elapsed)
        return False


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

class Registry:
    """The metric + trace store. One process-wide instance
    (`get_registry()`) backs the whole package; tests may build their
    own. `enabled` gates tracing only — metrics are always live (see
    module doc). `profile` additionally asks the compile driver to run
    `jit_cost` on every compiled callable artifact."""

    def __init__(self, *, max_spans: int = 65536, hist_window: int = 65536):
        self._lock = threading.Lock()
        self._metrics: "dict[tuple, Counter | Gauge | Histogram]" = {}
        self._spans: deque = deque(maxlen=max_spans)
        self._hist_window = hist_window
        self._tls = threading.local()
        self._ids = itertools.count(1)
        self._id_lock = threading.Lock()
        self.enabled = False
        self.profile = False

    # -- internals -----------------------------------------------------------

    def _next_id(self) -> int:
        with self._id_lock:
            return next(self._ids)

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            self._spans.append(rec)

    @staticmethod
    def _key(kind: str, name: str, labels: Mapping) -> tuple:
        return (kind, name,
                tuple(sorted((k, str(v)) for k, v in labels.items())))

    def _metric(self, kind: str, name: str, labels: Mapping):
        key = self._key(kind, name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                labdict = dict(key[2])
                if kind == "counter":
                    m = Counter(name, labdict)
                elif kind == "gauge":
                    m = Gauge(name, labdict)
                else:
                    m = Histogram(name, labdict, window=self._hist_window)
                self._metrics[key] = m
            return m

    # -- metric accessors (get-or-create) ------------------------------------

    def counter(self, name: str, /, **labels) -> Counter:
        return self._metric("counter", name, labels)

    def gauge(self, name: str, /, **labels) -> Gauge:
        return self._metric("gauge", name, labels)

    def histogram(self, name: str, /, **labels) -> Histogram:
        return self._metric("histogram", name, labels)

    # -- tracing -------------------------------------------------------------

    def span(self, name: str, /, **attrs):
        """A nested trace span (no-op unless `enabled`); attributes are
        keyword arguments plus anything set via `set_attr` inside."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def timed(self, name: str, /, **labels) -> _Timed:
        """Time a block into `histogram(name, **labels)` — the one code
        path for bench timing loops AND production latency metrics."""
        return _Timed(self.histogram(name, **labels))

    def spans(self) -> list[SpanRecord]:
        with self._lock:
            return list(self._spans)

    # -- exporters -----------------------------------------------------------

    def _sorted_metrics(self) -> list:
        with self._lock:
            items = list(self._metrics.items())
        return sorted(items, key=lambda kv: (kv[0][1], kv[0][2]))

    def report(self) -> str:
        """Human-readable table of every metric plus span totals."""
        lines = []
        for (kind, name, _), m in self._sorted_metrics():
            label = _render_labels(m.labels)
            if kind == "histogram":
                s = m.snapshot()
                unit = 1e3 if name.endswith("_seconds") else 1.0
                suffix = " ms" if unit == 1e3 else ""
                lines.append(
                    f"histogram {name}{label}: count={s['count']} "
                    f"mean={s['mean'] * unit:.3g}{suffix} "
                    f"p50={s['p50'] * unit:.3g}{suffix} "
                    f"p95={s['p95'] * unit:.3g}{suffix} "
                    f"p99={s['p99'] * unit:.3g}{suffix}")
            else:
                v = m.value
                shown = f"{v:.6g}" if isinstance(v, float) else str(v)
                lines.append(f"{kind:9s} {name}{label}: {shown}")
        by_name: dict[str, list[float]] = {}
        for rec in self.spans():
            by_name.setdefault(rec.name, []).append(rec.duration_s)
        for name in sorted(by_name):
            durs = by_name[name]
            lines.append(
                f"span      {name}: n={len(durs)} "
                f"total={sum(durs) * 1e3:.3g} ms "
                f"max={max(durs) * 1e3:.3g} ms")
        return "\n".join(lines)

    def prometheus(self) -> str:
        """Prometheus text exposition: counters, gauges, and histograms
        as summaries (`quantile` labels + `_sum`/`_count`)."""
        out = []
        last_typed = None
        for (kind, name, _), m in self._sorted_metrics():
            if (kind, name) != last_typed:
                ptype = {"counter": "counter", "gauge": "gauge",
                         "histogram": "summary"}[kind]
                out.append(f"# TYPE {name} {ptype}")
                last_typed = (kind, name)
            if kind == "histogram":
                for q in (0.5, 0.95, 0.99):
                    lab = _render_labels({**m.labels, "quantile": q})
                    out.append(f"{name}{lab} {m.percentile(q):.9g}")
                lab = _render_labels(m.labels)
                out.append(f"{name}_sum{lab} {m.sum:.9g}")
                out.append(f"{name}_count{lab} {m.count}")
            else:
                lab = _render_labels(m.labels)
                v = m.value
                shown = f"{v:.9g}" if isinstance(v, float) else str(v)
                out.append(f"{name}{lab} {shown}")
        return "\n".join(out) + ("\n" if out else "")

    def export_jsonl(self, path) -> int:
        """Write every retained finished span as one JSON object per
        line; returns the number of spans written."""
        spans = self.spans()
        with open(path, "w") as f:
            for rec in spans:
                f.write(json.dumps(rec.as_dict(), sort_keys=True))
                f.write("\n")
        return len(spans)

    def summary(self) -> dict:
        """JSON-stable dict of everything (folded into BENCH_netgen.json)."""
        counters, gauges, hists = [], [], []
        for (kind, name, _), m in self._sorted_metrics():
            entry = {"name": name, "labels": m.labels}
            if kind == "counter":
                counters.append({**entry, "value": m.value})
            elif kind == "gauge":
                gauges.append({**entry, "value": m.value})
            else:
                hists.append({**entry, **m.snapshot()})
        return {"format": _TRACE_FORMAT, "counters": counters,
                "gauges": gauges, "histograms": hists,
                "spans_retained": len(self.spans())}

    # -- lifecycle -----------------------------------------------------------

    def reset(self) -> None:
        """Zero every metric in place (live component handles stay
        valid) and drop all retained spans. `enabled`/`profile` keep
        their values."""
        with self._lock:
            metrics = list(self._metrics.values())
            self._spans.clear()
        for m in metrics:
            m.reset()


def _render_labels(labels: Mapping) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{k}="{_escape(v)}"' for k, v in sorted(
            (k, str(v)) for k, v in labels.items()))
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


# ---------------------------------------------------------------------------
# Profiling hook (lazy jax)
# ---------------------------------------------------------------------------

def jit_cost(fn, shape, dtype="uint8") -> dict | None:
    """XLA cost analysis of a jitted callable at a sample input shape:
    {"flops", "bytes_accessed"} — the roofline inputs for one compiled
    artifact. Returns None whenever the callable cannot be lowered (a
    Python wrapper without `.lower`, no jax, analysis unsupported); a
    telemetry hook must never fail a compile."""
    try:
        import jax
        import numpy as np
        lowered = fn.lower(
            jax.ShapeDtypeStruct(tuple(int(d) for d in shape),
                                 np.dtype(dtype)))
        cost = lowered.compile().cost_analysis()
    except Exception:  # noqa: BLE001 — absent jax/lower/analysis all degrade
        return None
    if isinstance(cost, (list, tuple)):     # jax <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return None
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0))}


# ---------------------------------------------------------------------------
# Process-wide default registry + module-level convenience API
# ---------------------------------------------------------------------------

_REGISTRY = Registry()

_SCOPE_LOCK = threading.Lock()
_SCOPE_IDS: dict[str, int] = {}


def new_scope(prefix: str) -> str:
    """A process-unique instance label (`cache-0`, `server-3`, ...) so
    per-instance stats (two CompileCaches, say) never merge in the
    shared registry."""
    with _SCOPE_LOCK:
        n = _SCOPE_IDS.get(prefix, 0)
        _SCOPE_IDS[prefix] = n + 1
    return f"{prefix}-{n}"


def get_registry() -> Registry:
    return _REGISTRY


def enable(profile: bool = False) -> None:
    """Turn span tracing on (metrics are always live). `profile=True`
    additionally records `jit_cost` per compiled callable artifact."""
    _REGISTRY.enabled = True
    _REGISTRY.profile = bool(profile)


def disable() -> None:
    _REGISTRY.enabled = False
    _REGISTRY.profile = False


def counter(name: str, /, **labels) -> Counter:
    return _REGISTRY.counter(name, **labels)


def kernel_launches(form: str) -> Counter:
    """The per-datapath Pallas launch counter,
    `netgen_kernel_launches_total{form}` — backends increment it by the
    number of pallas_call launches one predictor call performs (depth
    per chain call, depth x M for the multi chain, exactly 1 for the
    fusednet megakernel)."""
    return _REGISTRY.counter("netgen_kernel_launches_total", form=form)


def gauge(name: str, /, **labels) -> Gauge:
    return _REGISTRY.gauge(name, **labels)


def histogram(name: str, /, **labels) -> Histogram:
    return _REGISTRY.histogram(name, **labels)


def span(name: str, /, **attrs):
    return _REGISTRY.span(name, **attrs)


def timed(name: str, /, **labels) -> _Timed:
    return _REGISTRY.timed(name, **labels)


def report() -> str:
    return _REGISTRY.report()


def prometheus() -> str:
    return _REGISTRY.prometheus()


def export_jsonl(path) -> int:
    return _REGISTRY.export_jsonl(path)


def summary() -> dict:
    return _REGISTRY.summary()


def reset() -> None:
    _REGISTRY.reset()
