"""`netgen.engine` — async online serving: admission queue + continuous
slot batching over the stacked multi-net dispatch.

The paper's whole argument is inference *throughput*: the FPGA wins
because it serves a stream of 28x28 classifications with no per-request
software overhead, while the CPU baseline pays dispatch costs per call
(PAPER.md §V). `NetServer` alone is still the CPU pattern — a caller
hands it a pre-formed batch. This module is the production front door
the ROADMAP's "serves millions of users" north star asks for: many
clients submit SINGLE requests; the engine amortizes dispatch across
them by forming slot blocks continuously.

    ServingEngine — owns (or builds) a `NetServer` and a single batcher
        thread. `submit(version, x)` enqueues one uint8 request and
        returns a `concurrent.futures.Future`; `infer` is the blocking
        convenience. The batcher performs *continuous slot formation*:
        it collects requests until some version fills a slot block
        (`slot_capacity` rows) or `max_batch_delay` elapses since the
        first undispatched request — whichever comes first — then
        serves the whole group through `NetServer.predict_many`, so
        stack-compatible versions ride ONE jitted multi-net dispatch
        per round and the engine reuses exactly the slot mechanics,
        stacked-fn cache, occupancy accounting, and per-version
        latency/request metrics of the batch API.

    SLO knobs — `max_batch_delay` trades p50 latency against batch
        fill; `max_queue_depth` bounds admission (a full queue REJECTS
        with `QueueFullError` instead of growing without bound — load
        shedding beats collapse); a per-request `deadline` rejects
        requests that expired while queued (`DeadlineExceededError` on
        the future) rather than burning kernel time on answers nobody
        is waiting for.

    Lifecycle — engines are context managers mirroring `Session`:
        exiting drains the queue (every accepted future resolves) and
        joins the batcher thread; `shutdown(drain=False)` fails pending
        futures with `EngineClosedError` instead. A dropped engine is
        reclaimed by a weakref finalizer, so no thread outlives it
        (same no-leak contract the PR-6 Session executor has).

Telemetry (all labelled `engine=<scope>`, alongside the server's own
`netgen_predict_latency_seconds` / `netgen_requests_total` /
`netgen_slot_occupancy`):

    netgen_engine_submitted_total / netgen_engine_completed_total
    netgen_engine_rejected_total{reason=queue_full|deadline|closed}
    netgen_engine_queue_depth          (gauge, post-admission)
    netgen_engine_queue_wait_seconds   (histogram, dequeue - enqueue)
    netgen_engine_batch_rows           (histogram, rows per dispatch)
    netgen.engine.batch                (span around each dispatch)

    engine = netgen.Session(store=...).engine(slot_capacity=256,
                                              max_batch_delay=0.002)
    with engine:
        engine.register("v1", qnet)
        fut = engine.submit("v1", image)        # (n_inputs,) uint8
        label = fut.result()
        label = engine.infer("v1", image)       # blocking convenience

`benchmarks/bench_netgen_engine.py` drives this with closed- and
open-loop (Poisson) load and reports p50/p99/throughput next to the
one-request-per-dispatch baseline.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.netgen import telemetry
from repro.netgen.serve import NetServer
from repro.netgen.session import _validate_batch
from repro.serve.slots import stack_requests

__all__ = [
    "DeadlineExceededError", "EngineClosedError", "EngineStats",
    "QueueFullError", "ServingEngine",
]


class QueueFullError(RuntimeError):
    """Admission rejected: the queue is at `max_queue_depth`."""


class DeadlineExceededError(TimeoutError):
    """The request's deadline elapsed while it waited in the queue."""


class EngineClosedError(RuntimeError):
    """Submitted to (or pending in) an engine that has shut down."""


@dataclasses.dataclass
class EngineStats:
    """Point-in-time snapshot of one engine's telemetry counters."""
    submitted: int = 0
    completed: int = 0
    rejected_queue_full: int = 0
    rejected_deadline: int = 0
    rejected_closed: int = 0
    batches: int = 0
    queue_depth: int = 0

    def row(self) -> str:
        return (f"engine: {self.submitted} submitted, {self.completed} "
                f"completed in {self.batches} batches, rejected "
                f"{self.rejected_queue_full} full / "
                f"{self.rejected_deadline} deadline / "
                f"{self.rejected_closed} closed, depth {self.queue_depth}")


class _Request:
    """One admitted request: payload, response future, and the queue
    timestamps the SLO knobs act on (absolute perf_counter times)."""

    __slots__ = ("version", "x", "future", "t_enqueue", "deadline")

    def __init__(self, version: str, x: np.ndarray,
                 deadline: float | None):
        self.version = version
        self.x = x
        self.future: Future = Future()
        self.t_enqueue = time.perf_counter()
        self.deadline = (None if deadline is None
                         else self.t_enqueue + float(deadline))


class _EngineCore:
    """Everything the batcher thread touches. Deliberately holds no
    reference to the `ServingEngine` wrapper: the thread keeps the core
    alive, the wrapper's weakref finalizer closes the core, so a
    dropped engine's thread exits instead of pinning it forever."""

    def __init__(self, server: NetServer, max_batch_delay: float,
                 max_queue_depth: int):
        self.server = server
        self.max_batch_delay = float(max_batch_delay)
        self.max_queue_depth = int(max_queue_depth)
        self.cv = threading.Condition()
        self.queue: "deque[_Request]" = deque()
        self.closed = False
        self.tel = telemetry.get_registry()
        self.scope = telemetry.new_scope("engine")
        self.c_submitted = self.tel.counter(
            "netgen_engine_submitted_total", engine=self.scope)
        self.c_completed = self.tel.counter(
            "netgen_engine_completed_total", engine=self.scope)
        self.c_batches = self.tel.counter(
            "netgen_engine_batches_total", engine=self.scope)
        self.c_rejected = {
            reason: self.tel.counter(
                "netgen_engine_rejected_total",
                engine=self.scope, reason=reason)
            for reason in ("queue_full", "deadline", "closed")}
        self.g_depth = self.tel.gauge(
            "netgen_engine_queue_depth", engine=self.scope)
        self.h_queue_wait = self.tel.histogram(
            "netgen_engine_queue_wait_seconds", engine=self.scope)
        self.h_batch_rows = self.tel.histogram(
            "netgen_engine_batch_rows", engine=self.scope)

    # -- batcher thread ------------------------------------------------------

    def loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            self._serve(batch)

    def _next_batch(self) -> "list[_Request] | None":
        """Continuous slot formation: block for the first request, then
        keep admitting until some version fills a slot block or
        `max_batch_delay` has elapsed — whichever first. Returns up to
        `slot_capacity` requests per version (FIFO; overflow stays
        queued for the next round) or None at drained shutdown."""
        cap = self.server.slot_capacity
        with self.cv:
            while not self.queue:
                if self.closed:
                    return None
                self.cv.wait(0.1)
            deadline_t = time.perf_counter() + self.max_batch_delay
            while not self.closed:
                counts: dict[str, int] = {}
                full = False
                for r in self.queue:
                    c = counts.get(r.version, 0) + 1
                    counts[r.version] = c
                    if c >= cap:
                        full = True
                        break
                remaining = deadline_t - time.perf_counter()
                if full or remaining <= 0:
                    break
                self.cv.wait(remaining)
            taken: list[_Request] = []
            kept: "deque[_Request]" = deque()
            counts = {}
            for r in self.queue:
                c = counts.get(r.version, 0)
                if c < cap:
                    counts[r.version] = c + 1
                    taken.append(r)
                else:
                    kept.append(r)
            self.queue = kept
            self.g_depth.set(len(kept))
            return taken

    def _serve(self, batch: "list[_Request]") -> None:
        """Dispatch one formed batch through the server's shared core.
        Expired deadlines are rejected here — after queueing, before
        kernel work — and a dispatch failure fails only this batch's
        futures, never the batcher thread."""
        now = time.perf_counter()
        live: list[_Request] = []
        for req in batch:
            self.h_queue_wait.observe(now - req.t_enqueue)
            if req.deadline is not None and now > req.deadline:
                self.c_rejected["deadline"].inc()
                if not req.future.cancelled():
                    req.future.set_exception(DeadlineExceededError(
                        f"deadline exceeded after "
                        f"{now - req.t_enqueue:.4f}s in queue"))
                continue
            if not req.future.set_running_or_notify_cancel():
                continue                     # caller cancelled while queued
            live.append(req)
        if not live:
            return
        by_version: "dict[str, list[_Request]]" = {}
        for req in live:
            by_version.setdefault(req.version, []).append(req)
        xs = {v: stack_requests([r.x for r in rs])
              for v, rs in by_version.items()}
        self.c_batches.inc()
        self.h_batch_rows.observe(len(live))
        try:
            with self.tel.span("netgen.engine.batch", engine=self.scope,
                               versions=len(xs), rows=len(live)):
                preds = self.server.predict_many(xs)
        except BaseException as e:  # noqa: BLE001 — fail batch, keep serving
            for req in live:
                req.future.set_exception(e)
            return
        for v, rs in by_version.items():
            for req, p in zip(rs, preds[v]):
                req.future.set_result(int(p))
        self.c_completed.inc(len(live))

    # -- shutdown ------------------------------------------------------------

    def close(self, drain: bool) -> "list[_Request]":
        """Mark closed; with drain the batcher finishes the queue, else
        the pending requests are returned for the caller to fail."""
        with self.cv:
            self.closed = True
            dropped: list[_Request] = []
            if not drain:
                dropped = list(self.queue)
                self.queue.clear()
                self.g_depth.set(0)
            self.cv.notify_all()
        return dropped


def _finalize_engine(core: _EngineCore, thread: threading.Thread) -> None:
    """weakref.finalize callback — module-level so it holds no reference
    back to the ServingEngine (which would keep it alive forever)."""
    core.close(drain=True)
    if thread.is_alive():
        thread.join(timeout=10.0)


class ServingEngine:
    """The async online front door over a `NetServer` (see module doc).

    Construction: pass an existing `server=`, or `session=` (plus
    `target=`/`pipeline=`) to build one over a `Session`'s compile
    tiers — `Session.engine(...)` is the one-liner. Register versions
    through `register` (delegates to the server; warmup runs before
    publication, so the engine never serves a cold predictor).
    """

    def __init__(self, server: NetServer | None = None, *, session=None,
                 target: str | None = None, pipeline=None,
                 slot_capacity: int = 256, warmup: bool = True,
                 max_batch_delay: float = 0.002,
                 max_queue_depth: int = 4096,
                 prefer_explored: bool = True):
        if max_batch_delay < 0:
            raise ValueError(
                f"max_batch_delay must be >= 0, got {max_batch_delay}")
        if max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {max_queue_depth}")
        if server is not None:
            if session is not None or target is not None \
                    or pipeline is not None:
                raise ValueError(
                    "pass server= OR session=/target=/pipeline=, not both")
        else:
            server = NetServer(
                session=session,
                target=target if target is not None else "jnp",
                pipeline=pipeline, slot_capacity=slot_capacity,
                warmup=warmup, prefer_explored=prefer_explored)
        self._core = _EngineCore(server, max_batch_delay, max_queue_depth)
        self._thread: threading.Thread | None = None
        self._finalizer = None

    # -- delegation to the server -------------------------------------------

    @property
    def server(self) -> NetServer:
        return self._core.server

    @property
    def scope(self) -> str:
        return self._core.scope

    @property
    def max_batch_delay(self) -> float:
        return self._core.max_batch_delay

    @property
    def max_queue_depth(self) -> int:
        return self._core.max_queue_depth

    def register(self, version: str, net):
        return self._core.server.register(version, net)

    def unregister(self, version: str) -> None:
        self._core.server.unregister(version)

    def versions(self) -> list[str]:
        return self._core.server.versions()

    # -- admission -----------------------------------------------------------

    def submit(self, version: str, x_uint8, *,
               deadline: float | None = None) -> Future:
        """Enqueue ONE request — a (n_inputs,) uint8 vector — for
        `version`; returns a Future resolving to the predicted class
        (int). `deadline` (seconds from now) rejects the request with
        `DeadlineExceededError` if it is still queued when it expires.
        Raises `QueueFullError` when admission is at `max_queue_depth`
        and `EngineClosedError` after shutdown."""
        x = np.asarray(x_uint8)
        compiled = self._core.server.compiled_for(version)  # KeyError early
        if x.ndim != 1:
            raise ValueError(
                f"submit takes one request of shape "
                f"({compiled.circuit.n_inputs},); got {x.shape} — use "
                f"NetServer.predict for pre-formed batches")
        _validate_batch(x[None, :], compiled.circuit.n_inputs)
        req = _Request(version, x, deadline)
        core = self._core
        with core.cv:
            if core.closed:
                core.c_rejected["closed"].inc()
                raise EngineClosedError("engine is shut down")
            if len(core.queue) >= core.max_queue_depth:
                core.c_rejected["queue_full"].inc()
                raise QueueFullError(
                    f"admission queue at max_queue_depth="
                    f"{core.max_queue_depth}")
            core.queue.append(req)
            core.g_depth.set(len(core.queue))
            self._ensure_thread()
            core.cv.notify()
        core.c_submitted.inc()
        return req.future

    def infer(self, version: str, x_uint8, *, deadline: float | None = None,
              timeout: float | None = None) -> int:
        """Blocking convenience: `submit(...).result(timeout)`."""
        return self.submit(version, x_uint8, deadline=deadline).result(
            timeout)

    def queue_depth(self) -> int:
        with self._core.cv:
            return len(self._core.queue)

    def stats(self) -> EngineStats:
        core = self._core
        return EngineStats(
            submitted=int(core.c_submitted.value),
            completed=int(core.c_completed.value),
            rejected_queue_full=int(core.c_rejected["queue_full"].value),
            rejected_deadline=int(core.c_rejected["deadline"].value),
            rejected_closed=int(core.c_rejected["closed"].value),
            batches=int(core.c_batches.value),
            queue_depth=self.queue_depth())

    # -- lifecycle -----------------------------------------------------------

    def _ensure_thread(self) -> None:
        # called under core.cv: first admission starts the batcher
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._core.loop,
                name=f"netgen-engine-{self._core.scope}", daemon=True)
            self._finalizer = weakref.finalize(
                self, _finalize_engine, self._core, self._thread)
            self._thread.start()

    def shutdown(self, *, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Stop the engine (idempotent). With `drain` (default) every
        already-accepted request is served before the batcher exits;
        otherwise pending futures fail with `EngineClosedError`.
        Further `submit` calls are rejected either way."""
        dropped = self._core.close(drain=drain)
        for req in dropped:
            self._core.c_rejected["closed"].inc()
            if not req.future.cancelled():
                req.future.set_exception(
                    EngineClosedError("engine shut down before dispatch"))
        if self._thread is not None:
            self._thread.join(timeout)
        if self._finalizer is not None:
            self._finalizer.detach()
            self._finalizer = None

    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, et, ev, tb) -> None:
        self.shutdown()
