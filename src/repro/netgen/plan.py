"""ExecutionPlan: the one circuit→tensor lowering shared by array backends.

Before this module, every array backend (`backends/jnp.py`,
`backends/pallas.py`, the fused path) independently re-extracted dense
weight matrices from the circuit IR and re-derived the layer structure.
`lower_circuit` centralizes that step: it turns an optimized *regular*
circuit into an explicit layer-structured tensor program — per-layer
weight matrices, the activation applied after each accumulation, the
input binarization threshold, and the final argmax — that backends
execute without ever looking at IR nodes again.

The plan has four orthogonal forms:

  dense    — per-layer int32 (fan_in, fan_out) matrices, activations as
             int8 {0,1} vectors. What the paper's arithmetic literally
             says; the jnp oracle executes this form.
  packed   — `plan.pack()`: every layer's fan_in axis is zero-padded up
             to a multiple of 32 so the ±1-weighted single-bit
             activations can travel as uint32 words (32 per lane) into
             `kernels.binary_matvec.binary_matmul_packed` — the TPU
             analogue of the paper's single-bit wires, 8x less
             activation traffic than int8. Zero-padding is exact: a
             padded activation bit is 0 and its weight row is zero.
  planes   — `plan.planes()`: the packed form with each layer's int32
             weight matrix additionally decomposed into signed binary
             bit-planes, w = sum_b 2^b (pos_plane_b - neg_plane_b),
             every plane packed 32-lanes-per-uint32 along fan_in
             (`decompose_planes`). The plane count is set by the
             layer's ACTUAL post-pass weight magnitude range (tiny for
             the paper's quantized nets), so both operands of
             `binary_matmul_planes` travel as bits — the paper's
             selected-addends idea taken to its packed conclusion: a
             P-plane layer moves 2P bits of weight per addend instead
             of 32, and the kernel accumulates via popcount over words.
  stacked  — `stack_plans([...])`: M compatible single-net plans joined
             along a leading model axis ((M, fan_in, fan_out) weights)
             for the serving layer's multi-net dispatch. Hidden widths
             may differ between versions (pruning is per-model): they
             are zero-padded to the per-layer maximum, exact under the
             strict step semantics (an all-zero column is an empty
             accumulator, step(0) = 0, and its outgoing row is
             zero-padded too). A stacked plan can then be packed or
             plane-decomposed (the plane count is the per-layer maximum
             over the stacked versions).

Backends declare which form they execute via target options
(`pallas[packed=true]`, `pallas[planes=true]`); the Session records the
compiled form on the `Artifact` (`artifact.plan_form`).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.netgen.graph import Circuit, as_layered_weights

__all__ = [
    "ExecutionPlan", "MegakernelView", "PlanLayer", "PACK_LANES",
    "decompose_planes", "lower_circuit", "stack_plans",
]

PACK_LANES = 32      # activations per uint32 word in the packed datapath

# Activation kinds a layer can apply to its accumulator vector.
STEP = "step"        # hidden layers: strict sign step, acc > 0 -> {0,1}
ARGMAX = "argmax"    # final layer: the class scores feed the argmax


@dataclasses.dataclass(frozen=True, eq=False)
class PlanLayer:
    """One dense layer of the tensor program.

    `weights` is int32 (fan_in, fan_out) — or (M, fan_in, fan_out) in a
    stacked plan. `activation` says what happens to the accumulator:
    "step" (hidden layers) or "argmax" (the final scores). In a packed
    plan the fan_in axis is padded to a PACK_LANES multiple and `words`
    holds the uint32 lane count (fan_in // 32); dense layers have
    `words` None. In the bit-plane form `pos_planes`/`neg_planes` hold
    the packed uint32 signed bit-planes ((P, words, fan_out), model
    axis leading when stacked) and `n_planes` the plane count P —
    `weights` stays populated as the decomposition's ground truth.
    """
    weights: np.ndarray
    activation: str
    words: int | None = None
    pos_planes: np.ndarray | None = None
    neg_planes: np.ndarray | None = None
    n_planes: int | None = None

    @property
    def fan_in(self) -> int:
        return self.weights.shape[-2]

    @property
    def fan_out(self) -> int:
        return self.weights.shape[-1]


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """A complete layer-structured tensor program for one (or M stacked)
    circuit(s): binarize uint8 inputs against `input_threshold`, run the
    layers in order, return the final layer's argmax. See module doc for
    the dense/packed/stacked forms."""
    n_inputs: int
    input_threshold: int
    layers: tuple[PlanLayer, ...]
    packed: bool = False
    bitplanes: bool = False          # packed + plane-decomposed weights
    n_models: int | None = None      # None: single net; M: stacked plans

    @property
    def depth(self) -> int:
        return len(self.layers)

    @property
    def stacked(self) -> bool:
        return self.n_models is not None

    @property
    def form(self) -> str:
        """The datapath form an executor of this plan implements —
        recorded on Artifacts and shown in benchmarks."""
        if self.bitplanes:
            return "planes"
        return "packed" if self.packed else "dense"

    @property
    def n_classes(self) -> int:
        return self.layers[-1].fan_out

    def describe(self) -> str:
        shape = "x".join(str(l.fan_out) for l in self.layers)
        stacked = f"{self.n_models}x" if self.stacked else ""
        return f"{stacked}{self.n_inputs}-{shape} ({self.form})"

    def verify(self, *, collect: bool = False):
        """Certify the plan's invariants via `repro.netgen.analysis
        .verify_plan`: layer chain shape agreement, packed lane-padding
        exactness (padding rows all zero), bit-plane decomposition
        losslessness, int32 kernel-accumulation safety at the actual
        fan-in. Raises `analysis.VerificationError` on a violation;
        `collect=True` returns the diagnostics instead."""
        from repro.netgen.analysis import verify_plan
        return verify_plan(self, collect=collect)

    # -- form conversions ----------------------------------------------------

    def pack(self) -> "ExecutionPlan":
        """The packed form of this plan: every layer's fan_in axis
        zero-padded to a PACK_LANES multiple so activations travel as
        uint32 words (see module doc; exact by construction)."""
        if self.packed:
            return self
        layers = []
        for layer in self.layers:
            k = layer.fan_in
            kp = -(-k // PACK_LANES) * PACK_LANES if k else 0
            w = layer.weights
            if kp != k:
                pad = [(0, 0)] * w.ndim
                pad[-2] = (0, kp - k)
                w = np.pad(w, pad)
            layers.append(dataclasses.replace(
                layer, weights=w, words=kp // PACK_LANES))
        return dataclasses.replace(
            self, layers=tuple(layers), packed=True)

    def planes(self) -> "ExecutionPlan":
        """The fully bit-packed form: the packed plan with every layer's
        weight matrix decomposed into packed signed bit-planes (see
        module doc; exact — `decompose_planes` reconstructs the int32
        matrix bit for bit). The plane count is per layer, from that
        layer's actual post-pass weight magnitude range."""
        if self.bitplanes:
            return self
        base = self.pack()
        layers = []
        for layer in base.layers:
            pos, neg, n_planes = decompose_planes(layer.weights)
            layers.append(dataclasses.replace(
                layer, pos_planes=pos, neg_planes=neg, n_planes=n_planes))
        return dataclasses.replace(
            base, layers=tuple(layers), bitplanes=True)

    def megakernel_view(self) -> "MegakernelView":
        """The whole-net megakernel's flattened view of this plan: the
        planes form with each hidden layer's fan_out zero-padded up to
        the NEXT layer's word width (N_l == W_{l+1} * 32), so the
        in-kernel step+repack between layers is a pure reshape with no
        bit shuffling. Zero-width layers are padded to one zero word.
        Padding is exact under strict-step semantics: a padded
        accumulator column is 0, step(0) = 0, and the padded bit lands
        in a zero-padded weight word of the next layer (zero popcount).
        The final layer's fan_out is NOT padded — `n_classes` bounds
        the fused argmax so a phantom class can never win."""
        plan = self.planes()
        if plan.n_classes < 1:
            raise ValueError("megakernel_view needs at least one class")
        depth = plan.depth
        arrays: list[np.ndarray] = []
        layer_words, layer_planes, layer_fan_out = [], [], []
        want_w: int | None = None
        for i, layer in enumerate(plan.layers):
            hidden = i < depth - 1
            w_target = max(1, layer.words) if want_w is None else want_w
            n = layer.fan_out
            n_target = (max(1, -(-n // PACK_LANES)) * PACK_LANES
                        if hidden else n)

            def _padded(a: np.ndarray) -> np.ndarray:
                pw = w_target - a.shape[-2]
                pn = n_target - a.shape[-1]
                if pw or pn:
                    pad = [(0, 0)] * a.ndim
                    pad[-2], pad[-1] = (0, pw), (0, pn)
                    a = np.pad(a, pad)
                return np.ascontiguousarray(a)

            arrays += [_padded(layer.pos_planes), _padded(layer.neg_planes)]
            layer_words.append(w_target)
            layer_planes.append(int(layer.n_planes))
            layer_fan_out.append(n)
            want_w = n_target // PACK_LANES if hidden else None
        return MegakernelView(
            n_inputs=plan.n_inputs,
            input_threshold=plan.input_threshold,
            n_classes=plan.n_classes,
            n_models=plan.n_models,
            layer_words=tuple(layer_words),
            layer_planes=tuple(layer_planes),
            layer_fan_out=tuple(layer_fan_out),
            arrays=tuple(arrays))


@dataclasses.dataclass(frozen=True, eq=False)
class MegakernelView:
    """Shape-generic metadata + flat plane arrays for the whole-net
    megakernel (`kernels.binary_matvec.binary_forward_planes`): per-layer
    word widths / plane counts / TRUE (unpadded) fan_outs, and the
    interleaved (pos_0, neg_0, pos_1, neg_1, ...) uint32 plane arrays —
    (P_l, W_l, N_l) each, leading model axis when stacked — already
    padded so consecutive layers chain by construction."""
    n_inputs: int
    input_threshold: int
    n_classes: int
    n_models: int | None
    layer_words: tuple[int, ...]
    layer_planes: tuple[int, ...]
    layer_fan_out: tuple[int, ...]
    arrays: tuple[np.ndarray, ...]

    @property
    def depth(self) -> int:
        return len(self.layer_words)

    @property
    def stacked(self) -> bool:
        return self.n_models is not None

    def vmem_bytes(self, *, bm: int, bkw: int | None = None) -> int:
        """Estimated per-grid-step VMEM residency: every layer's plane
        arrays (one model's worth when stacked) + the input tile + the
        peak per-layer working set (popcount temporaries bounded by the
        `bkw` word chunk, accumulator, activation words). The legality
        check in `repro.netgen.analysis` holds this under the VMEM
        budget before a tuner candidate is ever measured."""
        models = self.n_models or 1
        weight = sum(a.size * 4 for a in self.arrays) // models
        x_tile = bm * self.n_inputs
        peak = 0
        for li, (w, _p) in enumerate(zip(self.layer_words,
                                         self.layer_planes)):
            n = (self.layer_words[li + 1] * PACK_LANES
                 if li + 1 < self.depth else self.layer_fan_out[li])
            ck = min(bkw, w) if bkw else w
            work = 2 * bm * ck * n * 4 + bm * n * 4 + bm * w * 4
            peak = max(peak, work)
        return weight + x_tile + peak + bm * 4


def decompose_planes(w: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Decompose an int32 weight matrix (..., K, N) with K a PACK_LANES
    multiple into packed signed bit-planes:

        w = sum_b 2^b (unpack(pos[..., b, :, :]) - unpack(neg[..., b, :, :]))

    Returns (pos, neg, n_planes): uint32 arrays of shape
    (..., P, K // 32, N) — bit i of word j along the packed axis holds
    plane bit (32*j + i) — and P = bit_length(max |w|) (>= 1, so an
    all-zero layer still has a well-formed single zero plane). Positive
    and negative magnitudes get separate planes; a weight is never in
    both."""
    k, n = w.shape[-2], w.shape[-1]
    if k % PACK_LANES:
        raise ValueError(
            f"fan_in {k} is not a multiple of {PACK_LANES}; pack() first")
    mag = np.abs(w)
    n_planes = max(1, int(mag.max(initial=0)).bit_length())
    lead = w.shape[:-2]
    words = k // PACK_LANES
    shifts = np.arange(PACK_LANES, dtype=np.uint32)

    def pack_mag(m: np.ndarray) -> np.ndarray:
        planes = []
        for b in range(n_planes):
            bits = ((m >> np.uint32(b)) & np.uint32(1))
            r = bits.reshape(*lead, words, PACK_LANES, n)
            planes.append(np.bitwise_or.reduce(
                r << shifts[:, None], axis=-2))
        return np.stack(planes, axis=-3)          # (..., P, words, N)

    pos = pack_mag(np.maximum(w, 0).astype(np.uint32))
    neg = pack_mag(np.maximum(-w, 0).astype(np.uint32))
    return pos, neg, n_planes


_FORMS = ("dense", "packed", "planes")


def lower_circuit(circuit: Circuit, *, packed: bool = False,
                  form: str | None = None) -> ExecutionPlan:
    """Lower a *regular* optimized circuit into an ExecutionPlan — the
    single weight-extraction step every array backend compiles through.
    `form` picks the datapath ("dense" / "packed" / "planes"; the
    legacy `packed=True` flag means form="packed"). Raises
    IrregularCircuitError for shared/CSE circuits (which have no
    layered tensor form; see `graph.as_layered_weights`)."""
    if form is None:
        form = "packed" if packed else "dense"
    if form not in _FORMS:
        raise ValueError(f"unknown plan form {form!r} (have {_FORMS})")
    mats = as_layered_weights(circuit)
    layers = tuple(
        PlanLayer(weights=np.asarray(w, dtype=np.int32),
                  activation=STEP if i < len(mats) - 1 else ARGMAX)
        for i, w in enumerate(mats))
    plan = ExecutionPlan(
        n_inputs=circuit.n_inputs,
        input_threshold=circuit.input_threshold,
        layers=layers)
    if form == "packed":
        return plan.pack()
    if form == "planes":
        return plan.planes()
    return plan


def stack_plans(plans: Sequence[ExecutionPlan]) -> ExecutionPlan:
    """Join M compatible single-net dense plans along a leading model
    axis for the multi-net dispatch. Versions must agree on depth, input
    width, class count, and input threshold; hidden widths are
    zero-padded to the per-layer maximum (exact — see module doc).
    Pack *after* stacking (`stack_plans(plans).pack()`): padding hidden
    widths changes the lane count."""
    if not plans:
        raise ValueError("no plans to stack")
    if any(p.packed or p.stacked for p in plans):
        raise ValueError(
            "stack_plans takes dense single-net plans; pack after stacking")

    depths = {p.depth for p in plans}
    if len(depths) != 1:
        raise ValueError(f"versions disagree on depth: {sorted(depths)}")
    thrs = {p.input_threshold for p in plans}
    if len(thrs) != 1:
        raise ValueError(
            f"versions disagree on input threshold: {sorted(thrs)}")
    n_ins = {p.n_inputs for p in plans}
    if len(n_ins) != 1:
        raise ValueError(
            f"versions disagree on input width: {sorted(n_ins)}")
    n_outs = {p.n_classes for p in plans}
    if len(n_outs) != 1:
        # class counts cannot be padded: an extra constant-0 class could
        # win the argmax when every real score is negative
        raise ValueError(
            f"versions disagree on class count: {sorted(n_outs)}")

    depth = depths.pop()
    mats = [[l.weights for l in p.layers] for p in plans]
    for layer in range(depth - 1):
        width = max(m[layer].shape[1] for m in mats)
        for m in mats:
            have = m[layer].shape[1]
            if have < width:
                m[layer] = np.pad(m[layer], ((0, 0), (0, width - have)))
                m[layer + 1] = np.pad(
                    m[layer + 1], ((0, width - have), (0, 0)))
    layers = tuple(
        PlanLayer(
            weights=np.stack([m[layer] for m in mats]).astype(np.int32),
            activation=STEP if layer < depth - 1 else ARGMAX)
        for layer in range(depth))
    return ExecutionPlan(
        n_inputs=n_ins.pop(),
        input_threshold=thrs.pop(),
        layers=layers,
        n_models=len(plans))
