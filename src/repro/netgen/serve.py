"""Compile-cache serving of netgen-specialized predictors.

The paper's economics (§IV-§V) are compile-per-model-then-serve: the
expensive step is specializing a trained net into a fixed circuit; the
cheap step is running it. This module makes that split operational, the
ROADMAP's "Serving specialized programs" item:

  CompileCache — the in-memory tier of the Session API. The key is the
      sha256 digest of the quantized weights + input threshold
      (`repro.core.quantize.weights_digest`) crossed with the canonical
      `PipelineSpec` and `Target` strings. A hit returns the *same*
      `Artifact` object that was compiled before; a miss consults the
      optional persistent `ArtifactStore` (so a second process
      warm-starts without recompiling), then compiles, records
      wall-clock compile time, persists, and LRU-evicts past a fixed
      capacity. Thread-safe: the lock covers lookup/insert only, a
      per-key in-flight future coalesces concurrent requests for the
      same key onto one compile, and compiles on unrelated keys never
      block each other (no head-of-line blocking).

  NetServer — a multi-version predictor server in the style of
      `repro.serve.engine`: fixed-capacity slot batching (one live jit
      trace per model), per-request routing by version name, and
      *cross-model* batching: versions whose circuits lower to
      compatible ExecutionPlans are stacked along a model axis
      (`repro.netgen.plan.stack_plans`) and served by one jitted
      multi-net dispatch (the target's `compile_multi` form, with the
      server's declared target options — interpret, packed — forwarded
      through the registry) — M versions, one XLA call. For the
      bit-plane datapath (`pallas[planes=true]` / `fusednet=true`) the
      stacked dispatch is the whole-net megakernel: one persistent
      Pallas launch per dispatch round for all M versions and every
      layer, recorded on the `netgen.kernel` span (form/launches) and
      in `netgen_kernel_launches_total{form}`. When a device
      mesh with a data axis is active (`repro.parallel.sharding
      .use_mesh`), the stacked dispatch additionally shards its slot
      (batch) dimension across the mesh with `shard_map` — the
      predictions of a slot block are row-independent, so each device
      serves `slot_capacity / n_data` rows of every version — and
      falls back to the single-device dispatch when no mesh is active,
      the mesh has no data axis, or the capacity does not divide.
      A NetServer can be built over a `Session`
      (`NetServer(session=Session(store=...))`) to share its memory
      tier and persistent store, or over legacy backend/passes/cache
      keywords.

Hidden-width padding used for stacking is exact: a zero-padded column is
an empty accumulator, and under the strict step semantics step(0) = 0,
so padded units contribute nothing downstream (their outgoing rows are
zero-padded too).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core.quantize import weights_digest
from repro.netgen import telemetry
from repro.netgen.backends import compile_multi
from repro.netgen.frontend import _extract_weights
from repro.netgen.graph import Circuit, IrregularCircuitError
from repro.netgen.pipeline import PipelineSpec
from repro.netgen.plan import lower_circuit, stack_plans
from repro.netgen.session import (
    Artifact, ArtifactStore, _validate_batch, artifact_key, compile_resolved,
)
from repro.netgen.targets import resolve_target, target_string
from repro.serve.slots import pad_slots

__all__ = [
    "CacheCounters", "CacheKey", "CacheStats", "CompileCache",
    "DEFAULT_CACHE", "NetServer", "cached_compile_net",
    "stack_layered_weights",
]


# ---------------------------------------------------------------------------
# Content-addressed compile cache
# ---------------------------------------------------------------------------

def _pass_fingerprint(p) -> str:
    """Canonical spec item for one pass callable (registry name plus
    bracketed options, e.g. `cse[budget=2]`). `functools.partial` of a
    registered pass maps its bound keywords back to declared options, so
    a budgeted variant does not alias the unbudgeted one.

    Lambdas and closures are refused (by `PipelineSpec.from_passes`):
    their qualified name does not cover their captured state, so two
    different ones would alias to the same key and the cache would hand
    back a predictor compiled with the OTHER pipeline. Spell
    parameterized passes declaratively (`"cse[budget=5]"`) or as
    functools.partial of a registered module-level function.
    """
    return PipelineSpec.from_passes([p]).spec_string()


@dataclasses.dataclass(frozen=True)
class CacheKey:
    """What a compiled predictor is a function of: weight content digest,
    target name, canonical pipeline spec, and target options."""
    digest: str
    backend: str
    passes: str
    opts: tuple


@dataclasses.dataclass
class CacheStats:
    """Point-in-time snapshot of a compile tier's counters (see
    `CacheCounters` for the live, atomic backing metrics)."""
    hits: int = 0              # memory-tier hits
    misses: int = 0            # memory-tier misses (store hit OR compile)
    evictions: int = 0
    compile_seconds: float = 0.0   # total wall-clock spent compiling
    compiles: int = 0          # actual full compilations
    store_hits: int = 0        # misses served by the persistent store
    load_seconds: float = 0.0  # wall-clock spent loading from the store
    failures: int = 0          # misses whose compile raised (verify/backend)

    def row(self) -> str:
        return (f"cache: {self.hits} hits, {self.misses} misses "
                f"({self.store_hits} from store, {self.failures} failed), "
                f"{self.evictions} evictions, "
                f"{self.compile_seconds * 1e3:.1f} ms compiling, "
                f"{self.load_seconds * 1e3:.1f} ms loading")


class CacheCounters:
    """The live telemetry metrics behind one compile tier's `CacheStats`
    — atomic `telemetry.Counter`s plus two duration histograms, labelled
    with a process-unique `cache=` scope so two tiers never merge in the
    shared registry. `CompileCache` and the uncached `Session` path both
    mutate these (increments are race-free without the owner's lock);
    `snapshot()` is the dataclass read API everything else consumes."""

    __slots__ = ("scope", "hits", "misses", "evictions", "compiles",
                 "store_hits", "failures", "compile_seconds", "load_seconds")

    def __init__(self, scope: str | None = None,
                 registry: "telemetry.Registry | None" = None):
        tel = registry if registry is not None else telemetry.get_registry()
        self.scope = scope if scope is not None else telemetry.new_scope(
            "cache")
        self.hits = tel.counter("netgen_cache_hits_total", cache=self.scope)
        self.misses = tel.counter(
            "netgen_cache_misses_total", cache=self.scope)
        self.evictions = tel.counter(
            "netgen_cache_evictions_total", cache=self.scope)
        self.compiles = tel.counter(
            "netgen_cache_compiles_total", cache=self.scope)
        self.store_hits = tel.counter(
            "netgen_cache_store_hits_total", cache=self.scope)
        # Misses that ended in a raised compile (e.g. a VerificationError
        # from the pre-backend analysis): the third leg of the identity
        # misses == compiles + store_hits + failures that the CI metrics
        # gate (benchmarks/check_trace.py) holds per cache scope.
        self.failures = tel.counter(
            "netgen_cache_compile_failures_total", cache=self.scope)
        self.compile_seconds = tel.histogram(
            "netgen_cache_compile_seconds", cache=self.scope)
        self.load_seconds = tel.histogram(
            "netgen_cache_load_seconds", cache=self.scope)

    def snapshot(self) -> CacheStats:
        return CacheStats(
            hits=int(self.hits.value),
            misses=int(self.misses.value),
            evictions=int(self.evictions.value),
            compiles=int(self.compiles.value),
            store_hits=int(self.store_hits.value),
            failures=int(self.failures.value),
            compile_seconds=float(self.compile_seconds.sum),
            load_seconds=float(self.load_seconds.sum))


class _InFlight:
    """One in-progress compile: waiters block on the event instead of on
    the cache lock, so a cold compile of key A never serializes hits (or
    other compiles) on unrelated keys behind it."""

    __slots__ = ("event", "error")

    def __init__(self):
        self.event = threading.Event()
        self.error: BaseException | None = None


class CompileCache:
    """LRU-bounded, thread-safe, content-addressed compile cache — the
    in-memory tier over an optional persistent `ArtifactStore`.

    Compiles run OUTSIDE the cache lock: the lock covers only lookup and
    insert, while a per-key in-flight future makes concurrent requests
    for the same key coalesce onto one compile. Requests for other keys
    proceed concurrently — a cold compile cannot head-of-line-block a
    hit on an unrelated key (the admission path of the serving engine
    routes every request through here, so this matters under load)."""

    def __init__(self, capacity: int = 32, store: ArtifactStore | None = None,
                 tuner=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.store = store
        self.tuner = tuner       # forwarded to wants_tuner target compiles
        self._lock = threading.RLock()
        self._entries: "OrderedDict[CacheKey, Artifact]" = OrderedDict()
        self._inflight: dict[CacheKey, _InFlight] = {}
        self._compile_seconds: dict[CacheKey, float] = {}
        self._counters = CacheCounters()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> list[CacheKey]:
        with self._lock:
            return list(self._entries)

    def stats(self) -> CacheStats:
        """Snapshot of the hit/miss/eviction counters (atomic; safe to
        read while other threads compile)."""
        return self._counters.snapshot()

    def compile_seconds(self, key: CacheKey) -> float | None:
        """Recorded compile time of a resident entry (None if evicted)."""
        with self._lock:
            return self._compile_seconds.get(key)

    def _resolve(self, net, backend, passes, input_threshold, backend_opts):
        ws, thr = _extract_weights(net, input_threshold)
        spec = PipelineSpec.coerce(passes)
        tgt, opts = resolve_target(backend, backend_opts)
        key = CacheKey(
            digest=weights_digest(ws, thr),
            backend=tgt.name,
            passes=spec.spec_string(),
            opts=tuple(sorted(opts.items())),
        )
        return key, spec, tgt, opts, ws, thr

    def key_for(self, net, *, backend: str = "jnp",
                passes=None, input_threshold: int | None = None,
                **backend_opts) -> CacheKey:
        """The content-addressed key `get_or_compile` would use. `net` is
        anything the frontend accepts; weights are canonicalized the same
        way the frontend lowers them, so two nets with equal integer
        content produce the same key regardless of container or dtype.
        `passes` accepts a PipelineSpec, a spec/registry string, or a
        sequence of pass callables (see `_pass_fingerprint`)."""
        key, *_ = self._resolve(
            net, backend, passes, input_threshold, backend_opts)
        return key

    def get_or_compile(self, net, *, backend: str = "jnp",
                       passes=None, input_threshold: int | None = None,
                       **backend_opts) -> Artifact:
        """Return the cached `Artifact` for this exact (weights, pipeline,
        target, options) combination — from memory, then the store, then
        by compiling (and persisting) on first sight anywhere."""
        key, spec, tgt, opts, ws, thr = self._resolve(
            net, backend, passes, input_threshold, backend_opts)
        while True:
            owner = False
            with self._lock:
                hit = self._entries.get(key)
                if hit is not None:
                    self._entries.move_to_end(key)
                    self._counters.hits.inc()
                    return hit
                flight = self._inflight.get(key)
                if flight is None:
                    flight = self._inflight[key] = _InFlight()
                    self._counters.misses.inc()   # this call owns the miss
                    owner = True
            if owner:
                return self._compile_owner(
                    key, flight, spec, tgt, opts, ws, thr)
            # joiner: block until the owner resolves this key, then
            # re-check the table (a hit in the common case — counted as
            # one; an immediate eviction falls through to a fresh miss)
            flight.event.wait()
            if flight.error is not None:
                raise flight.error

    def _compile_owner(self, key, flight, spec, tgt, opts, ws, thr):
        """Resolve one miss outside the lock: store lookup, then a full
        compile; publish into the table and release the waiters."""
        try:
            compiled = None
            dt = None
            skey = artifact_key(key.digest, spec, target_string(tgt, opts))
            if self.store is not None:
                compiled = self.store.get(skey)
                if compiled is not None:
                    self._counters.store_hits.inc()
                    self._counters.load_seconds.observe(
                        compiled.timings.get("load_s", 0.0))
            if compiled is None:
                t0 = time.perf_counter()
                compiled = compile_resolved(
                    ws, thr, key.digest, spec, tgt, opts, tuner=self.tuner)
                dt = time.perf_counter() - t0
                self._counters.compiles.inc()
                self._counters.compile_seconds.observe(dt)
                if self.store is not None:
                    self.store.put(compiled)
        except BaseException as e:
            self._counters.failures.inc()
            with self._lock:
                self._inflight.pop(key, None)
            flight.error = e
            flight.event.set()
            raise
        with self._lock:
            self._entries[key] = compiled
            if dt is not None:
                self._compile_seconds[key] = dt
            self._inflight.pop(key, None)
            while len(self._entries) > self.capacity:
                evicted, _ = self._entries.popitem(last=False)
                self._compile_seconds.pop(evicted, None)
                self._counters.evictions.inc()
        flight.event.set()
        return compiled


DEFAULT_CACHE = CompileCache(capacity=64)


def cached_compile_net(net, **kw) -> Artifact:
    """`compile_artifact` through the process-wide DEFAULT_CACHE."""
    return DEFAULT_CACHE.get_or_compile(net, **kw)


# ---------------------------------------------------------------------------
# Cross-model weight stacking
# ---------------------------------------------------------------------------

def stack_layered_weights(circuits: Sequence[Circuit]
                          ) -> tuple[int, list[np.ndarray]]:
    """Stack M regular circuits' weight matrices for the multi-net
    targets: lower each circuit to its ExecutionPlan and join them with
    `repro.netgen.plan.stack_plans` (which owns the compatibility
    checks and the exact hidden-width padding).

    Returns (input_threshold, [per-layer (M, fan_in, fan_out) int32]) —
    the pre-plan calling convention, kept for callers that want the raw
    arrays. Raises IrregularCircuitError for shared/CSE circuits (via
    `lower_circuit`) and ValueError for incompatible topologies.
    """
    if not circuits:
        raise ValueError("no circuits to stack")
    plan = stack_plans([lower_circuit(c) for c in circuits])
    return plan.input_threshold, [l.weights for l in plan.layers]


def _kernel_attrs(fn) -> dict:
    """The datapath attributes a `netgen.kernel` span carries when the
    predictor declares them (pallas builds do): `form` names the
    executed datapath and `launches` the pallas_call count one dispatch
    performs — `benchmarks/check_trace.py` gates that every fusednet
    round records exactly one launch."""
    dp = getattr(fn, "datapath", None)
    if dp is None:
        return {}
    attrs = {"form": dp}
    launches = getattr(fn, "launches_per_call", None)
    if launches is not None:
        attrs["launches"] = launches
    return attrs


def _shard_stacked(fn, mesh, capacity: int):
    """Wrap a stacked dispatch ((M, cap, n_in) -> (M, cap)) in
    `shard_map` over the mesh's data axes, splitting the slot (batch)
    dimension — each device serves cap / n_data rows of every version.
    Returns None (single-device fallback) when the mesh has no data
    axis or the capacity does not divide across it. Mirrors
    `repro.layers.moe_shardmap`'s jax-version compat."""
    import jax

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not data_axes:
        return None
    n = 1
    for a in data_axes:
        n *= mesh.shape[a]
    if n < 1 or capacity % n != 0:
        return None
    from jax.sharding import PartitionSpec as P
    ax = data_axes if len(data_axes) > 1 else data_axes[0]
    in_specs = (P(None, ax, None),)
    out_specs = P(None, ax)
    if hasattr(jax, "shard_map"):
        mapped = jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
    else:  # jax <= 0.4.x: experimental home, replication check named check_rep
        from jax.experimental.shard_map import shard_map as _shard_map
        mapped = _shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_rep=False)
    wrapped = jax.jit(mapped)
    # keep the datapath identity visible on the sharded wrapper: the
    # kernel span's form/launches attrs come from these
    for attr in ("datapath", "launches_per_call", "plan_form"):
        if hasattr(fn, attr):
            try:
                setattr(wrapped, attr, getattr(fn, attr))
            except AttributeError:   # jitted fns normally allow attrs
                break
    return wrapped


# ---------------------------------------------------------------------------
# Multi-version server
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Version:
    name: str
    compiled: Artifact


class NetServer:
    """Serve uint8 image batches across registered model versions.

    Single-version requests (`predict`) route to that version's cached
    `Artifact` with fixed-capacity slot batching (the
    `repro.serve.engine` pattern — one live jit trace per model; larger
    batches are chunked). Multi-version requests (`predict_many`) stack
    compatible versions' ExecutionPlans into one jitted multi-net
    dispatch — sharded over the slot dimension with `shard_map` when a
    mesh with a data axis is active (see the module doc); incompatible
    sets (different depth/width/classes, or a target without a multi
    form) fall back to per-version routing. `dispatch_counts` records
    which path served each request ("sharded" counts alongside
    "stacked", not instead of it).

    Construction: pass `session=` to compile through a `Session` (its
    memory tier and persistent store are reused; `target=`/`pipeline=`
    select what to compile), or the legacy `backend=`/`passes=`/`cache=`
    keywords. The target must produce a callable artifact.
    """

    def __init__(self, *, session=None, target: str | None = None,
                 pipeline=None, backend: str = "jnp",
                 passes=None, cache: CompileCache | None = None,
                 slot_capacity: int = 256, warmup: bool = True,
                 prefer_explored: bool = True):
        target = target if target is not None else backend
        self._target, self._opts = resolve_target(target)
        if not self._target.callable:
            raise ValueError(
                f"NetServer needs a callable backend, got {target!r} "
                f"(kind: {self._target.kind})")
        if slot_capacity < 1:
            raise ValueError(f"slot_capacity must be >= 1, got {slot_capacity}")
        if session is not None:
            if cache is not None:
                raise ValueError("pass session= or cache=, not both")
            if session.cache is None:
                raise ValueError(
                    "NetServer needs a Session with an in-memory tier "
                    "(capacity > 0)")
            self.cache = session.cache
        else:
            self.cache = cache if cache is not None else CompileCache()
        self.session = session
        # tuned=true stacked dispatch builds reuse the same persistent
        # tuning records as the single-version compiles
        self._tuner = getattr(self.cache, "tuner", None)
        self.backend = self._target.name
        # prefer a design-space-explored datapath record over the
        # hand-coded form precedence for stacked dispatch builds, when
        # the target declares `explored` and the caller didn't pin it
        # (a missing record leaves the option inert — see
        # `repro.netgen.explore`)
        self.prefer_explored = bool(prefer_explored) and \
            any(name == "explored" for name, _ in self._target.opts)
        self.passes = pipeline if pipeline is not None else passes
        self.slot_capacity = int(slot_capacity)
        self.warmup = bool(warmup)
        self._lock = threading.RLock()
        self._versions: "OrderedDict[str, _Version]" = OrderedDict()
        self._multi: dict[tuple, tuple] = {}
        # why a version set could not stack: {key: analysis.StackReport}
        self._stack_reports: dict[tuple, object] = {}
        self._generation = 0   # bumped by register/unregister; guards _multi
        self._tel = telemetry.get_registry()
        self._scope = telemetry.new_scope("server")
        self._dispatch = {
            path: self._tel.counter(
                "netgen_dispatch_total", server=self._scope, path=path)
            for path in ("single", "stacked", "sharded", "fallback")}
        self._h_occupancy = self._tel.histogram(
            "netgen_slot_occupancy", server=self._scope)

    @property
    def dispatch_counts(self) -> dict:
        """Per-path dispatch counts as a plain dict snapshot (the live
        values are atomic telemetry counters labelled with this
        server's scope)."""
        return {path: int(c.value) for path, c in self._dispatch.items()}

    def _latency(self, version: str):
        return self._tel.histogram(
            "netgen_predict_latency_seconds",
            server=self._scope, version=version)

    def _requests(self, version: str):
        """Per-version request counter: incremented exactly once per
        dispatch call per version, so `benchmarks/check_trace.py` can
        gate that every request produced exactly one latency
        observation (the misattribution bug fixed in ISSUE 7)."""
        return self._tel.counter(
            "netgen_requests_total", server=self._scope, version=version)

    # -- registry ------------------------------------------------------------

    def register(self, version: str, net) -> Artifact:
        """Compile (through the cache, and the session's store when one
        is configured) and register a model version. When `warmup` is
        on, the serving shape is traced and executed BEFORE the version
        is published into the routing table — a concurrent `predict`
        either sees the old state (KeyError / previous weights) or a
        fully warm predictor, never a registered-but-cold one whose
        first request pays the jit latency `warmup=True` promises to
        hide (and whose warmup a concurrent stacked dispatch would then
        redundantly re-run)."""
        compiled = self.cache.get_or_compile(
            net, backend=self.backend, passes=self.passes, **self._opts)
        if self.warmup:
            z = np.zeros((self.slot_capacity, compiled.circuit.n_inputs),
                         np.uint8)
            np.asarray(compiled(z))
        with self._lock:
            self._versions[version] = _Version(version, compiled)
            self._multi.clear()
            self._stack_reports.clear()
            self._generation += 1
        return compiled

    def unregister(self, version: str) -> None:
        with self._lock:
            del self._versions[version]
            self._multi.clear()
            self._stack_reports.clear()
            self._generation += 1

    def stack_report(self, names=None):
        """Why a version set fell back to per-version dispatch: the
        structured `repro.netgen.analysis.StackReport` recorded when
        `_stacked_fn` diagnosed the set (None for sets that stacked
        fine or were never requested). With `names`, the report for
        that version set under the currently active mesh; without,
        {version-name tuple: report} for every diagnosed set."""
        from repro.parallel.sharding import active_mesh
        with self._lock:
            if names is None:
                return {k[0]: r for k, r in self._stack_reports.items()}
            return self._stack_reports.get(
                (tuple(sorted(names)), active_mesh()))

    def versions(self) -> list[str]:
        with self._lock:
            return list(self._versions)

    def compiled_for(self, version: str) -> Artifact:
        with self._lock:
            v = self._versions.get(version)
        if v is None:
            raise KeyError(
                f"unknown version {version!r} (registered: {self.versions()})")
        return v.compiled

    # -- serving -------------------------------------------------------------

    def predict(self, version: str, x_uint8) -> np.ndarray:
        """Route one batch to one version. Returns predictions (B,)."""
        compiled = self.compiled_for(version)
        self._dispatch["single"].inc()
        t0 = time.perf_counter()
        with self._tel.span("netgen.dispatch", path="single",
                            versions=version):
            out = self._run_slots(compiled, np.asarray(x_uint8))
        self._requests(version).inc()
        self._latency(version).observe(time.perf_counter() - t0)
        return out

    def predict_many(self, requests: dict) -> dict:
        """Serve {version: uint8 batch} in one cross-model stacked dispatch
        when the requested versions are stack-compatible (else per-version
        fallback). Returns {version: predictions}.

        Skewed batches do not waste rounds: each slot round dispatches
        only the versions that still have requested rows (an exhausted
        version's padded all-zero block would burn kernel work and skew
        the occupancy histogram with rows nobody asked for), and the
        last remaining version finishes through the single-version slot
        path. `netgen_predict_latency_seconds` records per-version
        SERVICE time — the rounds a version actually participated in —
        so a 1-row version no longer inherits the whole-call latency of
        a 4096-row co-batched one."""
        t0 = time.perf_counter()
        names = tuple(sorted(requests))
        compiled = {v: self.compiled_for(v) for v in names}
        xs = {v: np.asarray(requests[v]) for v in names}
        for v in names:
            _validate_batch(xs[v], compiled[v].circuit.n_inputs)
        if len(names) == 1:
            (v,) = names
            self._dispatch["single"].inc()
            with self._tel.span("netgen.dispatch", path="single",
                                versions=v):
                out = {v: self._run_slots(compiled[v], xs[v])}
            self._requests(v).inc()
            self._latency(v).observe(time.perf_counter() - t0)
            return out

        fn, sharded = self._stacked_fn(names)
        if fn is None:
            self._dispatch["fallback"].inc()
            out = {}
            with self._tel.span("netgen.dispatch", path="fallback",
                                versions=len(names)):
                for v in names:
                    t1 = time.perf_counter()
                    out[v] = self._run_slots(compiled[v], xs[v])
                    self._requests(v).inc()
                    self._latency(v).observe(time.perf_counter() - t1)
            return out

        self._dispatch["stacked"].inc()
        if sharded:
            self._dispatch["sharded"].inc()
        cap = self.slot_capacity
        rounds = max((x.shape[0] + cap - 1) // cap for x in xs.values())
        out: dict[str, list] = {v: [] for v in names}
        service = {v: 0.0 for v in names}
        with self._tel.span("netgen.dispatch",
                            path="sharded" if sharded else "stacked",
                            versions=len(names), rounds=rounds):
            for r in range(rounds):
                active = tuple(v for v in names if xs[v].shape[0] > r * cap)
                if len(active) == 1:
                    (v,) = active
                    t1 = time.perf_counter()
                    out[v].append(self._run_slots(
                        compiled[v], xs[v][r * cap:]))
                    service[v] += time.perf_counter() - t1
                    break
                # a strict subset of a stackable set is itself stackable;
                # its multi-net fn is cached in _multi like the full set's
                afn = fn if active == names else self._stacked_fn(active)[0]
                chunks = [xs[v][r * cap:(r + 1) * cap] for v in active]
                t1 = time.perf_counter()
                preds, valid = self._stacked_round(afn, chunks, round=r)
                dt = time.perf_counter() - t1
                for i, v in enumerate(active):
                    out[v].append(preds[i, :valid[i]])
                    service[v] += dt
        for v in names:
            self._requests(v).inc()
            self._latency(v).observe(service[v])
        return {v: (np.concatenate(out[v]) if out[v]
                    else np.zeros((0,), np.int64)) for v in names}

    # -- internals -----------------------------------------------------------

    def _stacked_round(self, fn, chunks: list, round: int = 0
                       ) -> tuple[np.ndarray, list]:
        """ONE stacked dispatch round — the slot mechanics shared by
        `predict_many` and the async serving engine
        (`repro.netgen.engine`): pad each version's chunk into the
        (M, cap, n_in) slot block, observe occupancy over the slots
        actually requested, run the jitted multi-net fn. Returns the
        (M, cap) predictions and the per-version valid row counts."""
        cap = self.slot_capacity
        block = np.zeros((len(chunks), cap, chunks[0].shape[1]), np.uint8)
        valid = []
        for i, chunk in enumerate(chunks):
            block[i], n = pad_slots(chunk, cap)
            valid.append(n)
        self._h_occupancy.observe(sum(valid) / (len(chunks) * cap))
        attrs = {"round": round, "valid": sum(valid), **_kernel_attrs(fn)}
        with self._tel.span("netgen.kernel", **attrs):
            preds = np.asarray(fn(block))            # (M, cap)
        return preds, valid

    def _run_slots(self, compiled: Artifact, x: np.ndarray) -> np.ndarray:
        _validate_batch(x, compiled.circuit.n_inputs)
        cap = self.slot_capacity
        if x.shape[0] == 0:
            return np.zeros((0,), np.int64)
        attrs = _kernel_attrs(getattr(compiled, "artifact", None))
        outs = []
        for i in range(0, x.shape[0], cap):
            padded, n = pad_slots(x[i:i + cap], cap)
            self._h_occupancy.observe(n / cap)
            with self._tel.span("netgen.kernel", valid=n, **attrs):
                outs.append(np.asarray(compiled(padded))[:n])
        return np.concatenate(outs)

    def _stacked_fn(self, names: tuple) -> tuple:
        """Build (or recall) the multi-net dispatch for this version set;
        returns (fn, sharded) with fn None when the set cannot be
        stacked. The stacked plan is compiled through the Target
        registry (`backends.compile_multi`), so the declared target
        options — interpret, packed — reach the multi form through the
        same validation as the single-version path. When a mesh with a
        data axis is active the dispatch is wrapped in `shard_map` over
        the slot dimension (the cache is keyed on the mesh, so leaving
        the mesh context falls back to the single-device build).
        Compilation happens outside the lock; a generation check before
        storing guards against a concurrent (un)register racing the
        build — a stale fn must never enter `_multi`, or it would
        silently serve old weights.

        A set that cannot stack is no longer a silent fallback: the
        static diagnosis (`repro.netgen.analysis.diagnose_stack`, or
        the build error when compilation itself fails) is recorded as a
        `StackReport` readable through `stack_report()` and counted in
        `netgen_stack_incompat_total{reason}`."""
        from repro.netgen import analysis
        from repro.parallel.sharding import active_mesh

        mesh = active_mesh()
        key = (names, mesh)
        while True:
            with self._lock:
                if key in self._multi:
                    return self._multi[key]
                generation = self._generation
                circuits = [self._versions[v].compiled.circuit for v in names]
            report = None
            if self._target.compile_multi is None:
                entry = (None, False)
                report = analysis.StackReport(
                    compatible=False, n_versions=len(names),
                    diagnostics=(analysis.Diagnostic(
                        check="stack.target",
                        message=f"target {self._target.name!r} has no "
                                "multi-net dispatch"),))
            else:
                report = analysis.diagnose_stack(circuits)
                if not report.compatible:
                    entry = (None, False)
                else:
                    try:
                        plan = stack_plans(
                            [lower_circuit(c) for c in circuits])
                        opts = dict(self._opts)
                        if self.prefer_explored and "explored" not in opts:
                            opts["explored"] = True
                        fn = compile_multi(
                            plan, backend=self._target.name,
                            tuner=self._tuner, **opts)
                        sharded_fn = (
                            None if mesh is None else
                            _shard_stacked(fn, mesh, self.slot_capacity))
                        entry = ((sharded_fn, True) if sharded_fn is not None
                                 else (fn, False))
                        report = None
                    except (IrregularCircuitError, ValueError) as e:
                        entry = (None, False)
                        report = analysis.StackReport(
                            compatible=False, n_versions=len(names),
                            diagnostics=(analysis.Diagnostic(
                                check="stack.build", message=str(e)),))
            with self._lock:
                if self._generation == generation:
                    self._multi[key] = entry
                    if report is not None:
                        self._stack_reports[key] = report
                        self._tel.counter(
                            "netgen_stack_incompat_total",
                            server=self._scope, reason=report.reason).inc()
                    return entry
            # registry changed underneath the build: retry with fresh circuits
