"""Compile-cache serving of netgen-specialized predictors.

The paper's economics (§IV-§V) are compile-per-model-then-serve: the
expensive step is specializing a trained net into a fixed circuit; the
cheap step is running it. This module makes that split operational, the
ROADMAP's "Serving specialized programs" item:

  CompileCache — the in-memory tier of the Session API. The key is the
      sha256 digest of the quantized weights + input threshold
      (`repro.core.quantize.weights_digest`) crossed with the canonical
      `PipelineSpec` and `Target` strings. A hit returns the *same*
      `Artifact` object that was compiled before; a miss consults the
      optional persistent `ArtifactStore` (so a second process
      warm-starts without recompiling), then compiles, records
      wall-clock compile time, persists, and LRU-evicts past a fixed
      capacity. Thread-safe (one lock; concurrent requests for the same
      key compile exactly once).

  NetServer — a multi-version predictor server in the style of
      `repro.serve.engine`: fixed-capacity slot batching (one live jit
      trace per model), per-request routing by version name, and
      *cross-model* batching: versions whose circuits reconstruct to
      compatible layered weights are stacked along a model axis
      (`stack_layered_weights`) and served by one jitted multi-net
      dispatch (the target's `compile_multi` form) — M versions, one
      XLA call. A NetServer can be built over a `Session`
      (`NetServer(session=Session(store=...))`) to share its memory
      tier and persistent store, or over legacy backend/passes/cache
      keywords.

Hidden-width padding used for stacking is exact: a zero-padded column is
an empty accumulator, and under the strict step semantics step(0) = 0,
so padded units contribute nothing downstream (their outgoing rows are
zero-padded too).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core.quantize import weights_digest
from repro.netgen.frontend import _extract_weights
from repro.netgen.graph import (
    Circuit, IrregularCircuitError, as_layered_weights,
)
from repro.netgen.pipeline import PipelineSpec
from repro.netgen.session import (
    Artifact, ArtifactStore, _validate_batch, artifact_key, compile_resolved,
)
from repro.netgen.targets import resolve_target, target_string
from repro.serve.slots import pad_slots

__all__ = [
    "CacheKey", "CacheStats", "CompileCache", "DEFAULT_CACHE", "NetServer",
    "cached_compile_net", "stack_layered_weights",
]


# ---------------------------------------------------------------------------
# Content-addressed compile cache
# ---------------------------------------------------------------------------

def _pass_fingerprint(p) -> str:
    """Canonical spec item for one pass callable (registry name plus
    bracketed options, e.g. `cse[budget=2]`). `functools.partial` of a
    registered pass maps its bound keywords back to declared options, so
    a budgeted variant does not alias the unbudgeted one.

    Lambdas and closures are refused (by `PipelineSpec.from_passes`):
    their qualified name does not cover their captured state, so two
    different ones would alias to the same key and the cache would hand
    back a predictor compiled with the OTHER pipeline. Spell
    parameterized passes declaratively (`"cse[budget=5]"`) or as
    functools.partial of a registered module-level function.
    """
    return PipelineSpec.from_passes([p]).spec_string()


@dataclasses.dataclass(frozen=True)
class CacheKey:
    """What a compiled predictor is a function of: weight content digest,
    target name, canonical pipeline spec, and target options."""
    digest: str
    backend: str
    passes: str
    opts: tuple


@dataclasses.dataclass
class CacheStats:
    hits: int = 0              # memory-tier hits
    misses: int = 0            # memory-tier misses (store hit OR compile)
    evictions: int = 0
    compile_seconds: float = 0.0   # total wall-clock spent compiling
    compiles: int = 0          # actual full compilations
    store_hits: int = 0        # misses served by the persistent store
    load_seconds: float = 0.0  # wall-clock spent loading from the store

    def row(self) -> str:
        return (f"cache: {self.hits} hits, {self.misses} misses "
                f"({self.store_hits} from store), {self.evictions} "
                f"evictions, {self.compile_seconds * 1e3:.1f} ms compiling, "
                f"{self.load_seconds * 1e3:.1f} ms loading")


class CompileCache:
    """LRU-bounded, thread-safe, content-addressed compile cache — the
    in-memory tier over an optional persistent `ArtifactStore`."""

    def __init__(self, capacity: int = 32, store: ArtifactStore | None = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.store = store
        self._lock = threading.RLock()
        self._entries: "OrderedDict[CacheKey, Artifact]" = OrderedDict()
        self._compile_seconds: dict[CacheKey, float] = {}
        self._stats = CacheStats()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: CacheKey) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> list[CacheKey]:
        with self._lock:
            return list(self._entries)

    def stats(self) -> CacheStats:
        """Snapshot of the hit/miss/eviction counters."""
        with self._lock:
            return dataclasses.replace(self._stats)

    def compile_seconds(self, key: CacheKey) -> float | None:
        """Recorded compile time of a resident entry (None if evicted)."""
        with self._lock:
            return self._compile_seconds.get(key)

    def _resolve(self, net, backend, passes, input_threshold, backend_opts):
        ws, thr = _extract_weights(net, input_threshold)
        spec = PipelineSpec.coerce(passes)
        tgt, opts = resolve_target(backend, backend_opts)
        key = CacheKey(
            digest=weights_digest(ws, thr),
            backend=tgt.name,
            passes=spec.spec_string(),
            opts=tuple(sorted(opts.items())),
        )
        return key, spec, tgt, opts, ws, thr

    def key_for(self, net, *, backend: str = "jnp",
                passes=None, input_threshold: int | None = None,
                **backend_opts) -> CacheKey:
        """The content-addressed key `get_or_compile` would use. `net` is
        anything the frontend accepts; weights are canonicalized the same
        way the frontend lowers them, so two nets with equal integer
        content produce the same key regardless of container or dtype.
        `passes` accepts a PipelineSpec, a spec/registry string, or a
        sequence of pass callables (see `_pass_fingerprint`)."""
        key, *_ = self._resolve(
            net, backend, passes, input_threshold, backend_opts)
        return key

    def get_or_compile(self, net, *, backend: str = "jnp",
                       passes=None, input_threshold: int | None = None,
                       **backend_opts) -> Artifact:
        """Return the cached `Artifact` for this exact (weights, pipeline,
        target, options) combination — from memory, then the store, then
        by compiling (and persisting) on first sight anywhere."""
        key, spec, tgt, opts, ws, thr = self._resolve(
            net, backend, passes, input_threshold, backend_opts)
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None:
                self._entries.move_to_end(key)
                self._stats.hits += 1
                return hit
            self._stats.misses += 1
            compiled = None
            skey = artifact_key(key.digest, spec, target_string(tgt, opts))
            if self.store is not None:
                compiled = self.store.get(skey)
                if compiled is not None:
                    self._stats.store_hits += 1
                    self._stats.load_seconds += compiled.timings.get(
                        "load_s", 0.0)
            if compiled is None:
                t0 = time.perf_counter()
                compiled = compile_resolved(
                    ws, thr, key.digest, spec, tgt, opts)
                dt = time.perf_counter() - t0
                self._stats.compiles += 1
                self._stats.compile_seconds += dt
                self._compile_seconds[key] = dt
                if self.store is not None:
                    self.store.put(compiled)
            self._entries[key] = compiled
            while len(self._entries) > self.capacity:
                evicted, _ = self._entries.popitem(last=False)
                self._compile_seconds.pop(evicted, None)
                self._stats.evictions += 1
            return compiled


DEFAULT_CACHE = CompileCache(capacity=64)


def cached_compile_net(net, **kw) -> Artifact:
    """`compile_artifact` through the process-wide DEFAULT_CACHE."""
    return DEFAULT_CACHE.get_or_compile(net, **kw)


# ---------------------------------------------------------------------------
# Cross-model weight stacking
# ---------------------------------------------------------------------------

def stack_layered_weights(circuits: Sequence[Circuit]
                          ) -> tuple[int, list[np.ndarray]]:
    """Stack M regular circuits' reconstructed weight matrices for the
    multi-net targets.

    Returns (input_threshold, [per-layer (M, fan_in, fan_out) int32]).
    Versions must agree on depth, input width, class count, and input
    threshold; *hidden* widths may differ (pruning is per-model) — they
    are zero-padded to the per-layer maximum, which is exact under the
    strict step semantics (an all-zero column is an empty accumulator,
    step(0) = 0, and its outgoing row is zero-padded too).

    Raises IrregularCircuitError for shared/CSE circuits (via
    `as_layered_weights`) and ValueError for incompatible topologies.
    """
    if not circuits:
        raise ValueError("no circuits to stack")
    mats = [as_layered_weights(c) for c in circuits]

    depths = {len(m) for m in mats}
    if len(depths) != 1:
        raise ValueError(f"versions disagree on depth: {sorted(depths)}")
    thrs = {c.input_threshold for c in circuits}
    if len(thrs) != 1:
        raise ValueError(f"versions disagree on input threshold: {sorted(thrs)}")
    n_ins = {m[0].shape[0] for m in mats}
    if len(n_ins) != 1:
        raise ValueError(f"versions disagree on input width: {sorted(n_ins)}")
    n_outs = {m[-1].shape[1] for m in mats}
    if len(n_outs) != 1:
        # class counts cannot be padded: an extra constant-0 class could
        # win the argmax when every real score is negative
        raise ValueError(f"versions disagree on class count: {sorted(n_outs)}")

    depth = depths.pop()
    for layer in range(depth - 1):
        width = max(m[layer].shape[1] for m in mats)
        for m in mats:
            have = m[layer].shape[1]
            if have < width:
                m[layer] = np.pad(m[layer], ((0, 0), (0, width - have)))
                m[layer + 1] = np.pad(m[layer + 1], ((0, width - have), (0, 0)))
    return thrs.pop(), [
        np.stack([m[layer] for m in mats]).astype(np.int32)
        for layer in range(depth)]


# ---------------------------------------------------------------------------
# Multi-version server
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Version:
    name: str
    compiled: Artifact


class NetServer:
    """Serve uint8 image batches across registered model versions.

    Single-version requests (`predict`) route to that version's cached
    `Artifact` with fixed-capacity slot batching (the
    `repro.serve.engine` pattern — one live jit trace per model; larger
    batches are chunked). Multi-version requests (`predict_many`) stack
    compatible versions' weights into one jitted multi-net dispatch;
    incompatible sets (different depth/width/classes, or a target
    without a multi form) fall back to per-version routing.
    `dispatch_counts` records which path served each request.

    Construction: pass `session=` to compile through a `Session` (its
    memory tier and persistent store are reused; `target=`/`pipeline=`
    select what to compile), or the legacy `backend=`/`passes=`/`cache=`
    keywords. The target must produce a callable artifact.
    """

    def __init__(self, *, session=None, target: str | None = None,
                 pipeline=None, backend: str = "jnp",
                 passes=None, cache: CompileCache | None = None,
                 slot_capacity: int = 256, warmup: bool = True):
        target = target if target is not None else backend
        self._target, self._opts = resolve_target(target)
        if not self._target.callable:
            raise ValueError(
                f"NetServer needs a callable backend, got {target!r} "
                f"(kind: {self._target.kind})")
        if slot_capacity < 1:
            raise ValueError(f"slot_capacity must be >= 1, got {slot_capacity}")
        if session is not None:
            if cache is not None:
                raise ValueError("pass session= or cache=, not both")
            if session.cache is None:
                raise ValueError(
                    "NetServer needs a Session with an in-memory tier "
                    "(capacity > 0)")
            self.cache = session.cache
        else:
            self.cache = cache if cache is not None else CompileCache()
        self.session = session
        self.backend = self._target.name
        self.passes = pipeline if pipeline is not None else passes
        self.slot_capacity = int(slot_capacity)
        self.warmup = bool(warmup)
        self._lock = threading.RLock()
        self._versions: "OrderedDict[str, _Version]" = OrderedDict()
        self._multi: dict[tuple, object] = {}
        self._generation = 0   # bumped by register/unregister; guards _multi
        self.dispatch_counts = {"single": 0, "stacked": 0, "fallback": 0}

    # -- registry ------------------------------------------------------------

    def register(self, version: str, net) -> Artifact:
        """Compile (through the cache, and the session's store when one
        is configured) and register a model version. When `warmup` is
        on, the serving shape is traced and executed once so the first
        real request pays no jit latency."""
        compiled = self.cache.get_or_compile(
            net, backend=self.backend, passes=self.passes, **self._opts)
        with self._lock:
            self._versions[version] = _Version(version, compiled)
            self._multi.clear()
            self._generation += 1
        if self.warmup:
            z = np.zeros((self.slot_capacity, compiled.circuit.n_inputs),
                         np.uint8)
            np.asarray(compiled(z))
        return compiled

    def unregister(self, version: str) -> None:
        with self._lock:
            del self._versions[version]
            self._multi.clear()
            self._generation += 1

    def versions(self) -> list[str]:
        with self._lock:
            return list(self._versions)

    def compiled_for(self, version: str) -> Artifact:
        with self._lock:
            v = self._versions.get(version)
        if v is None:
            raise KeyError(
                f"unknown version {version!r} (registered: {self.versions()})")
        return v.compiled

    # -- serving -------------------------------------------------------------

    def predict(self, version: str, x_uint8) -> np.ndarray:
        """Route one batch to one version. Returns predictions (B,)."""
        compiled = self.compiled_for(version)
        with self._lock:
            self.dispatch_counts["single"] += 1
        return self._run_slots(compiled, np.asarray(x_uint8))

    def predict_many(self, requests: dict) -> dict:
        """Serve {version: uint8 batch} in one cross-model stacked dispatch
        when the requested versions are stack-compatible (else per-version
        fallback). Returns {version: predictions}."""
        names = tuple(sorted(requests))
        compiled = {v: self.compiled_for(v) for v in names}
        for v in names:
            _validate_batch(np.asarray(requests[v]),
                            compiled[v].circuit.n_inputs)
        if len(names) == 1:
            (v,) = names
            with self._lock:
                self.dispatch_counts["single"] += 1
            return {v: self._run_slots(compiled[v], np.asarray(requests[v]))}

        fn = self._stacked_fn(names)
        if fn is None:
            with self._lock:
                self.dispatch_counts["fallback"] += 1
            return {v: self._run_slots(compiled[v], np.asarray(requests[v]))
                    for v in names}

        with self._lock:
            self.dispatch_counts["stacked"] += 1
        cap = self.slot_capacity
        n_in = compiled[names[0]].circuit.n_inputs
        xs = {v: np.asarray(requests[v]) for v in names}
        rounds = max((x.shape[0] + cap - 1) // cap for x in xs.values())
        out: dict[str, list] = {v: [] for v in names}
        for r in range(rounds):
            block = np.zeros((len(names), cap, n_in), np.uint8)
            valid = []
            for i, v in enumerate(names):
                chunk = xs[v][r * cap:(r + 1) * cap]
                block[i], n = pad_slots(chunk, cap)
                valid.append(n)
            preds = np.asarray(fn(block))            # (M, cap)
            for i, v in enumerate(names):
                out[v].append(preds[i, :valid[i]])
        return {v: (np.concatenate(out[v]) if out[v]
                    else np.zeros((0,), np.int64)) for v in names}

    # -- internals -----------------------------------------------------------

    def _run_slots(self, compiled: Artifact, x: np.ndarray) -> np.ndarray:
        _validate_batch(x, compiled.circuit.n_inputs)
        cap = self.slot_capacity
        if x.shape[0] == 0:
            return np.zeros((0,), np.int64)
        outs = []
        for i in range(0, x.shape[0], cap):
            padded, n = pad_slots(x[i:i + cap], cap)
            outs.append(np.asarray(compiled(padded))[:n])
        return np.concatenate(outs)

    def _stacked_fn(self, names: tuple):
        """Build (or recall) the multi-net dispatch for this version set;
        None when the set cannot be stacked. Compilation happens outside
        the lock; a generation check before storing guards against a
        concurrent (un)register racing the build — a stale fn must never
        enter `_multi`, or it would silently serve old weights."""
        while True:
            with self._lock:
                if names in self._multi:
                    return self._multi[names]
                generation = self._generation
                circuits = [self._versions[v].compiled.circuit for v in names]
            if self._target.compile_multi is None:
                fn = None
            else:
                try:
                    thr, stacked = stack_layered_weights(circuits)
                    fn = self._target.compile_multi(
                        stacked, thr, **self._opts)
                except (IrregularCircuitError, ValueError):
                    fn = None
            with self._lock:
                if self._generation == generation:
                    self._multi[names] = fn
                    return fn
            # registry changed underneath the build: retry with fresh circuits
