"""`repro.netgen` — the paper's net-to-hardware step as a real compiler.

The source paper's central artifact (§IV-§V) is a Python script that
walks a trained 784-500-10 net and prints a clockless Verilog module,
applying structural optimizations on the way. This package generalizes
that script into a small compiler over a typed circuit IR, so the same
rewrites serve arbitrary-depth nets and multiple execution targets:

    frontend.lower        quantized N-layer stack -> circuit IR
    PipelineSpec          declarative pass pipeline ("zeros,prune,...")
    plan.lower_circuit    optimized circuit -> ExecutionPlan, the ONE
                          layer-structured tensor lowering every array
                          backend executes (dense / bit-packed /
                          stacked multi-net forms)
    Target registry       IR -> artifact (jitted fn, Verilog text,
                          logic-cell cost report)
    Session + ArtifactStore   compile once per content, persist across
                          processes

Paper-section map
-----------------
  §III.B / Fig. 6 line 5   -> graph.InputCompare (pixel > threshold)
  §III.A step activation   -> graph.SignStep
  §V.D MSB sign-bit trick  -> SignStep emission in backends/verilog.py
                              (and the strict-vs-msb semantics note in
                              graph.evaluate)
  §V.B zero-weight pruning -> passes.delete_zero_terms (per-term) and
     (L4, ~50% cell cut)      passes.prune_dead_units (per-unit)
  §V.C multiplication-free -> passes.addend_rewrite (w*x -> |w| addends;
     (L5, 38k -> <16k cells)  after it, ops().mults == 0)
  beyond the paper         -> passes.share_common_addends (adder CSE;
                              `cse[bucketed=true]` scales it to the full
                              784-input net), the `cost` target
                              (Figure-7-style logic-cell estimates)
  Fig. 6 line 15 argmax    -> graph.Argmax, emitted as a priority mux
  Fig. 6/7 module shape    -> backends/verilog.py "legacy" style
                              (byte-compatible with the seed emitter)

Quick use
---------
Compilation goes through a `Session`: pick a target (an execution
backend from the registry — `netgen.list_targets()` enumerates them)
and a pipeline (a named or declarative `PipelineSpec`), get back an
`Artifact` carrying the optimized circuit, per-pass stats, a logic-cell
estimate, timings, and the artifact itself:

    from repro.core.quantize import quantize
    from repro import netgen

    session = netgen.Session(store=netgen.ArtifactStore("./netgen-store"))
    art = session.compile(quantize(params), target="jnp")
    preds = art(images_uint8)            # bit-exact vs predict_l3
    print(art.report())                  # per-pass savings + cell count

    verilog = session.compile(qnet, target="verilog", pipeline="hw").artifact
    cost = session.compile(qnet, target="cost", pipeline="hw").artifact
    print(cost.report())                 # per-pass cells vs paper Fig. 7

Pipelines are declarative strings — `"zeros,prune"` (named: "default"),
`"zeros,prune,addends,cse[budget=5000,bucketed=true]"` (named "hw" in
its unbudgeted form) — that round-trip through `PipelineSpec.parse` and
fingerprint stably, so they key the store. Because the store is
content-addressed by `QuantizedNet.digest()` x
`PipelineSpec.fingerprint()` x target, a SECOND process pointed at the
same directory warm-starts every artifact without recompiling.

`compile_net(...)` is the pre-Session entry point; it still works but
is deprecated and routed through a default Session.

Execution plans (the array-backend lowering)
--------------------------------------------
`repro.netgen.plan.lower_circuit` turns an optimized circuit into an
`ExecutionPlan` — per-layer weight matrices, activation kind, input
threshold, final argmax — and every array backend (jnp / pallas /
fused) is a thin executor over it. `plan.pack()` is the bit-packed
form: ±1-weighted single-bit activations travel 32-per-uint32 word
into `kernels.binary_matvec.binary_matmul_packed` (the paper's
single-bit wires, on the TPU), selected with `pallas[packed=true]`,
chained packed end-to-end (the step emits packed words — no int8
activation between layers) and bit-exact with the dense path.
`plan.planes()` goes further (`pallas[planes=true]`): each weight
matrix is decomposed into packed signed bit-planes
(`decompose_planes`, w = sum_b 2^b (pos_b - neg_b)) and accumulated by
popcount in `binary_matmul_planes` — both operands travel as bits,
with the plane count set by the layer's actual post-pass weight
magnitudes. `plan.stack_plans` joins M compatible plans along a model
axis for the serving layer. `pallas[fusednet=true]` is the planes form
taken to its limit: `plan.megakernel_view()` flattens the whole net
(hidden fan_outs pre-padded to the next layer's word width) and
`binary_forward_planes` runs EVERY layer in one persistent Pallas
launch — weights resident in VMEM, strict step + repack in-register
between layers (inter-layer activations never touch HBM), argmax fused
— one launch per forward instead of one per layer. Artifacts record
the compiled form (`artifact.plan_form`), the datapath
(`artifact.datapath`) and launch count (`artifact.launches_per_call`),
and re-derive the plan via `artifact.plan()`.

Autotuning (`repro.netgen.tune`): `pallas[tuned=true]` grid-searches
the kernel block sizes (bm, bn, bkw) — and the datapath form, unless
pinned — per plan shape x device kind; `fused[tuned=true]` searches
its batch tile. `Session(tune_store=...)` persists the winners
content-addressed (a second process performs ZERO tuning
measurements); `session.tune_stats()` shows hits vs measurements.

Design-space exploration (`repro.netgen.explore`)
-------------------------------------------------
The paper's levers — pass pipeline, datapath form, kernel tile sizes —
interact, so `Session.explore(...)` searches them as ONE optimization
problem: a seeded `Explorer` ("random" permutation or simulated
annealing) over a `SearchSpace` of pipeline spec strings x
dense/packed/planes/fusednet x (bm, bn, bkw) tiles x optionally
several nets (the ladder-depth axis), under a pluggable lower-is-
better objective ("latency", deterministic "cells" from the Fig-7
estimate, "combined", or `make_objective(fn, name=...)`). Illegal
candidates are pruned BEFORE measurement through the shared
`analysis.tile_legality` / `IrregularCircuitError` checks; every
measured candidate compiles through the Session (artifacts persist in
the ArtifactStore) and the whole search persists as one content-
addressed `TuneRecord`, so a second process replays the returned
`ExplorationReport` with zero measurements and zero compiles.
`pallas[explored=true]` resolves the persisted winner for a plan
shape, and the serving layer's stacked dispatch prefers it over the
hand-coded form precedence (`NetServer(prefer_explored=...)`):

    rep = session.explore(qnet, objective="latency", budget=24, seed=0)
    spec, target = rep.best_config()
    art = session.compile(qnet, target=target, pipeline=spec)
    print(rep.describe())            # candidates / pruned / winner

Serving (compile cache + multi-version dispatch + mesh sharding)
----------------------------------------------------------------
`repro.netgen.serve` makes the compile-per-model-then-serve workflow
operational: `CompileCache` is the Session's in-memory tier (same
content addressing, LRU, thread-safe), and a `NetServer` routes request
batches — cross-model batches of stack-compatible versions run as ONE
jitted multi-net dispatch, and when a device mesh with a data axis is
active (`repro.parallel.sharding.use_mesh`) that dispatch shards its
slot dimension across the mesh via `shard_map` (single-device fallback
otherwise):

    session = netgen.Session(store=netgen.ArtifactStore(cache_dir),
                             tune_store=tune_dir)
    handle = session.compile_async(qnet, target="pallas[tuned=true]")
    server = netgen.NetServer(session=session, slot_capacity=64)
    server.register("v1", qnet)              # warm: async compile + store
    server.register("v1-replica", qnet)      # memory hit, ~us
    out = server.predict_many({"v1": imgs_a, "v2": imgs_b})
    print(session.stats().row())             # hits/misses/compile time

    with shd.use_mesh(make_host_mesh(data=8)):    # 8-way batch sharding
        out = server.predict_many({"v1": imgs_a, "v2": imgs_b})

Online serving (`repro.netgen.engine`) is the async front door over
that dispatch: clients `submit()` SINGLE uint8 requests (getting a
Future) or call the blocking `infer()`, and a batcher thread performs
continuous slot formation — fill a slot block or wait `max_batch_delay`,
whichever first — grouping stack-compatible versions into one stacked
dispatch per round. SLO knobs: `max_batch_delay`, `max_queue_depth`
(explicit `QueueFullError` rejection), per-request `deadline`
(`DeadlineExceededError`); exiting the context manager drains the queue:

    with session.engine(slot_capacity=256, max_batch_delay=0.002) as eng:
        eng.register("v1", qnet)
        label = eng.submit("v1", image).result()   # or eng.infer(...)

See `benchmarks/bench_netgen_serve.py` for cold-vs-warm,
cold-process-vs-warm-store, and stacked-vs-individual numbers,
`benchmarks/bench_netgen_engine.py` for the closed/open-loop (Poisson)
p50/p99/throughput sweep of the engine vs one-request-per-dispatch, and
the top-level README.md for the end-to-end quickstart.

Static analysis & verification (`repro.netgen.analysis`)
--------------------------------------------------------
The invariants the paper's Verilog relies on — exact accumulator
ranges, sound bit-widths, lossless packed/bit-plane lowering — are
machine-checked instead of assumed:

    verify_circuit(c)     structural IR verifier: DAG well-formedness,
                          src validity, kind-specific invariants, and
                          per-pass postconditions ("no zero-weight
                          terms after zeros", ...)
    analyze_ranges(c)     interval dataflow: per-node exact [lo, hi]
                          plus the magnitude bound that sizes widths —
                          proves every accumulator fits its emitted
                          `signed_width` (subsumes `value_bounds` /
                          `evaluate(check_widths=True)`)
    verify_plan(p)        ExecutionPlan certification: chain shapes,
                          packed-padding exactness, `decompose_planes`
                          losslessness, int32 popcount-accumulation
                          safety (also `plan.verify()`)
    diagnose_stack(cs)    structured stack-compatibility report (the
                          NetServer records it as `stack_report()`
                          instead of silently falling back)

Wiring: `PipelineSpec.run(verify=True)` checks the full suite at every
pass boundary (default follows the NETGEN_VERIFY env var — on in
tests/CI, off in prod, where violations count
`netgen_verify_failures_total` instead of raising);
`Session.compile_resolved` runs one pre-backend analysis, hands the
proven widths to the verilog/cost backends (`Target.wants_analysis`),
and records a proof summary on the Artifact (`artifact.analysis`,
persisted in meta.json, shown by `artifact.report()`); the kernel
tuner statically rejects illegal/duplicate tile candidates before
measuring them (`analysis.tile_legality`); and
`python -m repro.netgen.analysis <store-dir>` lints every artifact in
an ArtifactStore, failing on corrupt, stale, or unsound entries.

Observability (`repro.netgen.telemetry`)
----------------------------------------
Every layer above reports into one zero-dependency, thread-safe
registry: counters/gauges/histograms are ALWAYS live (they back
`CacheStats` / `StoreStats` / `TuneStats` / `NetServer.dispatch_counts`
atomically), while nested trace spans are opt-in:

    from repro.netgen import telemetry
    telemetry.enable(profile=True)   # spans on + jit cost_analysis/artifact
    ... compile and serve ...
    print(telemetry.report())        # human table: metrics + span totals
    telemetry.prometheus()           # text exposition (scrape or file)
    telemetry.export_jsonl(path)     # one finished span per line
    telemetry.summary()              # JSON-stable dict (BENCH_netgen.json)
    telemetry.disable(); telemetry.reset()

API surface: `counter/gauge/histogram(name, **labels)` (get-or-create;
histograms have exact nearest-rank `p50/p95/p99`), `span(name, **attrs)`
(nested per-thread; no-op context unless enabled), `timed(name,
**labels)` (time a block into a histogram — the benches use this),
`jit_cost(fn, shape)` (XLA flops/bytes for roofline rows),
`new_scope(prefix)` (per-instance label), `get_registry()`. The traced
span tree and metric names are documented in the telemetry module
docstring; `examples/mnist_fpga_pipeline.py --trace DIR` shows the
whole thing end to end.

`repro.core.netgen` remains as a thin compatibility shim with the old
`specialize` / `emit_verilog` / `prune` / `stats` names.
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.netgen import analysis, backends, telemetry
from repro.netgen.analysis import (
    Diagnostic, RangeAnalysis, StackReport, VerificationError,
    analyze_ranges, diagnose_stack, verify_circuit, verify_plan,
)
from repro.netgen.backends.cost import CellCounts, CostReport
from repro.netgen.explore import (
    Candidate, ExplorationReport, Explorer, Objective, SearchSpace,
    make_objective,
)
from repro.netgen.frontend import lower
from repro.netgen.graph import (
    Argmax, Circuit, InputCompare, IrregularCircuitError, SignStep, Term,
    WeightedSum, as_layered_weights, circuit_from_arrays, circuit_to_arrays,
    evaluate, node_widths,
)
from repro.netgen.passes import (
    DEFAULT_PASSES, HW_PASSES, CircuitOps, Pass, PassStats, addend_rewrite,
    delete_zero_terms, ops, prune_dead_units, run_pipeline,
    share_common_addends,
)
from repro.netgen.pipeline import (
    PipelineSpec, list_passes, list_pipelines, register_pass,
    register_pipeline,
)
from repro.netgen.plan import (
    ExecutionPlan, PlanLayer, decompose_planes, lower_circuit, stack_plans,
)
from repro.netgen.session import (
    Artifact, ArtifactStore, Session, compile_artifact,
)
from repro.netgen.session import _validate_batch  # noqa: F401  (serving)
from repro.netgen.targets import (
    Target, list_targets, register_target, resolve_target,
)
from repro.netgen.tune import (
    KernelTuner, TuneRecord, TuneStats, TuneStore, default_tuner,
)

__all__ = [
    "Argmax", "Artifact", "ArtifactStore", "CacheKey", "Candidate",
    "CellCounts", "Circuit", "CircuitOps", "CompileCache", "CompiledNet",
    "CostReport", "DEFAULT_PASSES", "DeadlineExceededError", "Diagnostic",
    "EngineClosedError", "EngineStats", "ExecutionPlan",
    "ExplorationReport", "Explorer", "HW_PASSES", "InputCompare",
    "IrregularCircuitError", "KernelTuner", "NetServer", "Objective",
    "Pass", "PassStats", "PipelineSpec", "PlanLayer", "QueueFullError",
    "RangeAnalysis", "SearchSpace", "ServingEngine", "Session", "SignStep",
    "StackReport", "Target", "Term", "TuneRecord", "TuneStats",
    "TuneStore", "VerificationError", "WeightedSum", "addend_rewrite",
    "analysis", "analyze_ranges", "as_layered_weights", "backends",
    "cached_compile_net", "circuit_from_arrays", "circuit_to_arrays",
    "compile_artifact", "compile_net", "decompose_planes",
    "default_session", "default_tuner", "delete_zero_terms",
    "diagnose_stack", "emit_verilog", "engine", "evaluate",
    "list_passes", "list_pipelines", "list_targets", "lower",
    "lower_circuit", "make_objective", "node_widths", "ops",
    "prune_dead_units",
    "register_pass", "register_pipeline", "register_target",
    "resolve_target", "run_pipeline", "serve", "share_common_addends",
    "specialize", "stack_layered_weights", "stack_plans", "telemetry",
    "verify_circuit", "verify_plan",
]


@dataclasses.dataclass(frozen=True)
class CompiledNet:
    """Result of one end-to-end compilation through the deprecated
    `compile_net` shim: the optimized circuit, the per-pass statistics,
    and the backend artifact (a jitted callable for jnp/pallas/fused,
    the module source string for verilog). New code should hold the
    richer `Artifact` a `Session.compile` returns."""
    circuit: Circuit
    pass_stats: tuple[PassStats, ...]
    backend: str
    artifact: object

    def __call__(self, x_uint8):
        if not callable(self.artifact):
            raise TypeError(
                f"{self.backend} artifact is not callable (use .artifact)")
        _validate_batch(x_uint8, self.circuit.n_inputs)
        return self.artifact(x_uint8)

    def report(self) -> str:
        """Human-readable per-pass savings table."""
        return "\n".join(s.row() for s in self.pass_stats)


_DEFAULT_SESSION: Session | None = None


def default_session() -> Session:
    """The process-wide Session the deprecated entry points route
    through (memory tier only; configure your own Session for a
    persistent ArtifactStore)."""
    global _DEFAULT_SESSION
    if _DEFAULT_SESSION is None:
        _DEFAULT_SESSION = Session(capacity=16)
    return _DEFAULT_SESSION


def compile_net(
    net,
    *,
    backend: str = "jnp",
    passes=None,
    input_threshold: int | None = None,
    **backend_opts,
) -> CompiledNet:
    """Deprecated: use `Session.compile(net, target=..., pipeline=...)`.

    Kept as a thin shim routed through the default Session. `net` is
    anything `frontend.lower` accepts (a QuantizedNet of any depth, an
    object with `.weights`, or a list of integer matrices). `passes`
    accepts the old pass-callable sequences as well as PipelineSpec /
    spec strings; None means the "default" pipeline. Pass sequences a
    `PipelineSpec` cannot represent (closures, repeated passes) still
    compile — directly and uncached, exactly as the pre-Session
    `compile_net` did.
    """
    warnings.warn(
        "netgen.compile_net is deprecated; use netgen.Session(...).compile("
        "net, target=..., pipeline=...) — see the repro.netgen docstring",
        DeprecationWarning, stacklevel=2)
    try:
        spec = PipelineSpec.coerce(passes)
    except ValueError:
        # unrepresentable legacy pipeline: compile the old way (no cache)
        circuit = lower(net, input_threshold=input_threshold)
        circuit, stats = run_pipeline(circuit, passes)
        artifact = backends.compile_circuit(circuit, backend, **backend_opts)
        return CompiledNet(circuit=circuit, pass_stats=stats,
                           backend=backend.partition("[")[0],
                           artifact=artifact)
    art = default_session().compile(
        net, target=backend, pipeline=spec,
        input_threshold=input_threshold, **backend_opts)
    return CompiledNet(
        circuit=art.circuit, pass_stats=art.pass_stats,
        backend=art.backend, artifact=art.artifact)


def specialize(net, *, backend: str = "jnp", passes=None, pipeline=None, **kw):
    """Compile and return just the jitted predictor (old netgen name)."""
    return default_session().compile(
        net, target=backend,
        pipeline=pipeline if pipeline is not None else passes, **kw).artifact


def emit_verilog(net, *, addend: bool = True, module_name: str = "nn_inference",
                 passes=None) -> str:
    """Compile and return just the Verilog source (old netgen name).

    Matches the seed emitter's behavior: zero terms are always dropped at
    generation time; `addend=True` additionally applies the L5 rewrite.
    """
    if passes is None:
        passes = "zeros,addends" if addend else "zeros"
    return default_session().compile(
        net, target="verilog", pipeline=passes,
        module_name=module_name, addend=addend).artifact


# Serving layer (imported last: it builds on the session machinery).
from repro.netgen import serve  # noqa: E402
from repro.netgen.serve import (  # noqa: E402
    CacheKey, CompileCache, NetServer, cached_compile_net,
    stack_layered_weights,
)
from repro.netgen import engine  # noqa: E402  (builds on serve)
from repro.netgen.engine import (  # noqa: E402
    DeadlineExceededError, EngineClosedError, EngineStats, QueueFullError,
    ServingEngine,
)
