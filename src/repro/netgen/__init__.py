"""`repro.netgen` — the paper's net-to-hardware step as a real compiler.

The source paper's central artifact (§IV-§V) is a Python script that
walks a trained 784-500-10 net and prints a clockless Verilog module,
applying structural optimizations on the way. This package generalizes
that script into a small compiler over a typed circuit IR, so the same
rewrites serve arbitrary-depth nets and multiple execution targets:

    frontend.lower          quantized N-layer stack -> circuit IR
    passes.run_pipeline     exact structural rewrites + per-pass stats
    backends.compile_circuit  IR -> artifact (jitted fn or Verilog text)

Paper-section map
-----------------
  §III.B / Fig. 6 line 5   -> graph.InputCompare (pixel > threshold)
  §III.A step activation   -> graph.SignStep
  §V.D MSB sign-bit trick  -> SignStep emission in backends/verilog.py
                              (and the strict-vs-msb semantics note in
                              graph.evaluate)
  §V.B zero-weight pruning -> passes.delete_zero_terms (per-term) and
     (L4, ~50% cell cut)      passes.prune_dead_units (per-unit)
  §V.C multiplication-free -> passes.addend_rewrite (w*x -> |w| addends;
     (L5, 38k -> <16k cells)  after it, ops().mults == 0)
  beyond the paper         -> passes.share_common_addends (adder CSE,
                              the natural post-L5 hardware rewrite)
  Fig. 6 line 15 argmax    -> graph.Argmax, emitted as a priority mux
  Fig. 6/7 module shape    -> backends/verilog.py "legacy" style
                              (byte-compatible with the seed emitter)

Quick use
---------
    from repro.core.quantize import quantize
    from repro import netgen

    compiled = netgen.compile_net(quantize(params), backend="jnp")
    preds = compiled(images_uint8)          # bit-exact vs predict_l3
    print(compiled.report())                # per-pass savings
    v = netgen.compile_net(qnet, backend="verilog",
                           passes=netgen.HW_PASSES).artifact

Serving (compile cache + multi-version dispatch)
------------------------------------------------
`repro.netgen.serve` makes the compile-per-model-then-serve workflow
operational: compilations are content-addressed (sha256 of the quantized
weights x pass pipeline x backend), so a model version is specialized
exactly once per process, and a `NetServer` routes request batches —
cross-model batches of stack-compatible versions run as ONE jitted
multi-net dispatch:

    cache = netgen.CompileCache(capacity=16)
    server = netgen.NetServer(cache=cache, slot_capacity=64)
    server.register("v1", qnet)              # miss: compiles, ~ms
    server.register("v1-replica", qnet)      # hit: same CompiledNet, ~us
    out = server.predict_many({"v1": imgs_a, "v2": imgs_b})
    print(cache.stats().row())               # hits/misses/compile time

See `benchmarks/bench_netgen_serve.py` for the cold-vs-warm and
stacked-vs-individual numbers.

`repro.core.netgen` remains as a thin compatibility shim with the old
`specialize` / `emit_verilog` / `prune` / `stats` names.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.netgen import backends
from repro.netgen.frontend import lower
from repro.netgen.graph import (
    Argmax, Circuit, InputCompare, IrregularCircuitError, SignStep, Term,
    WeightedSum, as_layered_weights, evaluate, node_widths,
)
from repro.netgen.passes import (
    DEFAULT_PASSES, HW_PASSES, CircuitOps, Pass, PassStats, addend_rewrite,
    delete_zero_terms, ops, prune_dead_units, run_pipeline,
    share_common_addends,
)

__all__ = [
    "Argmax", "CacheKey", "Circuit", "CircuitOps", "CompileCache",
    "CompiledNet", "DEFAULT_PASSES", "HW_PASSES", "InputCompare",
    "IrregularCircuitError", "NetServer", "Pass", "PassStats", "SignStep",
    "Term", "WeightedSum", "addend_rewrite", "as_layered_weights",
    "backends", "cached_compile_net", "compile_net", "delete_zero_terms",
    "emit_verilog", "evaluate", "lower", "node_widths", "ops",
    "prune_dead_units", "run_pipeline", "serve", "share_common_addends",
    "specialize", "stack_layered_weights",
]


def _validate_batch(x, n_inputs: int) -> None:
    """Reject non-uint8 or wrongly-shaped predictor input with a clear
    error instead of silently mis-binarizing (a float image batch would
    compare scaled values against the integer pixel threshold)."""
    dtype = getattr(x, "dtype", None)
    if dtype is None or np.dtype(dtype) != np.uint8:
        raise TypeError(
            f"compiled predictors take raw uint8 images, got dtype={dtype!r} "
            "(binarization happens inside the circuit; do not pre-scale)")
    shape = tuple(getattr(x, "shape", ()))
    if len(shape) != 2 or shape[1] != n_inputs:
        raise ValueError(
            f"expected a (batch, {n_inputs}) uint8 image batch, "
            f"got shape {shape}")


@dataclasses.dataclass(frozen=True)
class CompiledNet:
    """Result of one end-to-end compilation: the optimized circuit, the
    per-pass statistics, and the backend artifact (a jitted callable for
    jnp/pallas/fused, the module source string for verilog)."""
    circuit: Circuit
    pass_stats: tuple[PassStats, ...]
    backend: str
    artifact: object

    def __call__(self, x_uint8):
        if not callable(self.artifact):
            raise TypeError(
                f"{self.backend} artifact is not callable (use .artifact)")
        _validate_batch(x_uint8, self.circuit.n_inputs)
        return self.artifact(x_uint8)

    def report(self) -> str:
        """Human-readable per-pass savings table."""
        return "\n".join(s.row() for s in self.pass_stats)


def compile_net(
    net,
    *,
    backend: str = "jnp",
    passes: Sequence[Pass] | None = None,
    input_threshold: int | None = None,
    **backend_opts,
) -> CompiledNet:
    """Frontend -> pass pipeline -> backend, in one call.

    `net` is anything `frontend.lower` accepts (a QuantizedNet of any
    depth, an object with `.weights`, or a list of integer matrices).
    `passes` defaults to DEFAULT_PASSES (exact rewrites that keep the
    layered form every backend supports); pass HW_PASSES for the full
    multiplication-free + adder-sharing hardware pipeline (verilog only).
    """
    circuit = lower(net, input_threshold=input_threshold)
    circuit, stats = run_pipeline(
        circuit, DEFAULT_PASSES if passes is None else passes)
    artifact = backends.compile_circuit(circuit, backend, **backend_opts)
    return CompiledNet(
        circuit=circuit, pass_stats=stats, backend=backend, artifact=artifact)


def specialize(net, *, backend: str = "jnp", **kw):
    """Compile and return just the jitted predictor (old netgen name)."""
    return compile_net(net, backend=backend, **kw).artifact


def emit_verilog(net, *, addend: bool = True, module_name: str = "nn_inference",
                 passes: Sequence[Pass] | None = None) -> str:
    """Compile and return just the Verilog source (old netgen name).

    Matches the seed emitter's behavior: zero terms are always dropped at
    generation time; `addend=True` additionally applies the L5 rewrite.
    """
    if passes is None:
        passes = (delete_zero_terms, addend_rewrite) if addend \
            else (delete_zero_terms,)
    return compile_net(
        net, backend="verilog", passes=passes,
        module_name=module_name, addend=addend).artifact


# Serving layer (imported last: it needs CompiledNet / compile_net above).
from repro.netgen import serve  # noqa: E402
from repro.netgen.serve import (  # noqa: E402
    CacheKey, CompileCache, NetServer, cached_compile_net,
    stack_layered_weights,
)
