"""Verilog backend: emit a clockless combinational module from the IR.

Two emission styles:

  * legacy  — byte-identical to the original `repro.core.netgen`
    emitter for the paper's regular 2-layer net (Figure 6 structure:
    `in*` comparators, `hi*` sums, `ho*` MSB steps, `fi*` sums,
    priority-mux `prediction`), with one shared signed width per layer
    exactly as the paper sizes its accumulators.
  * generic — any depth, and irregular (CSE-shared) DAGs: per-layer wire
    groups `s{l}_*` / `a{l}_*`, shared sub-sums `t*`, each wire sized by
    the IR's per-node signed bit-width inference.

`style="auto"` (default) picks legacy whenever the circuit is the
regular 2-layer form, preserving the golden artifact, and generic
otherwise. Continuous assignments are order-independent, so emission
order is cosmetic — we keep the paper's grouping either way.

Registered as the `verilog` target (kind "text"; declared options
`module_name`, `style`, `addend` — addressable as
`verilog[style=legacy]` etc.); see `repro.netgen.targets`.
"""
from __future__ import annotations

import math

from repro.netgen.analysis import RangeAnalysis, analyze_ranges
from repro.netgen.graph import (
    Argmax, Circuit, InputCompare, IrregularCircuitError, SignStep,
    WeightedSum,
)
from repro.netgen.plan import lower_circuit

__all__ = ["emit_verilog"]


def _sum_expr(terms, names) -> str:
    """Render one accumulator: signed sum of named sources, in term order.
    Unit weights print bare names (the multiplication-free form); other
    magnitudes print `|w|*name` (pre-L5 style)."""
    units: list[tuple[int, str]] = []
    for t in terms:
        name = names[t.src]
        mag = abs(t.weight)
        term = name if mag == 1 else f"{mag}*{name}"
        units.append((1 if t.weight > 0 else -1, term))
    if not units:
        return "0"
    parts = [units[0][1] if units[0][0] > 0 else f"-{units[0][1]}"]
    for sign, term in units[1:]:
        parts.append(("+ " if sign > 0 else "- ") + term)
    return " ".join(parts)


def _argmax_mux(n_out: int, pw: int, names: list[str]) -> str:
    """Priority chain of comparators computing argmax (first max wins) —
    the flat equivalent of the paper's single wide comparison LUT."""
    expr = f"{pw}'d{n_out-1}"
    for k in range(n_out - 2, -1, -1):
        conds = " && ".join(
            f"{names[k]} >= {names[m]}" for m in range(k + 1, n_out))
        expr = f"(({conds}) ? {pw}'d{k} : {expr})"
    return expr


def _layer_width(bounds: dict, layer_sums: list[WeightedSum]) -> int:
    """The original emitter's per-layer accumulator width: the max column
    sum of |w|, plus one, rounded up — `_acc_width` verbatim."""
    bound = max((bounds[n.id] for n in layer_sums), default=0) + 1
    return max(math.ceil(math.log2(bound + 1)) + 1, 2)


def _is_addend_form(circuit: Circuit) -> bool:
    return all(
        abs(t.weight) <= 1
        for n in circuit.by_kind(WeightedSum) for t in n.terms)


def emit_verilog(
    circuit: Circuit,
    *,
    module_name: str = "nn_inference",
    style: str = "auto",
    addend: bool | None = None,
    _analysis: RangeAnalysis | None = None,
) -> str:
    """Emit the circuit as a combinational Verilog module. `addend`
    controls only the header comment (None: detect from the terms).
    Accumulator widths come from the shared range analysis — the
    Session driver passes its pre-backend `RangeAnalysis` as
    `_analysis` (the verilog target declares `wants_analysis`), so the
    emitted widths are exactly the ones the analysis proved; direct
    callers get the same analysis computed here."""
    if style not in ("auto", "legacy", "generic"):
        raise ValueError(f"unknown style {style!r}")
    if addend is None:
        addend = _is_addend_form(circuit)
    ranges = analyze_ranges(circuit) if _analysis is None else _analysis
    if style in ("auto", "legacy"):
        try:
            if circuit.depth == 2:
                lower_circuit(circuit)       # regularity check only
                return _emit_legacy(circuit, module_name, addend, ranges)
        except IrregularCircuitError:
            if style == "legacy":
                raise
        if style == "legacy":
            raise IrregularCircuitError(
                "legacy style requires the regular 2-layer form")
    return _emit_generic(circuit, module_name, addend, ranges)


# ---------------------------------------------------------------------------
# Legacy style (paper Figure 6; byte-compatible with the seed emitter)
# ---------------------------------------------------------------------------

def _emit_legacy(circuit: Circuit, module_name: str, addend: bool,
                 ranges: RangeAnalysis) -> str:
    inputs = sorted(circuit.by_kind(InputCompare), key=lambda n: n.pixel)
    sums = circuit.by_kind(WeightedSum)
    hidden = [n for n in sums if n.layer == 1]
    final = [n for n in sums if n.layer == 2]
    steps = circuit.by_kind(SignStep)
    step_of = {s.src: s for s in steps}
    bounds = ranges.bounds()

    n_in, n_h, n_out = len(inputs), len(hidden), len(final)
    bw1, bw2 = _layer_width(bounds, hidden), _layer_width(bounds, final)
    pw = max(math.ceil(math.log2(n_out)), 1)

    names: dict[int, str] = {}
    for i, n in enumerate(inputs):
        names[n.id] = f"in{i}"
    for j, n in enumerate(hidden):
        names[n.id] = f"hi{j}"
        names[step_of[n.id].id] = f"ho{j}"
    for k, n in enumerate(final):
        names[n.id] = f"fi{k}"

    L: list[str] = []
    L.append(f"// Auto-generated by repro.core.netgen — do not edit.")
    L.append(f"// {n_in}-{n_h}-{n_out} feed-forward classifier, clockless.")
    L.append(f"module {module_name} (")
    L.append("    input  wire [7:0] " + ", ".join(f"px{i}" for i in range(n_in)) + ",")
    L.append(f"    output wire [{pw-1}:0] prediction")
    L.append(");")
    L.append(f"  wire " + ", ".join(f"in{i}" for i in range(n_in)) + ";")
    L.append(f"  wire signed [{bw1-1}:0] " + ", ".join(f"hi{j}" for j in range(n_h)) + ";")
    L.append(f"  wire " + ", ".join(f"ho{j}" for j in range(n_h)) + ";")
    L.append(f"  wire signed [{bw2-1}:0] " + ", ".join(f"fi{k}" for k in range(n_out)) + ";")
    L.append("")
    L.append("  // input comparators (paper L2: pixel > threshold)")
    for i, n in enumerate(inputs):
        L.append(f"  assign in{i} = (px{i} > {n.threshold}) ? 1'b1 : 1'b0;")
    L.append("")
    L.append("  // hidden-input sums (L4 pruned" + (", L5 addend form)" if addend else ")"))
    for j, n in enumerate(hidden):
        L.append(f"  assign hi{j} = {_sum_expr(n.terms, names)};")
    L.append("")
    L.append("  // step activation via sign bit (paper §V.D MSB trick)")
    for j in range(n_h):
        L.append(f"  assign ho{j} = ~hi{j}[{bw1-1}];")
    L.append("")
    L.append("  // final-input sums")
    for k, n in enumerate(final):
        L.append(f"  assign fi{k} = {_sum_expr(n.terms, names)};")
    L.append("")
    L.append("  // prediction: index of the maximum final input (paper Figure 6 line 15)")
    expr = _argmax_mux(n_out, pw, [f"fi{k}" for k in range(n_out)])
    L.append(f"  assign prediction = {expr};")
    L.append("endmodule")
    return "\n".join(L) + "\n"


# ---------------------------------------------------------------------------
# Generic style (any depth, irregular DAGs, per-node widths)
# ---------------------------------------------------------------------------

def _emit_generic(circuit: Circuit, module_name: str, addend: bool,
                  ranges: RangeAnalysis) -> str:
    inputs = sorted(circuit.by_kind(InputCompare), key=lambda n: n.pixel)
    sums = circuit.by_kind(WeightedSum)
    steps = circuit.by_kind(SignStep)
    argmax = circuit.node(circuit.output)
    assert isinstance(argmax, Argmax)
    step_of = {s.src: s for s in steps}
    widths = ranges.widths()
    depth = circuit.depth

    final_ids = set(argmax.srcs)
    final = [circuit.node(s) for s in argmax.srcs]
    # layer sums feed a step; shared CSE sub-sums feed other sums directly
    by_layer: dict[int, list[WeightedSum]] = {}
    shared: list[WeightedSum] = []
    for n in sums:
        if n.id in final_ids:
            continue
        (by_layer.setdefault(n.layer, []) if n.id in step_of else shared).append(n)

    names: dict[int, str] = {}
    for i, n in enumerate(inputs):
        names[n.id] = f"in{i}"
    for layer, group in sorted(by_layer.items()):
        for j, n in enumerate(group):
            names[n.id] = f"s{layer}_{j}"
            names[step_of[n.id].id] = f"a{layer}_{j}"
    for m, n in enumerate(shared):
        names[n.id] = f"t{m}"
    for k, n in enumerate(final):
        names[n.id] = f"fi{k}"

    n_in, n_out = len(inputs), len(final)
    sizes = [len(by_layer.get(l, [])) for l in range(1, depth)] + [n_out]
    pw = max(math.ceil(math.log2(n_out)), 1)

    def decl(group: list[WeightedSum]) -> list[str]:
        return [
            f"  wire signed [{widths[n.id]-1}:0] {names[n.id]};" for n in group]

    L: list[str] = []
    L.append("// Auto-generated by repro.netgen — do not edit.")
    L.append("// " + "-".join(str(s) for s in [n_in] + sizes)
             + " feed-forward classifier, clockless.")
    L.append(f"module {module_name} (")
    L.append("    input  wire [7:0] " + ", ".join(f"px{i}" for i in range(n_in)) + ",")
    L.append(f"    output wire [{pw-1}:0] prediction")
    L.append(");")
    L.append("  wire " + ", ".join(f"in{i}" for i in range(n_in)) + ";")
    for layer in sorted(by_layer):
        group = by_layer[layer]
        L.extend(decl(group))
        L.append("  wire " + ", ".join(names[step_of[n.id].id] for n in group) + ";")
    if shared:
        L.extend(decl(shared))
    L.extend(decl(final))
    L.append("")
    L.append("  // input comparators (paper L2: pixel > threshold)")
    for i, n in enumerate(inputs):
        L.append(f"  assign in{i} = (px{i} > {n.threshold}) ? 1'b1 : 1'b0;")
    if shared:
        L.append("")
        L.append("  // shared sub-sums (common-addend CSE)")
        for n in shared:
            L.append(f"  assign {names[n.id]} = {_sum_expr(n.terms, names)};")
    for layer in sorted(by_layer):
        group = by_layer[layer]
        L.append("")
        L.append(f"  // layer {layer} sums (L4 pruned"
                 + (", L5 addend form)" if addend else ")"))
        for n in group:
            L.append(f"  assign {names[n.id]} = {_sum_expr(n.terms, names)};")
        L.append(f"  // layer {layer} step activations via sign bit (§V.D MSB trick)")
        for n in group:
            s = names[step_of[n.id].id]
            L.append(f"  assign {s} = ~{names[n.id]}[{widths[n.id]-1}];")
    L.append("")
    L.append("  // final-input sums")
    for n in final:
        L.append(f"  assign {names[n.id]} = {_sum_expr(n.terms, names)};")
    L.append("")
    L.append("  // prediction: index of the maximum final input (priority mux)")
    expr = _argmax_mux(n_out, pw, [names[n.id] for n in final])
    L.append(f"  assign prediction = {expr};")
    L.append("endmodule")
    return "\n".join(L) + "\n"
