"""Pluggable netgen backends, enumerated by the Target registry.

A backend turns an optimized circuit into an artifact:

  jnp      — jitted adds-only predictor, weights as XLA literals (oracle)
  pallas   — per-layer binary_matvec TPU kernel chain
  fused    — single-launch whole-net Pallas kernel (2-layer only)
  verilog  — the paper's combinational module source (string)
  cost     — IR walk -> logic-cell estimate vs the paper's Figure 7

`compile_circuit(circuit, backend)` dispatches by name — `backend` may
carry bracketed options ("verilog[style=legacy]", "pallas[interpret]")
— through `repro.netgen.targets`, the registry that owns each target's
entry point, artifact kind, declared options, and multi-net form.
Callable artifacts map uint8 image batches to predicted class indices.

The jnp and pallas targets additionally offer a *multi-net* form
(`compile_multi`): M versions' reconstructed weight matrices, stacked
along a model axis, become one jitted (M, B, n_in) -> (M, B) dispatch —
the cross-model batching used by `repro.netgen.serve.NetServer`.
"""
from __future__ import annotations

from repro.netgen.backends.cost import CellCounts, CostReport, logic_cells
from repro.netgen.backends.jnp import compile_jnp, compile_jnp_multi
from repro.netgen.backends.pallas import (
    compile_fused, compile_pallas, compile_pallas_multi,
)
from repro.netgen.backends.verilog import emit_verilog
from repro.netgen.targets import (
    Target, get_target, list_targets, register_target, resolve_target,
)

BACKENDS = tuple(t.name for t in list_targets())
MULTI_BACKENDS = tuple(
    t.name for t in list_targets() if t.compile_multi is not None)


def compile_circuit(circuit, backend: str = "jnp", **opts):
    """Compile an IR circuit with the named target. Extra options are
    target-specific (declared in the registry; e.g. module_name/style/
    addend for verilog, interpret for pallas/fused)."""
    target, merged = resolve_target(backend, opts)
    return target.compile(circuit, **merged)


def compile_multi(stacked_ws, input_threshold: int, backend: str = "jnp",
                  **opts):
    """Compile M stacked weight sets into one jitted multi-net dispatch:
    uint8 (M, B, n_in) -> predictions (M, B). `backend` accepts bracket
    options like the single-net form (e.g. "pallas[interpret=false]")."""
    target, merged = resolve_target(backend, opts)
    if target.compile_multi is None:
        raise ValueError(
            f"target {target.name!r} has no multi-net dispatch "
            f"(have {MULTI_BACKENDS})")
    return target.compile_multi(stacked_ws, input_threshold, **merged)
