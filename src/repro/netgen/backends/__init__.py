"""Pluggable netgen backends.

A backend turns an optimized circuit into an artifact:

  jnp      — jitted adds-only predictor, weights as XLA literals (oracle)
  pallas   — per-layer binary_matvec TPU kernel chain
  fused    — single-launch whole-net Pallas kernel (2-layer only)
  verilog  — the paper's combinational module source (string)

`compile_circuit(circuit, backend)` dispatches by name; callable
artifacts map uint8 image batches to predicted class indices.

The jnp and pallas backends additionally offer a *multi-net* form
(`compile_multi`): M versions' reconstructed weight matrices, stacked
along a model axis, become one jitted (M, B, n_in) -> (M, B) dispatch —
the cross-model batching used by `repro.netgen.serve.NetServer`.
"""
from __future__ import annotations

from repro.netgen.backends.jnp import compile_jnp, compile_jnp_multi
from repro.netgen.backends.pallas import (
    compile_fused, compile_pallas, compile_pallas_multi,
)
from repro.netgen.backends.verilog import emit_verilog

BACKENDS = ("jnp", "pallas", "fused", "verilog")
MULTI_BACKENDS = ("jnp", "pallas")


def compile_circuit(circuit, backend: str = "jnp", **opts):
    """Compile an IR circuit with the named backend. Extra options are
    backend-specific (e.g. module_name/style/addend for verilog)."""
    if backend == "jnp":
        return compile_jnp(circuit, **opts)
    if backend == "pallas":
        return compile_pallas(circuit, **opts)
    if backend == "fused":
        return compile_fused(circuit, **opts)
    if backend == "verilog":
        return emit_verilog(circuit, **opts)
    raise ValueError(f"unknown backend {backend!r} (have {BACKENDS})")


def compile_multi(stacked_ws, input_threshold: int, backend: str = "jnp"):
    """Compile M stacked weight sets into one jitted multi-net dispatch:
    uint8 (M, B, n_in) -> predictions (M, B)."""
    if backend == "jnp":
        return compile_jnp_multi(stacked_ws, input_threshold)
    if backend == "pallas":
        return compile_pallas_multi(stacked_ws, input_threshold)
    raise ValueError(
        f"backend {backend!r} has no multi-net dispatch (have {MULTI_BACKENDS})")
