"""Pluggable netgen backends, enumerated by the Target registry.

A backend turns an optimized circuit into an artifact:

  jnp      — jitted adds-only predictor, weights as XLA literals (oracle)
  pallas   — per-layer binary_matvec TPU kernel chain (`packed=true`
             selects the bit-packed activation datapath)
  fused    — single-launch whole-net Pallas kernel (2-layer only)
  verilog  — the paper's combinational module source (string)
  cost     — IR walk -> logic-cell estimate vs the paper's Figure 7

Every array backend (jnp / pallas / fused) compiles through ONE
lowering step — `repro.netgen.plan.lower_circuit`, which turns the
circuit IR into a layer-structured `ExecutionPlan` — and is a thin
executor over that plan; no backend extracts weights from IR nodes
itself.

`compile_circuit(circuit, backend)` dispatches by name — `backend` may
carry bracketed options ("verilog[style=legacy]", "pallas[packed=true]")
— through `repro.netgen.targets`, the registry that owns each target's
entry point, artifact kind, declared options, and multi-net form.
Callable artifacts map uint8 image batches to predicted class indices.

The jnp and pallas targets additionally offer a *multi-net* form
(`compile_multi`): a *stacked* ExecutionPlan (M versions' plans joined
along a leading model axis by `repro.netgen.plan.stack_plans`) becomes
one jitted (M, B, n_in) -> (M, B) dispatch — the cross-model batching
used by `repro.netgen.serve.NetServer`. The multi form accepts exactly
the same declared target options as the single-net form.
"""
from __future__ import annotations

from repro.netgen.backends.cost import CellCounts, CostReport, logic_cells
from repro.netgen.backends.jnp import compile_jnp, compile_jnp_multi
from repro.netgen.backends.pallas import (
    compile_fused, compile_pallas, compile_pallas_multi,
)
from repro.netgen.backends.verilog import emit_verilog
from repro.netgen.targets import (
    Target, get_target, list_targets, register_target, resolve_target,
)

BACKENDS = tuple(t.name for t in list_targets())
MULTI_BACKENDS = tuple(
    t.name for t in list_targets() if t.compile_multi is not None)


def compile_circuit(circuit, backend: str = "jnp", **opts):
    """Compile an IR circuit with the named target. Extra options are
    target-specific (declared in the registry; e.g. module_name/style/
    addend for verilog, interpret/packed for pallas)."""
    target, merged = resolve_target(backend, opts)
    return target.compile(circuit, **merged)


def compile_multi(plan, backend: str = "jnp", tuner=None, **opts):
    """Compile a stacked ExecutionPlan into one jitted multi-net
    dispatch: uint8 (M, B, n_in) -> predictions (M, B). `backend`
    accepts bracket options like the single-net form (e.g.
    "pallas[packed=true]", "pallas[tuned=true]"); options are validated
    against the target's declaration — there is no raw-kwargs side
    door. `tuner` (a `repro.netgen.tune.KernelTuner`, not a declared
    option) reaches targets that want one — the serving layer passes
    its session's tuner so stacked dispatch builds reuse persisted
    tuning records.

    The plan is certified by `repro.netgen.analysis.verify_plan` before
    any backend sees it: chain/padding/plane-decomposition violations
    raise a structured `VerificationError` (a ValueError — the serving
    layer's fallback path still catches it) instead of a backend shape
    error deep inside a jit trace."""
    target, merged = resolve_target(backend, opts)
    if target.compile_multi is None:
        raise ValueError(
            f"target {target.name!r} has no multi-net dispatch "
            f"(have {MULTI_BACKENDS})")
    from repro.netgen import analysis
    analysis.verify_plan(plan, stage="compile_multi")
    if target.wants_tuner:
        merged["_tuner"] = tuner
    return target.compile_multi(plan, **merged)
