"""Pluggable netgen backends.

A backend turns an optimized circuit into an artifact:

  jnp      — jitted adds-only predictor, weights as XLA literals (oracle)
  pallas   — per-layer binary_matvec TPU kernel chain
  fused    — single-launch whole-net Pallas kernel (2-layer only)
  verilog  — the paper's combinational module source (string)

`compile_circuit(circuit, backend)` dispatches by name; callable
artifacts map uint8 image batches to predicted class indices.
"""
from __future__ import annotations

from repro.netgen.backends.jnp import compile_jnp
from repro.netgen.backends.pallas import compile_fused, compile_pallas
from repro.netgen.backends.verilog import emit_verilog

BACKENDS = ("jnp", "pallas", "fused", "verilog")


def compile_circuit(circuit, backend: str = "jnp", **opts):
    """Compile an IR circuit with the named backend. Extra options are
    backend-specific (e.g. module_name/style/addend for verilog)."""
    if backend == "jnp":
        return compile_jnp(circuit, **opts)
    if backend == "pallas":
        return compile_pallas(circuit, **opts)
    if backend == "fused":
        return compile_fused(circuit, **opts)
    if backend == "verilog":
        return emit_verilog(circuit, **opts)
    raise ValueError(f"unknown backend {backend!r} (have {BACKENDS})")
