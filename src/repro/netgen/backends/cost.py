"""Cost target: walk the IR and estimate FPGA logic-cell usage.

The paper reports its optimizations in *logic cells* (Figure 7: >80k
cells for the naive 784-500-10 circuit, ~38k after zero pruning, <16k in
the multiplication-free addend form). This backend is the structural-
hash analogue the ROADMAP asked for: instead of emitting Verilog and
synthesizing, it walks the circuit graph and prices each node with a
simple 4-input-LUT fabric model:

  InputCompare — an 8-bit magnitude comparator: `ceil(8/4) + 1` cells
                 (two 4-LUT slices plus the combining cell).
  WeightedSum  — a compressor (adder) tree. Summing N input *bits* down
                 to a W-bit result costs about `N - W` full adders, one
                 logic cell each; a term contributes `|w| * width(src)`
                 input bits (the |w| repeated addends the L5 rewrite
                 makes explicit — hardware pays them either way). A
                 `0 * x` term still occupies one adder slot (the paper's
                 generated module instantiates it before synthesis can
                 prove it zero — deleting them is exactly the L4 ~50%
                 cut), and every term with |w| > 1 prices its constant
                 multiplier at `width(src) * ceil(log2(|w|+1))` cells —
                 the cells the L5 addend rewrite deletes (38k -> <16k).
  SignStep     — free: the paper's §V.D trick reads the accumulator MSB.
  Argmax       — a priority chain of (n-1) W-bit comparators plus the
                 index mux: `(n-1) * (W + index_width)` cells.

The estimate is deliberately proportional-not-gospel — its job is to
rank rewrites and track the paper's Figure-7 trajectory, which is why
`CostReport` carries the paper's reference counts alongside and, when
compiled through a `Session`/pipeline, a per-pass breakdown (the cost of
the circuit after every pass boundary).
"""
from __future__ import annotations

import dataclasses
import math

from repro.netgen.graph import (
    Argmax, Circuit, InputCompare, SignStep, WeightedSum, value_bounds,
    signed_width,
)

__all__ = ["CellCounts", "CostReport", "compile_cost", "logic_cells"]

LUT_INPUTS = 4

# Paper Figure 7, 784-500-10 net (approximate read-offs; see module doc).
PAPER_FIG7_CELLS = {"naive": 80000, "pruned": 38000, "addend": 16000}


@dataclasses.dataclass(frozen=True)
class CellCounts:
    """Logic-cell estimate for one circuit, split by structure."""
    compare_cells: int
    adder_cells: int
    mult_cells: int
    argmax_cells: int

    @property
    def total(self) -> int:
        return (self.compare_cells + self.adder_cells + self.mult_cells
                + self.argmax_cells)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total"] = self.total
        return d

    def row(self) -> str:
        return (f"cells {self.total} (compare {self.compare_cells}, "
                f"adders {self.adder_cells}, mults {self.mult_cells}, "
                f"argmax {self.argmax_cells})")


def logic_cells(circuit: Circuit, *, analysis=None) -> CellCounts:
    """Price one circuit with the LUT model in the module doc.

    `analysis`, when given, is the driver's pre-backend
    `repro.netgen.analysis.RangeAnalysis`: its proven widths are used
    directly instead of re-deriving them from `value_bounds` (the two
    agree by construction — the analysis subsumes the ad-hoc width
    inference)."""
    if analysis is not None:
        width = analysis.widths()
    else:
        bounds = value_bounds(circuit)
        width = {
            nid: (1 if isinstance(circuit.node(nid),
                                  (InputCompare, SignStep))
                  else signed_width(b))
            for nid, b in bounds.items()}
    compare = adder = mult = argmax = 0
    cmp_cost = math.ceil(8 / LUT_INPUTS) + 1
    for n in circuit.nodes:
        if isinstance(n, InputCompare):
            compare += cmp_cost
        elif isinstance(n, WeightedSum):
            # a zero-weight term still occupies one adder slot (see doc)
            in_bits = sum(
                max(abs(t.weight), 1) * width[t.src] for t in n.terms)
            adder += max(in_bits - width[n.id], 0)
            for t in n.terms:
                if abs(t.weight) > 1:
                    mult += width[t.src] * math.ceil(
                        math.log2(abs(t.weight) + 1))
        elif isinstance(n, Argmax):
            w = max((width[s] for s in n.srcs), default=1)
            idx = max(math.ceil(math.log2(max(len(n.srcs), 2))), 1)
            argmax += max(len(n.srcs) - 1, 0) * (w + idx)
    return CellCounts(compare_cells=compare, adder_cells=adder,
                      mult_cells=mult, argmax_cells=argmax)


@dataclasses.dataclass(frozen=True)
class CostReport:
    """The cost target's artifact: the final circuit's cell estimate, the
    per-pass trajectory (when compiled through a pipeline), and the
    paper's Figure-7 reference counts for side-by-side reading."""
    final: CellCounts
    per_pass: tuple = ()        # ((stage_name, CellCounts), ...)
    paper_fig7: tuple = tuple(sorted(PAPER_FIG7_CELLS.items()))

    def as_dict(self) -> dict:
        return {
            "final": self.final.as_dict(),
            "per_pass": [[name, c.as_dict()] for name, c in self.per_pass],
            "paper_fig7": dict(self.paper_fig7),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CostReport":
        mk = lambda c: CellCounts(**{  # noqa: E731
            k: v for k, v in c.items() if k != "total"})
        return cls(
            final=mk(d["final"]),
            per_pass=tuple((name, mk(c)) for name, c in d["per_pass"]),
            paper_fig7=tuple(sorted(d["paper_fig7"].items())))

    def report(self) -> str:
        lines = [f"{name}: {c.row()}" for name, c in self.per_pass]
        lines.append(f"final: {self.final.row()}")
        lines.append("paper fig7: " + ", ".join(
            f"{k}~{v}" for k, v in self.paper_fig7))
        return "\n".join(lines)


def compile_cost(circuit: Circuit, *, _pass_trace=None,
                 _analysis=None) -> CostReport:
    """The `cost` target entry point. `_pass_trace`, supplied by the
    Session driver, is the ((stage_name, circuit), ...) sequence of
    pipeline boundaries — each is priced so the report shows which pass
    bought which cells, the paper's Figure-7 story per rewrite.
    `_analysis` is the driver's range analysis of the FINAL circuit;
    intermediate trace circuits differ structurally, so they are priced
    with freshly derived widths."""
    per_pass = tuple(
        (name, logic_cells(c)) for name, c in (_pass_trace or ()))
    return CostReport(final=logic_cells(circuit, analysis=_analysis),
                      per_pass=per_pass)
