"""Pallas backend: compile a regular circuit onto the TPU kernels.

Per-layer path (any depth) chains the `binary_matvec` masked-accumulate
kernel — the VPU select/add realization of the paper's L5 rewrite — with
a sign-bit step between layers. The `fused` variant lowers the whole
2-layer paper net into the single-launch `fused_mlp` kernel, the
combinational-circuit analogue (one "net" per prediction, intermediate
activations never leaving VMEM).

Kernels run in interpret mode on CPU containers (see kernels/*/ops.py);
on a real TPU the same code path compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netgen.graph import Circuit, IrregularCircuitError, as_layered_weights

__all__ = ["compile_pallas", "compile_pallas_multi", "compile_fused"]


def compile_pallas(circuit: Circuit, *, interpret: bool | None = None):
    """Return a jitted fn chaining one binary_matmul launch per layer.

    `interpret` overrides the kernel ops' container default (interpret
    mode on CPU); pass `pallas[interpret=false]` on a real TPU to lower
    through Mosaic.
    """
    from repro.kernels.binary_matvec import ops as bmv

    kw = {} if interpret is None else {"interpret": interpret}
    ws = [jnp.asarray(w, jnp.int32) for w in as_layered_weights(circuit)]
    thr = circuit.input_threshold

    def matmul(a, w):
        if w.shape[0] == 0:  # fully-pruned predecessor layer: constant 0
            return jnp.zeros((a.shape[0], w.shape[1]), jnp.int32)
        return bmv.binary_matmul(a, w, **kw)

    @jax.jit
    def predict(x_uint8):
        a = (x_uint8.astype(jnp.int32) > thr).astype(jnp.int8)
        for w in ws[:-1]:
            a = (matmul(a, w) > 0).astype(jnp.int8)
        return jnp.argmax(matmul(a, ws[-1]), axis=-1)

    return predict


def compile_pallas_multi(stacked_ws, input_threshold: int,
                         *, interpret: bool | None = None):
    """Multi-net dispatch through the binary_matvec kernel chain.

    `stacked_ws` is a list of (M, fan_in, fan_out) int arrays (padded and
    stacked per `repro.netgen.serve.stack_layered_weights`). The model
    axis is swept with `lax.map` — a scan whose body is the per-layer
    kernel chain, so the whole M-version batch is one jitted dispatch and
    each version's weights stream through the same kernel traces.
    `interpret` as in `compile_pallas` (the single-version path and the
    stacked path must honor the same target options).
    """
    from repro.kernels.binary_matvec import ops as bmv

    kw = {} if interpret is None else {"interpret": interpret}
    ws = [jnp.asarray(w, jnp.int32) for w in stacked_ws]
    thr = int(input_threshold)

    def matmul(a, w):
        if w.shape[0] == 0:  # fully-pruned predecessor layer: constant 0
            return jnp.zeros((a.shape[0], w.shape[1]), jnp.int32)
        return bmv.binary_matmul(a, w, **kw)

    def one_version(slices):
        x, *wm = slices
        a = (x.astype(jnp.int32) > thr).astype(jnp.int8)
        for w in wm[:-1]:
            a = (matmul(a, w) > 0).astype(jnp.int8)
        return jnp.argmax(matmul(a, wm[-1]), axis=-1)

    @jax.jit
    def predict(x_uint8):                            # (M, B, n_in)
        return jax.lax.map(one_version, (x_uint8, *ws))

    return predict


def compile_fused(circuit: Circuit, *, interpret: bool | None = None):
    """Whole-net single Pallas launch; 2-layer circuits only."""
    from repro.kernels.fused_mlp import ops as fused

    kw = {} if interpret is None else {"interpret": interpret}
    ws = as_layered_weights(circuit)
    if len(ws) != 2:
        raise IrregularCircuitError(
            f"fused backend supports exactly 2 layers, got {len(ws)}")
    w1 = jnp.asarray(ws[0], jnp.int32)
    w2 = jnp.asarray(ws[1], jnp.int32)
    thr = circuit.input_threshold

    @jax.jit
    def predict(x_uint8):
        return fused.fused_mlp_predict(x_uint8, w1, w2, threshold=thr, **kw)

    return predict
