"""Pallas backend: execute an ExecutionPlan on the TPU kernels.

Per-layer path (any depth) chains the `binary_matvec` kernels — the
VPU realization of the paper's L5 rewrite — with the step fused into
the layer boundary. Three datapaths, selected by the plan form
(`pallas[packed=true]`, `pallas[planes=true]`):

  dense   — activations travel as int8 {0,1} vectors into
            `binary_matmul` (one byte per wire, int32 weights).
  packed  — activations are bit-packed 32-per-uint32 word END TO END:
            the input binarizer emits packed words, every hidden step
            emits packed words (`step_pack` — no int8 activation ever
            materializes between layers), and `binary_matmul_packed`
            consumes them (one *bit* per wire; weights still int32).
  planes  — the fully bit-packed datapath: weights decomposed into
            packed signed bit-planes (`plan.planes()`) and accumulated
            by `binary_matmul_planes` as
            sum_b 2^b (popcount(x & pos_b) - popcount(x & neg_b)) —
            both operands travel as bits, the paper's selected-addends
            taken to the XNOR/AND+popcount form of the BNN-on-FPGA
            literature. Plane count tracks the post-pass weight
            magnitude range, so a quantized net moves ~2P bits of
            weight per addend instead of 32.

A fourth datapath, `pallas[fusednet=true]`, abandons the per-layer
chain entirely: the whole planes-form net (any depth, single or
stacked) runs as ONE persistent `binary_forward_planes` launch — every
layer's bit-plane weights resident in VMEM, step+repack in-register
between layers, argmax fused — via `plan.megakernel_view()`. It is the
*preferred* planes path for the stacked multi-net dispatch
(`compile_pallas_multi` upgrades `planes=true` to the megakernel,
falling back to the per-layer chain if the plan has no megakernel
view), and each predictor call is exactly one kernel launch, counted in
`netgen_kernel_launches_total{form}`.

Block sizes (`bm`, `bn`, `bkw`) are declared target options; with
`pallas[tuned=true]` they — and, when no form is forced, the
dense/packed/planes/fusednet choice itself — are grid-searched per
(plan shape x device kind) through `repro.netgen.tune` and persisted,
so a warm process never re-measures (`Session(tune_store=...)`).

The `fused` variant lowers the whole 2-layer paper net into the
single-launch `fused_mlp` kernel, the combinational-circuit analogue
(one "net" per prediction, intermediate activations never leaving
VMEM); `fused[tuned=true]` searches its batch tile.

Kernels run in interpret mode on CPU containers (see kernels/*/ops.py);
on a real TPU the same code path compiles to Mosaic.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.netgen.graph import Circuit, IrregularCircuitError
from repro.netgen.plan import ExecutionPlan, lower_circuit

__all__ = ["compile_pallas", "compile_pallas_multi", "compile_fused"]

_FORMS = ("dense", "packed", "planes")
# Executable datapaths: the plan forms plus the whole-net megakernel
# (which runs the planes form, but as one persistent launch).
_DATAPATHS = ("dense", "packed", "planes", "fusednet")

# The tuner's default candidate grid: block sizes the binary_matvec
# kernels accept, small enough to search in seconds yet covering the
# batch/fan-out/reduction trade-offs that actually move the needle.
_TUNE_BLOCKS = (
    {"bm": 128, "bn": 128, "bkw": 8},
    {"bm": 128, "bn": 128, "bkw": 16},
    {"bm": 64, "bn": 128, "bkw": 8},
    {"bm": 128, "bn": 64, "bkw": 8},
)
_TUNE_BATCH = 256        # measurement batch: the serve layer's default cap


def _resolve_form(packed: bool, planes: bool,
                  fusednet: bool = False) -> str | None:
    """The explicitly requested datapath, or None when the caller left
    the choice open (tuned=true may then search it). `fusednet` runs
    the planes form, so planes+fusednet means fusednet; packed is a
    different activation encoding and stays exclusive."""
    if packed and (planes or fusednet):
        raise ValueError(
            "pallas: packed=true is exclusive with the bit-plane "
            "datapaths (planes=true / fusednet=true)")
    if fusednet:
        return "fusednet"
    if planes:
        return "planes"
    if packed:
        return "packed"
    return None


def _in_form(plan: ExecutionPlan, form: str) -> ExecutionPlan:
    if form in ("planes", "fusednet"):
        return plan.planes()
    if form == "packed":
        return plan.pack()
    return plan


def _blocks_kw(form: str, blocks: dict) -> dict:
    """Map the declared bm/bn/bkw options onto the kernel entry point's
    keywords (the dense kernel's K tile is in bits, not words)."""
    kw = {}
    for k in ("bm", "bn"):
        if blocks.get(k) is not None:
            kw[k] = int(blocks[k])
    if blocks.get("bkw") is not None:
        if form == "dense":
            kw["bk"] = int(blocks["bkw"]) * 32
        else:
            kw["bkw"] = int(blocks["bkw"])
    return kw


def _chain(plan: ExecutionPlan, kw: dict, blocks: dict):
    """Build one version's layer chain for the plan's form.

    Returns (arrays, run): `arrays` is a flat tuple of per-layer jnp
    arrays (leading model axis when the plan is stacked — `lax.map`
    slices them per version) and `run(x_uint8, *arrays)` maps one
    version's uint8 batch to predicted classes. The packed and planes
    chains are packed END TO END: binarize emits uint32 words, every
    hidden boundary is a fused `step_pack`, and no int8 activation
    exists between layers.
    """
    from repro.kernels.binary_matvec import ops as bmv

    form = plan.form
    thr = plan.input_threshold
    bkw_kw = {**_blocks_kw(form, blocks), **kw}

    if form == "dense":
        arrays = tuple(jnp.asarray(l.weights, jnp.int32) for l in plan.layers)

        def matmul(a, w):
            if w.shape[-2] == 0:     # fully-pruned predecessor: constant 0
                return jnp.zeros((a.shape[0], w.shape[-1]), jnp.int32)
            return bmv.binary_matmul(a, w, **bkw_kw)

        def run(x_uint8, *ws):
            a = (x_uint8.astype(jnp.int32) > thr).astype(jnp.int8)
            for w in ws[:-1]:
                a = (matmul(a, w) > 0).astype(jnp.int8)
            return jnp.argmax(matmul(a, ws[-1]), axis=-1)

        return arrays, run

    words = [l.words for l in plan.layers]

    if form == "packed":
        arrays = tuple(jnp.asarray(l.weights, jnp.int32) for l in plan.layers)

        def matmul(a, w):
            if w.shape[-2] == 0:
                return jnp.zeros((a.shape[0], w.shape[-1]), jnp.int32)
            return bmv.binary_matmul_packed(a, w, **bkw_kw)

        def run(x_uint8, *ws):
            a = bmv.binarize_pack(x_uint8, threshold=thr, words=words[0])
            for w, nxt in zip(ws[:-1], words[1:]):
                a = bmv.step_pack(matmul(a, w), words=nxt)
            return jnp.argmax(matmul(a, ws[-1]), axis=-1)

        return arrays, run

    assert form == "planes", form
    arrays = []
    for layer in plan.layers:
        arrays.append(jnp.asarray(layer.pos_planes, jnp.uint32))
        arrays.append(jnp.asarray(layer.neg_planes, jnp.uint32))
    fan_outs = [l.fan_out for l in plan.layers]

    def plane_matmul(a, pos, neg, fan_out):
        if pos.shape[-2] == 0:       # zero words: fully-pruned fan_in
            return jnp.zeros((a.shape[0], fan_out), jnp.int32)
        return bmv.binary_matmul_planes(a, pos, neg, **bkw_kw)

    def run(x_uint8, *planes):
        a = bmv.binarize_pack(x_uint8, threshold=thr, words=words[0])
        for i in range(len(fan_outs) - 1):
            acc = plane_matmul(
                a, planes[2 * i], planes[2 * i + 1], fan_outs[i])
            a = bmv.step_pack(acc, words=words[i + 1])
        return jnp.argmax(
            plane_matmul(a, planes[-2], planes[-1], fan_outs[-1]), axis=-1)

    return tuple(arrays), run


def _finish_predictor(predict, jitted, *, plan_form: str, datapath: str,
                      blocks: dict, launches: int):
    """Stamp the predictor attributes every caller reads: the executed
    plan form, the datapath name (== form, or "fusednet" for the
    megakernel — surfaces in the `netgen.kernel` span and the launch
    counter), the chosen blocks, launches per call, and the underlying
    jitted fn (lowerable — `telemetry.jit_cost` roofline input)."""
    predict.plan_form = plan_form
    predict.datapath = datapath
    predict.blocks = dict(blocks)
    predict.launches_per_call = launches
    predict.jitted = jitted
    return predict


def _build_single(plan: ExecutionPlan, kw: dict, blocks: dict):
    from repro.netgen import telemetry

    arrays, run = _chain(plan, kw, blocks)
    jitted = jax.jit(lambda x: run(x, *arrays))
    form, depth = plan.form, plan.depth

    def predict(x_uint8):
        telemetry.kernel_launches(form).inc(depth)
        return jitted(x_uint8)

    return _finish_predictor(predict, jitted, plan_form=form, datapath=form,
                             blocks=blocks, launches=depth)


def _build_multi(plan: ExecutionPlan, kw: dict, blocks: dict):
    from repro.netgen import telemetry

    arrays, run = _chain(plan, kw, blocks)
    jitted = jax.jit(lambda block: jax.lax.map(
        lambda s: run(s[0], *s[1:]), (block, *arrays)))
    form = plan.form
    # lax.map sweeps the model axis sequentially: depth launches/model.
    launches = plan.depth * (plan.n_models or 1)

    def predict(x_uint8):                            # (M, B, n_in)
        telemetry.kernel_launches(form).inc(launches)
        return jitted(x_uint8)

    return _finish_predictor(predict, jitted, plan_form=form, datapath=form,
                             blocks=blocks, launches=launches)


def _build_fusednet(plan: ExecutionPlan, kw: dict, blocks: dict):
    """The whole-net megakernel predictor: one persistent
    `binary_forward_planes` launch per call — single (B, n_in) or
    stacked (M, B, n_in) — through `plan.megakernel_view()`. Raises
    ValueError when the plan has no megakernel view (callers that
    merely *prefer* the megakernel fall back to the per-layer chain)."""
    from repro.kernels.binary_matvec import ops as bmv
    from repro.netgen import telemetry

    view = plan.megakernel_view()
    arrays = tuple(jnp.asarray(a, jnp.uint32) for a in view.arrays)
    kkw = dict(kw)
    if blocks.get("bm") is not None:
        kkw["bm"] = int(blocks["bm"])
    if blocks.get("bkw") is not None:
        kkw["bkw"] = int(blocks["bkw"])
    jitted = jax.jit(lambda x: bmv.binary_forward_planes(
        x, *arrays, threshold=view.input_threshold,
        n_classes=view.n_classes, **kkw))

    def predict(x_uint8):
        telemetry.kernel_launches("fusednet").inc()
        return jitted(x_uint8)

    return _finish_predictor(predict, jitted, plan_form="planes",
                             datapath="fusednet", blocks=blocks, launches=1)


# ---------------------------------------------------------------------------
# Autotuning (repro.netgen.tune)
# ---------------------------------------------------------------------------

def _plan_signature(plan: ExecutionPlan) -> dict:
    """The JSON-stable shape identity tuning records are keyed on: layer
    geometry plus each layer's bit-plane count (the plane count sets the
    planes kernel's work, so nets of equal shape but different weight
    ranges tune separately). Computed from magnitudes directly — no
    plane decomposition is materialized for keying."""
    return {
        "n_inputs": plan.n_inputs,
        "widths": [l.fan_out for l in plan.layers],
        "n_models": plan.n_models,
        "n_planes": [
            max(1, int(np.abs(l.weights).max(initial=0)).bit_length())
            for l in plan.layers],
    }


# ---------------------------------------------------------------------------
# Explored datapath records (repro.netgen.explore)
# ---------------------------------------------------------------------------

# The design-space explorer publishes its winning datapath (form +
# blocks) under this pseudo-target, keyed on the plan signature alone —
# NOT on a candidate grid — so any later compile of the same shape can
# resolve it without knowing how the search was configured.
_EXPLORED_TARGET = "pallas-explored"


def explored_key_fields(signature: dict, *, interpret, multi: bool) -> dict:
    """The JSON-stable identity an explored datapath record is keyed on.
    One home for the scheme: the explorer writes through it and
    `pallas[explored=true]` reads through it."""
    return {
        "target": _EXPLORED_TARGET,
        "device_kind": jax.devices()[0].device_kind,
        "interpret": interpret,
        "multi": bool(multi),
        "signature": signature,
    }


def publish_explored(plan: ExecutionPlan, tuner, best: dict, *,
                     interpret=None, measurements=(), extra=None):
    """Upsert the explored winner's datapath record for this plan shape
    (`best`: form + bm/bn/bkw). Called by `repro.netgen.explore` after a
    search; later `explored=true` compiles of the same signature resolve
    it with zero measurements."""
    from repro.netgen import tune

    tuner = tuner if tuner is not None else tune.default_tuner()
    fields = explored_key_fields(
        _plan_signature(plan), interpret=interpret, multi=plan.stacked)
    return tuner.publish(fields, best, measurements=measurements,
                         extra=extra)


def explored_record(plan: ExecutionPlan, tuner, *, interpret, multi: bool):
    """The resident explored-winner record for this plan shape, or None.
    A stacked lookup that misses falls back to the single-net signature
    (model axis erased): the explorer searches one net at a time, and a
    homogeneous stack executes the same per-model geometry the single
    net was measured on."""
    from repro.netgen import tune

    tuner = tuner if tuner is not None else tune.default_tuner()
    sig = _plan_signature(plan)
    rec = tuner.record_for(tune.tune_key(
        explored_key_fields(sig, interpret=interpret, multi=multi)))
    if rec is None and multi:
        rec = tuner.record_for(tune.tune_key(explored_key_fields(
            {**sig, "n_models": None}, interpret=interpret, multi=False)))
    return rec


def _form_compatible(pinned: str | None, recorded: str) -> bool:
    """May an explored record's form satisfy an explicitly pinned one?
    planes and fusednet are the same bit-plane datapath family (the
    megakernel runs the planes form), so they satisfy each other; any
    other disagreement means the record is ignored."""
    if pinned is None or pinned == recorded:
        return True
    return {pinned, recorded} == {"planes", "fusednet"}


def _tuned_params(plan: ExecutionPlan, kw: dict, blocks: dict,
                  forms, tuner, *, multi: bool):
    """Grid-search (form x block sizes) for this plan through the tuner
    (memory -> store -> measure); returns (winning params, the winner's
    already-built predictor or None on a warm record hit — a cold
    search traced the winner once already, don't trace it twice).
    Explicit block options are pinned, not searched."""
    from repro.netgen import tune

    tuner = tuner if tuner is not None else tune.default_tuner()
    pinned = {k: v for k, v in blocks.items() if v is not None}
    candidates = []
    seen = set()
    for form in forms:
        for grid in _TUNE_BLOCKS:
            cand = {"form": form, **grid, **pinned}
            key = tuple(sorted(cand.items()))
            if key not in seen:
                seen.add(key)
                candidates.append(cand)

    batch = _TUNE_BATCH if not multi else max(32, _TUNE_BATCH // 4)
    shape = ((batch, plan.n_inputs) if not multi
             else (plan.n_models, batch, plan.n_inputs))
    x = np.zeros(shape, np.uint8)
    built: dict = {}

    def measure(cand: dict) -> float:
        ckey = tuple(sorted(cand.items()))
        fn = built.get(ckey)
        if fn is None:
            form = cand["form"]
            cblocks = {k: cand[k] for k in ("bm", "bn", "bkw")}
            if form == "fusednet":
                build = _build_fusednet
            else:
                build = _build_multi if multi else _build_single
            fn = build(_in_form(plan, form), kw, cblocks)
            built[ckey] = fn
        import time
        t0 = time.perf_counter()
        np.asarray(fn(x))
        return time.perf_counter() - t0

    key_fields = {
        "target": "pallas",
        "device_kind": jax.devices()[0].device_kind,
        "interpret": kw.get("interpret"),
        "multi": bool(multi),
        "batch": batch,
        "signature": _plan_signature(plan),
        "candidates": candidates,
    }
    # static tile legality: candidates whose blocks are non-positive or
    # clamp to a kernel another candidate already launches are rejected
    # before spending a measurement (repro.netgen.analysis)
    from repro.netgen.analysis import tile_legality
    best = tuner.get_or_tune(
        key_fields, candidates, measure,
        legal=tile_legality(plan, batch=batch, multi=multi))
    return best, built.get(tuple(sorted(best.items())))


def _resolve_datapath(plan: ExecutionPlan, kw: dict, *, packed, planes,
                      fusednet, tuned, bm, bn, bkw, tuner, multi: bool,
                      explored: bool = False):
    """Turn the declared target options into (form, blocks, prebuilt):
    explicit options pin their axis; `tuned=true` searches the rest
    (over every datapath, megakernel included, when no form is forced).
    `prebuilt` is the winning predictor when this process's search just
    built it (None otherwise — the caller builds).

    `explored=true` consults the design-space explorer's persisted
    winner for this plan signature FIRST (see `repro.netgen.explore`):
    a resident record supplies the form and any unpinned block sizes
    with zero measurements; without one (or when it contradicts an
    explicitly pinned form) the option is inert and resolution falls
    through to tuned/default — so the serving layer can request it
    unconditionally."""
    from repro.netgen import telemetry

    form = _resolve_form(packed, planes, fusednet)
    blocks = {"bm": bm, "bn": bn, "bkw": bkw}
    prebuilt = None
    if explored:
        rec = explored_record(plan, tuner, interpret=kw.get("interpret"),
                              multi=multi)
        hit = rec is not None and _form_compatible(form, rec.best.get("form"))
        telemetry.get_registry().counter(
            "netgen_explored_resolved_total",
            outcome="hit" if hit else "miss").inc()
        if hit:
            best = rec.best
            if form is None:
                form = best["form"]
            return form, {k: blocks[k] if blocks[k] is not None
                          else best.get(k) for k in blocks}, None
    if tuned:
        forms = (form,) if form is not None else _DATAPATHS
        best, prebuilt = _tuned_params(
            plan, kw, blocks, forms, tuner, multi=multi)
        form = best["form"]
        blocks = {k: best[k] for k in ("bm", "bn", "bkw")}
    elif form is None:
        form = "dense"
    return form, blocks, prebuilt


# ---------------------------------------------------------------------------
# Target entry points
# ---------------------------------------------------------------------------

def compile_pallas(circuit: Circuit, *, interpret: bool | None = None,
                   packed: bool = False, planes: bool = False,
                   fusednet: bool = False, tuned: bool = False,
                   explored: bool = False,
                   bm: int | None = None, bn: int | None = None,
                   bkw: int | None = None, _tuner=None):
    """Return a jitted fn chaining one kernel launch per plan layer —
    or, with `fusednet=true`, ONE whole-net megakernel launch.

    `interpret` overrides the kernel ops' container default (interpret
    mode on CPU); pass `pallas[interpret=false]` on a real TPU to lower
    through Mosaic. `packed` selects the end-to-end bit-packed
    activation datapath, `planes` the fully bit-packed (bit-plane
    weight) datapath, `fusednet` the single-launch planes-form
    megakernel — all bit-exact with dense. `bm`/`bn`/`bkw` pin kernel
    block sizes; `tuned` grid-searches unpinned block sizes (and the
    datapath, when none is forced) through the persistent autotuner.
    The returned fn carries `.plan_form`, `.datapath` and `.blocks`
    describing what the search (or the flags) chose. `explored=true`
    resolves the design-space explorer's persisted winner for this plan
    shape when one exists (see `repro.netgen.explore`); without a
    record it is inert.
    """
    kw = {} if interpret is None else {"interpret": interpret}
    plan = lower_circuit(circuit)
    form, blocks, prebuilt = _resolve_datapath(
        plan, kw, packed=packed, planes=planes, fusednet=fusednet,
        tuned=tuned, bm=bm, bn=bn, bkw=bkw, tuner=_tuner, multi=False,
        explored=explored)
    if prebuilt is not None:
        return prebuilt
    if form == "fusednet":
        return _build_fusednet(plan.planes(), kw, blocks)
    return _build_single(_in_form(plan, form), kw, blocks)


def compile_pallas_multi(plan: ExecutionPlan, *,
                         interpret: bool | None = None,
                         packed: bool = False, planes: bool = False,
                         fusednet: bool = False, tuned: bool = False,
                         explored: bool = False,
                         bm: int | None = None, bn: int | None = None,
                         bkw: int | None = None, _tuner=None):
    """Multi-net dispatch through the binary_matvec kernels.

    `plan` is a *stacked* ExecutionPlan (`repro.netgen.plan.stack_plans`,
    hidden widths pre-padded): per-layer (M, fan_in, fan_out) weights.

    The bit-plane datapath prefers the whole-net megakernel: both
    `fusednet=true` and `planes=true` build ONE persistent
    `binary_forward_planes` launch over grid (M, B/bm) — model axis
    outermost, so each version's resident weights serve a full batch
    sweep before the next version's are brought in. `planes=true`
    falls back to the per-layer chain when the megakernel build fails
    (`fusednet=true` is strict). Everything else sweeps the model axis
    with `lax.map` — a scan whose body is the per-layer kernel chain
    (depth x M launches per dispatch vs the megakernel's 1). All
    declared options behave as in `compile_pallas`; tuning records for
    stacked plans are keyed on the stacked shape (model count
    included), separate from the single-net records.
    """
    if not plan.stacked:
        raise ValueError("compile_pallas_multi needs a stacked ExecutionPlan")
    kw = {} if interpret is None else {"interpret": interpret}
    form, blocks, prebuilt = _resolve_datapath(
        plan, kw, packed=packed, planes=planes, fusednet=fusednet,
        tuned=tuned, bm=bm, bn=bn, bkw=bkw, tuner=_tuner, multi=True,
        explored=explored)
    if prebuilt is not None:
        return prebuilt
    if form == "fusednet":
        return _build_fusednet(plan.planes(), kw, blocks)
    if form == "planes":
        try:
            return _build_fusednet(plan.planes(), kw, blocks)
        except ValueError:
            pass                    # no megakernel view: per-layer chain
    return _build_multi(_in_form(plan, form), kw, blocks)


_FUSED_TUNE_BM = (64, 128, 256)


def compile_fused(circuit: Circuit, *, interpret: bool | None = None,
                  tuned: bool = False, bm: int | None = None, _tuner=None):
    """Whole-net single Pallas launch; 2-layer plans only. `bm` pins the
    batch tile; `fused[tuned=true]` searches it per plan shape through
    the persistent autotuner."""
    from repro.kernels.fused_mlp import ops as fused

    kw = {} if interpret is None else {"interpret": interpret}
    plan = lower_circuit(circuit)
    if plan.depth != 2:
        raise IrregularCircuitError(
            f"fused backend supports exactly 2 layers, got {plan.depth}")
    w1 = jnp.asarray(plan.layers[0].weights, jnp.int32)
    w2 = jnp.asarray(plan.layers[1].weights, jnp.int32)
    thr = plan.input_threshold

    if tuned and bm is None:
        from repro.netgen import tune

        tuner = _tuner if _tuner is not None else tune.default_tuner()
        x = np.zeros((_TUNE_BATCH, plan.n_inputs), np.uint8)
        candidates = [{"bm": b} for b in _FUSED_TUNE_BM]

        def measure(cand):
            import time
            t0 = time.perf_counter()
            np.asarray(fused.fused_mlp_predict(
                x, w1, w2, threshold=thr, bm=cand["bm"], **kw))
            return time.perf_counter() - t0

        best = tuner.get_or_tune({
            "target": "fused",
            "device_kind": jax.devices()[0].device_kind,
            "interpret": kw.get("interpret"),
            "batch": _TUNE_BATCH,
            "signature": _plan_signature(plan),
            "candidates": candidates,
        }, candidates, measure)
        bm = best["bm"]

    bm_kw = {} if bm is None else {"bm": int(bm)}

    @jax.jit
    def _jitted(x_uint8):
        return fused.fused_mlp_predict(
            x_uint8, w1, w2, threshold=thr, **bm_kw, **kw)

    from repro.netgen import telemetry

    def predict(x_uint8):
        telemetry.kernel_launches("fused").inc()
        return _jitted(x_uint8)

    return _finish_predictor(predict, _jitted, plan_form="dense",
                             datapath="fused", blocks=bm_kw, launches=1)
