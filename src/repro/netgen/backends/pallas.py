"""Pallas backend: execute an ExecutionPlan on the TPU kernels.

Per-layer path (any depth) chains the `binary_matvec` masked-accumulate
kernel — the VPU select/add realization of the paper's L5 rewrite — with
a sign-bit step between layers. Two datapaths, selected by the plan
form (`pallas[packed=true]`):

  dense   — activations travel as int8 {0,1} vectors into
            `binary_matmul` (one byte per wire).
  packed  — activations are bit-packed 32-per-uint32 word between
            layers and fed to `binary_matmul_packed` (one *bit* per
            wire — the TPU analogue of the paper's single-bit nets,
            8x less activation traffic and fewer K-grid steps).

The `fused` variant lowers the whole 2-layer paper net into the
single-launch `fused_mlp` kernel, the combinational-circuit analogue
(one "net" per prediction, intermediate activations never leaving VMEM).

Kernels run in interpret mode on CPU containers (see kernels/*/ops.py);
on a real TPU the same code path compiles to Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netgen.graph import Circuit, IrregularCircuitError
from repro.netgen.plan import ExecutionPlan, lower_circuit

__all__ = ["compile_pallas", "compile_pallas_multi", "compile_fused"]


def _layer_matmul(bmv, kw, packed: bool):
    """One plan layer as a kernel launch: int8 activation bits (B, K) x
    int32 weights -> int32 accumulators (B, N). The packed datapath
    packs the bits into uint32 words first (`pack_bits` pads K to the
    same 32-multiple the packed plan padded the weights to)."""
    def matmul(a, w):
        if w.shape[0] == 0:  # fully-pruned predecessor layer: constant 0
            return jnp.zeros((a.shape[0], w.shape[1]), jnp.int32)
        if packed:
            return bmv.binary_matmul_packed(bmv.pack_bits(a), w, **kw)
        return bmv.binary_matmul(a, w, **kw)
    return matmul


def compile_pallas(circuit: Circuit, *, interpret: bool | None = None,
                   packed: bool = False):
    """Return a jitted fn chaining one kernel launch per plan layer.

    `interpret` overrides the kernel ops' container default (interpret
    mode on CPU); pass `pallas[interpret=false]` on a real TPU to lower
    through Mosaic. `packed` selects the bit-packed activation datapath
    (`pallas[packed=true]`), bit-exact with the dense path.
    """
    from repro.kernels.binary_matvec import ops as bmv

    kw = {} if interpret is None else {"interpret": interpret}
    plan = lower_circuit(circuit, packed=packed)
    ws = [jnp.asarray(l.weights, jnp.int32) for l in plan.layers]
    thr = plan.input_threshold
    matmul = _layer_matmul(bmv, kw, plan.packed)

    @jax.jit
    def predict(x_uint8):
        a = (x_uint8.astype(jnp.int32) > thr).astype(jnp.int8)
        for w in ws[:-1]:
            a = (matmul(a, w) > 0).astype(jnp.int8)
        return jnp.argmax(matmul(a, ws[-1]), axis=-1)

    return predict


def compile_pallas_multi(plan: ExecutionPlan, *,
                         interpret: bool | None = None,
                         packed: bool = False):
    """Multi-net dispatch through the binary_matvec kernel chain.

    `plan` is a *stacked* ExecutionPlan (`repro.netgen.plan.stack_plans`,
    hidden widths pre-padded): per-layer (M, fan_in, fan_out) weights.
    The model axis is swept with `lax.map` — a scan whose body is the
    per-layer kernel chain, so the whole M-version batch is one jitted
    dispatch and each version's weights stream through the same kernel
    traces. `interpret` and `packed` as in `compile_pallas` (the
    single-version path and the stacked path honor the same declared
    target options).
    """
    from repro.kernels.binary_matvec import ops as bmv

    if not plan.stacked:
        raise ValueError("compile_pallas_multi needs a stacked ExecutionPlan")
    kw = {} if interpret is None else {"interpret": interpret}
    if packed:
        plan = plan.pack()
    ws = [jnp.asarray(l.weights, jnp.int32) for l in plan.layers]
    thr = plan.input_threshold
    matmul = _layer_matmul(bmv, kw, plan.packed)

    def one_version(slices):
        x, *wm = slices
        a = (x.astype(jnp.int32) > thr).astype(jnp.int8)
        for w in wm[:-1]:
            a = (matmul(a, w) > 0).astype(jnp.int8)
        return jnp.argmax(matmul(a, wm[-1]), axis=-1)

    @jax.jit
    def predict(x_uint8):                            # (M, B, n_in)
        return jax.lax.map(one_version, (x_uint8, *ws))

    return predict


def compile_fused(circuit: Circuit, *, interpret: bool | None = None):
    """Whole-net single Pallas launch; 2-layer plans only."""
    from repro.kernels.fused_mlp import ops as fused

    kw = {} if interpret is None else {"interpret": interpret}
    plan = lower_circuit(circuit)
    if plan.depth != 2:
        raise IrregularCircuitError(
            f"fused backend supports exactly 2 layers, got {plan.depth}")
    w1 = jnp.asarray(plan.layers[0].weights, jnp.int32)
    w2 = jnp.asarray(plan.layers[1].weights, jnp.int32)
    thr = plan.input_threshold

    @jax.jit
    def predict(x_uint8):
        return fused.fused_mlp_predict(x_uint8, w1, w2, threshold=thr, **kw)

    return predict
