"""jnp backend: compile a regular circuit into a jitted adds-only predictor.

The TPU analogue of the paper's weights-as-wiring: the integer weight
matrices reconstructed from the (pruned) circuit are embedded as XLA
literals, and every layer is the masked column-sum identity

    x @ W  ==  sum of W rows where x == 1      (x in {0,1})

realized as `where` + `sum` — adds only, no multiplies, no MXU. Works
for any depth. This is the oracle backend the pallas kernels are
checked against.

Registered as the `jnp` target (kind "callable", no options) with
`compile_jnp_multi` as its multi-net form; see `repro.netgen.targets`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netgen.graph import Circuit, as_layered_weights

__all__ = ["compile_jnp", "compile_jnp_multi"]


def compile_jnp(circuit: Circuit):
    """Return a jitted fn: uint8 images (B, n_in) -> int predictions (B,)."""
    ws = [jnp.asarray(w, jnp.int32) for w in as_layered_weights(circuit)]
    thr = circuit.input_threshold

    @jax.jit
    def predict(x_uint8):
        a = x_uint8.astype(jnp.int32) > thr
        for w in ws[:-1]:
            hi = jnp.sum(jnp.where(a[:, :, None], w[None], 0), axis=1)
            a = hi > 0
        fi = jnp.sum(jnp.where(a[:, :, None], ws[-1][None], 0), axis=1)
        return jnp.argmax(fi, axis=-1)

    return predict


def compile_jnp_multi(stacked_ws, input_threshold: int):
    """Multi-net dispatch: one jitted call serving M model versions.

    `stacked_ws` is a list of (M, fan_in, fan_out) int arrays — the
    per-version weight matrices reconstructed from their circuits, padded
    to common hidden widths and stacked along a leading model axis (see
    `repro.netgen.serve.stack_layered_weights`). Returns a jitted fn
    mapping uint8 images (M, B, n_in) to predictions (M, B): the same
    masked column-sum arithmetic as `compile_jnp`, batched over the model
    axis, so serving M versions costs one XLA dispatch instead of M.
    """
    ws = [jnp.asarray(w, jnp.int32) for w in stacked_ws]
    thr = int(input_threshold)

    @jax.jit
    def predict(x_uint8):
        a = x_uint8.astype(jnp.int32) > thr          # (M, B, K)
        for w in ws[:-1]:
            hi = jnp.sum(jnp.where(a[..., None], w[:, None], 0), axis=2)
            a = hi > 0
        fi = jnp.sum(jnp.where(a[..., None], ws[-1][:, None], 0), axis=2)
        return jnp.argmax(fi, axis=-1)               # (M, B)

    return predict
