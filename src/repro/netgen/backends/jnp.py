"""jnp backend: execute an ExecutionPlan as a jitted adds-only predictor.

The TPU analogue of the paper's weights-as-wiring: the integer weight
matrices of the plan lowered from the (pruned) circuit are embedded as
XLA literals, and every layer is the masked column-sum identity

    x @ W  ==  sum of W rows where x == 1      (x in {0,1})

realized as `where` + `sum` — adds only, no multiplies, no MXU. Works
for any depth. This is the oracle backend the pallas kernels are
checked against; it always executes the dense plan form.

Registered as the `jnp` target (kind "callable", no options) with
`compile_jnp_multi` as its multi-net form; see `repro.netgen.targets`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netgen.graph import Circuit
from repro.netgen.plan import ExecutionPlan, lower_circuit

__all__ = ["compile_jnp", "compile_jnp_multi"]


def compile_jnp(circuit: Circuit):
    """Return a jitted fn: uint8 images (B, n_in) -> int predictions (B,)."""
    return _execute_plan(lower_circuit(circuit))


def _execute_plan(plan: ExecutionPlan):
    """The dense-plan executor: one masked column-sum per layer."""
    ws = [jnp.asarray(l.weights, jnp.int32) for l in plan.layers]
    thr = plan.input_threshold

    @jax.jit
    def predict(x_uint8):
        a = x_uint8.astype(jnp.int32) > thr
        for w in ws[:-1]:
            hi = jnp.sum(jnp.where(a[:, :, None], w[None], 0), axis=1)
            a = hi > 0
        fi = jnp.sum(jnp.where(a[:, :, None], ws[-1][None], 0), axis=1)
        return jnp.argmax(fi, axis=-1)

    return predict


def compile_jnp_multi(plan: ExecutionPlan):
    """Multi-net dispatch: one jitted call serving M model versions.

    `plan` is a *stacked* ExecutionPlan (`repro.netgen.plan.stack_plans`):
    per-layer (M, fan_in, fan_out) weights along a leading model axis.
    Returns a jitted fn mapping uint8 images (M, B, n_in) to predictions
    (M, B): the same masked column-sum arithmetic as `compile_jnp`,
    batched over the model axis, so serving M versions costs one XLA
    dispatch instead of M.
    """
    if not plan.stacked:
        raise ValueError("compile_jnp_multi needs a stacked ExecutionPlan")
    ws = [jnp.asarray(l.weights, jnp.int32) for l in plan.layers]
    thr = plan.input_threshold

    @jax.jit
    def predict(x_uint8):
        a = x_uint8.astype(jnp.int32) > thr          # (M, B, K)
        for w in ws[:-1]:
            hi = jnp.sum(jnp.where(a[..., None], w[:, None], 0), axis=2)
            a = hi > 0
        fi = jnp.sum(jnp.where(a[..., None], ws[-1][:, None], 0), axis=2)
        return jnp.argmax(fi, axis=-1)               # (M, B)

    return predict
