"""jnp backend: compile a regular circuit into a jitted adds-only predictor.

The TPU analogue of the paper's weights-as-wiring: the integer weight
matrices reconstructed from the (pruned) circuit are embedded as XLA
literals, and every layer is the masked column-sum identity

    x @ W  ==  sum of W rows where x == 1      (x in {0,1})

realized as `where` + `sum` — adds only, no multiplies, no MXU. Works
for any depth. This is the oracle backend the pallas kernels are
checked against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.netgen.graph import Circuit, as_layered_weights

__all__ = ["compile_jnp"]


def compile_jnp(circuit: Circuit):
    """Return a jitted fn: uint8 images (B, n_in) -> int predictions (B,)."""
    ws = [jnp.asarray(w, jnp.int32) for w in as_layered_weights(circuit)]
    thr = circuit.input_threshold

    @jax.jit
    def predict(x_uint8):
        a = x_uint8.astype(jnp.int32) > thr
        for w in ws[:-1]:
            hi = jnp.sum(jnp.where(a[:, :, None], w[None], 0), axis=1)
            a = hi > 0
        fi = jnp.sum(jnp.where(a[:, :, None], ws[-1][None], 0), axis=1)
        return jnp.argmax(fi, axis=-1)

    return predict
