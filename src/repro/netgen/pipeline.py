"""Declarative pass-pipeline specs: parseable, nameable, fingerprintable.

`PipelineSpec` is the public way to say *which* optimization passes a
compilation runs. A spec is a comma list of registry entries, each with
optional bracketed options:

    PipelineSpec.parse("zeros,prune")
    PipelineSpec.parse("prune,addends,cse[budget=5000,bucketed=true]")

Registry names map onto `repro.netgen.passes`:

    zeros    -> delete_zero_terms      (paper L4, per-term)
    prune    -> prune_dead_units       (paper L4, per-unit)
    addends  -> addend_rewrite         (paper L5, multiplication-free)
    cse      -> share_common_addends   (adder sharing; opts: budget=<int>
                maps to max_new_nodes, bucketed=<bool> selects the
                (sign, magnitude)-bucketed candidate search)

Named pipelines ("default", "hw") resolve to full specs, and a spec
round-trips through its canonical string: passes sorted options, bare
boolean flags normalized to `opt=true`, aliases resolved. The canonical
string is what `fingerprint()` hashes (sha256, stable across processes
and machines), which is what makes a spec usable as one axis of the
`ArtifactStore` content address — the successor of the per-function
fingerprint logic that used to live in `repro.netgen.serve` and had to
refuse lambdas outright. Parameterized rewrites are now *representable*
(`cse[budget=5]`) instead of smuggled through closures.

Dotted module paths are accepted for out-of-tree passes
(`"mypkg.passes.retime"` imports and calls `mypkg.passes.retime`), so a
project-local rewrite still gets a stable, re-parseable fingerprint.
Lambdas and closures remain unrepresentable and raise.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import importlib
import re
from typing import Callable, Mapping, Sequence

from repro.netgen import passes as _passes
from repro.netgen import telemetry
from repro.netgen.graph import Circuit
from repro.netgen.passes import PassStats, ops

__all__ = [
    "PassDef", "PassSpec", "PipelineSpec", "list_passes", "list_pipelines",
    "parse_item", "register_pass", "register_pipeline", "render_opts",
]

_FINGERPRINT_TAG = "netgen-pipeline-v1"
_NAME_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*(\.[A-Za-z_][A-Za-z0-9_]*)*$")


# ---------------------------------------------------------------------------
# Bracket-option syntax, shared with the Target registry
# ---------------------------------------------------------------------------

def _parse_value(raw: str):
    """Literal for one bracket-option value: bool, int, or bare string."""
    low = raw.lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(raw, 10)
    except ValueError:
        return raw


def render_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


_SAFE_STR_RE = re.compile(r"^[A-Za-z0-9_./\-]+$")


def check_opt_string(value: str, where: str) -> str:
    """String option values are embedded verbatim in canonical spec /
    target strings (which must round-trip through `parse_item` and key
    the ArtifactStore), so they may not contain the syntax characters
    `, [ ] =` or whitespace, and may not collide with bool/int
    literals."""
    if not _SAFE_STR_RE.match(value):
        raise ValueError(
            f"{where}: string option value {value!r} must match "
            "[A-Za-z0-9_./-]+ — it is embedded in the canonical spec "
            "string that keys the artifact store")
    if not isinstance(_parse_value(value), str):
        raise ValueError(
            f"{where}: string option value {value!r} would re-parse as "
            f"{_parse_value(value)!r}; pick a non-literal name")
    return value


def render_opts(opts: Mapping) -> str:
    """Canonical `[k=v,...]` suffix (sorted keys; empty -> no brackets)."""
    if not opts:
        return ""
    inner = ",".join(f"{k}={render_value(v)}" for k, v in sorted(opts.items()))
    return f"[{inner}]"


def parse_item(item: str) -> tuple[str, dict]:
    """Parse one `name` / `name[k=v,flag,...]` item into (name, opts).

    A bare option inside brackets is a boolean flag (`pallas[interpret]`
    == `pallas[interpret=true]`). Raises ValueError on malformed input.
    """
    item = item.strip()
    if "[" in item:
        name, _, rest = item.partition("[")
        if not rest.endswith("]"):
            raise ValueError(
                f"malformed options in {item!r}: missing closing ']'")
        body = rest[:-1]
        if "]" in body or "[" in body:
            raise ValueError(f"malformed options in {item!r}: nested brackets")
        opts: dict = {}
        for part in body.split(","):
            part = part.strip()
            if not part:
                raise ValueError(f"malformed options in {item!r}: empty option")
            k, eq, v = part.partition("=")
            k = k.strip()
            if not k:
                raise ValueError(
                    f"malformed options in {item!r}: option with no name")
            if k in opts:
                raise ValueError(f"duplicate option {k!r} in {item!r}")
            opts[k] = _parse_value(v.strip()) if eq else True
    else:
        name, opts = item, {}
    name = name.strip()
    if not name or not _NAME_RE.match(name):
        raise ValueError(f"malformed pass/target name {name!r} in {item!r}")
    return name, opts


# ---------------------------------------------------------------------------
# Pass registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PassDef:
    """One registered pass: its callable, its declared options (spec opt
    name -> (python type, callable keyword)), and a one-liner."""
    name: str
    fn: Callable
    opts: tuple = ()            # ((opt_name, type, fn_keyword), ...)
    doc: str = ""

    def keyword_for(self, opt: str) -> str:
        for o, _, kw in self.opts:
            if o == opt:
                return kw
        raise KeyError(opt)

    def opt_for_keyword(self, kw: str) -> str | None:
        for o, _, k in self.opts:
            if k == kw:
                return o
        return None


_PASS_REGISTRY: dict[str, PassDef] = {}
_FN_TO_DEF: dict[Callable, PassDef] = {}
_PIPELINES: dict[str, str] = {}


def register_pass(passdef: PassDef) -> PassDef:
    _PASS_REGISTRY[passdef.name] = passdef
    _FN_TO_DEF[passdef.fn] = passdef
    return passdef


def register_pipeline(name: str, spec: str) -> None:
    """Name a full spec string (resolvable via `PipelineSpec.coerce`)."""
    PipelineSpec.parse(spec)  # validate eagerly
    _PIPELINES[name] = spec


def list_passes() -> tuple[PassDef, ...]:
    return tuple(_PASS_REGISTRY[k] for k in sorted(_PASS_REGISTRY))


def list_pipelines() -> dict[str, str]:
    return dict(_PIPELINES)


register_pass(PassDef(
    name="zeros", fn=_passes.delete_zero_terms,
    doc="drop 0*x addends (paper L4, per-term)"))
register_pass(PassDef(
    name="prune", fn=_passes.prune_dead_units,
    doc="remove structurally dead hidden units (paper L4, per-unit)"))
register_pass(PassDef(
    name="addends", fn=_passes.addend_rewrite,
    doc="expand w*x into |w| unit addends (paper L5, mult-free)"))
register_pass(PassDef(
    name="cse", fn=_passes.share_common_addends,
    opts=(("budget", int, "max_new_nodes"), ("bucketed", bool, "bucketed")),
    doc="share repeated addend pairs (adder CSE; irregular DAG)"))

def _resolve_dotted(name: str) -> Callable:
    mod, _, attr = name.rpartition(".")
    try:
        fn = getattr(importlib.import_module(mod), attr)
    except (ImportError, AttributeError) as e:
        raise ValueError(
            f"unknown pass {name!r}: not in the registry "
            f"({', '.join(sorted(_PASS_REGISTRY))}) and not importable "
            f"({e})") from None
    if not callable(fn):
        raise ValueError(f"pass {name!r} resolves to non-callable {fn!r}")
    return fn


# ---------------------------------------------------------------------------
# PipelineSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PassSpec:
    """One pipeline step in canonical form: registry (or dotted) name plus
    a sorted tuple of (opt, value) pairs."""
    name: str
    opts: tuple = ()

    def item_string(self) -> str:
        return f"{self.name}{render_opts(dict(self.opts))}"


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """A declarative, fingerprintable pass pipeline. See module doc."""
    steps: tuple[PassSpec, ...]

    # -- construction --------------------------------------------------------

    @classmethod
    def parse(cls, spec: str) -> "PipelineSpec":
        """Parse a comma list of `name[opts]` items. Unknown passes,
        malformed bracket options, unknown options, ill-typed option
        values, and duplicate steps all raise ValueError."""
        if not isinstance(spec, str):
            raise TypeError(f"PipelineSpec.parse takes a string, got {spec!r}")
        steps: list[PassSpec] = []
        seen: set[str] = set()
        # comma-split at bracket depth 0 only (opts may contain commas)
        depth = 0
        merged: list[str] = []
        for part in spec.split(","):
            if depth > 0:
                merged[-1] += "," + part
            else:
                merged.append(part)
            depth += part.count("[") - part.count("]")
        if depth != 0:
            raise ValueError(f"malformed spec {spec!r}: unbalanced brackets")
        items = [m.strip() for m in merged]
        if not items or any(not m for m in items):
            raise ValueError(
                f"empty item in pipeline spec {spec!r} (a spec is a comma "
                "list of pass names, e.g. 'zeros,prune')")
        for item in items:
            name, raw_opts = parse_item(item)
            name = _canonical_pass_name(name)
            opts = _validate_pass_opts(name, raw_opts)
            if name in seen:
                raise ValueError(
                    f"duplicate pass {name!r} in spec {spec!r} (each pass "
                    "may appear once; rewrites are applied in order)")
            seen.add(name)
            steps.append(PassSpec(name=name, opts=opts))
        return cls(steps=tuple(steps))

    @classmethod
    def named(cls, name: str) -> "PipelineSpec":
        """Resolve a registered pipeline name ("default", "hw")."""
        if name not in _PIPELINES:
            raise ValueError(
                f"unknown pipeline {name!r} (registered: "
                f"{', '.join(sorted(_PIPELINES))})")
        return cls.parse(_PIPELINES[name])

    @classmethod
    def from_passes(cls, passes: Sequence[Callable]) -> "PipelineSpec":
        """Represent a sequence of pass callables (registered functions,
        `functools.partial` of them, or callables produced by `build()`)
        as a spec. Lambdas/closures and unknown partial keywords raise —
        they have no stable canonical form."""
        steps = []
        for p in passes:
            steps.append(_spec_for_callable(p))
        spec = cls(steps=tuple(steps))
        names = [s.name for s in steps]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate passes in pipeline: {names}")
        return spec

    @classmethod
    def coerce(cls, value) -> "PipelineSpec":
        """The one entry point every API uses: None -> the "default"
        pipeline; a PipelineSpec -> itself; a string -> named pipeline or
        parsed spec; a sequence of callables -> `from_passes`."""
        if value is None:
            return cls.named("default")
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            if value in _PIPELINES:
                return cls.named(value)
            return cls.parse(value)
        if callable(value):
            return cls.from_passes([value])
        return cls.from_passes(list(value))

    # -- canonical form ------------------------------------------------------

    def spec_string(self) -> str:
        """The canonical string; `parse(spec_string())` is the identity."""
        return ",".join(s.item_string() for s in self.steps)

    def fingerprint(self) -> str:
        """sha256 of the canonical spec string (version-tagged). Stable
        across processes/machines: one axis of the ArtifactStore key."""
        h = hashlib.sha256()
        h.update(f"{_FINGERPRINT_TAG}:{self.spec_string()}".encode())
        return h.hexdigest()

    def __str__(self) -> str:
        return self.spec_string()

    # -- execution -----------------------------------------------------------

    def build(self) -> tuple[Callable, ...]:
        """Materialize the pipeline as `Circuit -> Circuit` callables.
        Each carries its canonical item string as `__name__` (so
        `PassStats.name` reads e.g. `cse[budget=8,bucketed=true]`) and a
        `_pass_spec` attribute for exact round-tripping."""
        fns = []
        for step in self.steps:
            fns.append(_build_step(step))
        return tuple(fns)

    def run(self, circuit: Circuit, *, observe=None,
            verify: bool | None = None
            ) -> tuple[Circuit, tuple[PassStats, ...]]:
        """Apply the pipeline, recording per-pass stats. `observe`, if
        given, is called as observe(stage_name, circuit) for the lowered
        circuit and after every pass (the cost target's pass trace).
        With tracing enabled each pass runs under a `netgen.pass` span
        (nested in `netgen.pipeline`) carrying its before/after node
        and term counts.

        `verify=True` checks the full `repro.netgen.analysis` invariant
        suite at every pass boundary — structural well-formedness, the
        pass's own postconditions, accumulator range proofs, and that
        no pass *widened* a class score's value interval (an exact
        rewrite may only tighten it). A violation raises
        `analysis.VerificationError` naming the pass and the node, and
        counts `netgen_verify_failures_total`. `verify=None` (default)
        takes the `NETGEN_VERIFY` env var: on in tests/CI, off in prod
        where per-pass sweeps would tax the compile path (the Session
        driver still runs one pre-backend analysis regardless)."""
        from repro.netgen import analysis

        tel = telemetry.get_registry()
        check = analysis.strict_verify() if verify is None else bool(verify)
        with tel.span("netgen.pipeline", pipeline=self.spec_string(),
                      steps=len(self.steps)):
            if observe is not None:
                observe("lowered", circuit)
            envelope = None
            if check:
                verify_circuit = analysis.verify_circuit
                verify_circuit(circuit, stage="lowered")
                envelope = analysis.analyze_ranges(
                    circuit).output_envelope(circuit)
            stats = []
            for step, fn in zip(self.steps, self.build()):
                before = ops(circuit)
                with tel.span("netgen.pass", name=step.item_string()) as sp:
                    circuit = fn(circuit)
                    after = ops(circuit)
                    sp.set_attr("terms_before", before.terms)
                    sp.set_attr("terms_after", after.terms)
                    sp.set_attr("nodes_deleted", before.nodes - after.nodes)
                stats.append(PassStats(
                    name=step.item_string(), before=before, after=after))
                if check:
                    stage = step.item_string()
                    ranges, diags = analysis.analyze(
                        circuit, after_pass=step.name, stage=stage,
                        collect=True)
                    if not diags:
                        nxt = ranges.output_envelope(circuit)
                        diags = analysis.check_envelope(
                            envelope, nxt, stage=stage, collect=True)
                        envelope = nxt
                    if diags:
                        tel.counter("netgen_verify_failures_total",
                                    phase="pipeline").inc(len(diags))
                        raise analysis.VerificationError(diags)
                if observe is not None:
                    observe(step.item_string(), circuit)
        return circuit, tuple(stats)


def _canonical_pass_name(name: str) -> str:
    if name in _PASS_REGISTRY:
        return name
    # full function names alias their registry entry
    for pd in _PASS_REGISTRY.values():
        if name == pd.fn.__name__:
            return pd.name
    if "." in name:
        _resolve_dotted(name)   # validates importability
        return name
    raise ValueError(
        f"unknown pass {name!r} (registered: "
        f"{', '.join(sorted(_PASS_REGISTRY))}; dotted module paths are "
        "also accepted)")


def _validate_pass_opts(name: str, raw_opts: dict) -> tuple:
    pd = _PASS_REGISTRY.get(name)
    if pd is None:             # dotted out-of-tree pass: opts pass through
        for k, v in raw_opts.items():
            if isinstance(v, str):
                check_opt_string(v, f"option {k!r} of pass {name!r}")
        return tuple(sorted(raw_opts.items()))
    declared = {o: t for o, t, _ in pd.opts}
    out = {}
    for k, v in raw_opts.items():
        if k not in declared:
            raise ValueError(
                f"unknown option {k!r} for pass {name!r} "
                f"(declared: {', '.join(sorted(declared)) or 'none'})")
        want = declared[k]
        if want is bool:
            if not isinstance(v, bool):
                raise ValueError(
                    f"option {k!r} of pass {name!r} wants true/false, "
                    f"got {v!r}")
        elif want is int:
            if isinstance(v, bool) or not isinstance(v, int):
                raise ValueError(
                    f"option {k!r} of pass {name!r} wants an integer, "
                    f"got {v!r}")
        out[k] = v
    return tuple(sorted(out.items()))


def _build_step(step: PassSpec) -> Callable:
    pd = _PASS_REGISTRY.get(step.name)
    if pd is not None:
        fn = pd.fn
        kwargs = {pd.keyword_for(k): v for k, v in step.opts}
    else:
        fn = _resolve_dotted(step.name)
        kwargs = dict(step.opts)

    def run(circuit: Circuit) -> Circuit:
        return fn(circuit, **kwargs)

    label = step.item_string()
    run.__name__ = label
    run.__qualname__ = label
    run._pass_spec = step
    return run


def _spec_for_callable(p: Callable) -> PassSpec:
    spec = getattr(p, "_pass_spec", None)
    if spec is not None:
        return spec
    if isinstance(p, functools.partial):
        inner = _spec_for_callable(p.func)
        if p.args:
            raise ValueError(
                f"cannot represent positional partial args of {p!r} in a "
                "pipeline spec; bind options by keyword")
        pd = _PASS_REGISTRY.get(inner.name)
        opts = dict(inner.opts)
        for kw, v in p.keywords.items():
            opt = pd.opt_for_keyword(kw) if pd is not None else kw
            if opt is None:
                raise ValueError(
                    f"keyword {kw!r} of {p!r} has no declared option on "
                    f"pass {inner.name!r} — it cannot be fingerprinted")
            opts[opt] = v
        return PassSpec(name=inner.name,
                        opts=_validate_pass_opts(inner.name, opts))
    pd = _FN_TO_DEF.get(p)
    if pd is not None:
        return PassSpec(name=pd.name)
    name = getattr(p, "__qualname__", None) or getattr(p, "__name__", None)
    if not name or "<lambda>" in name or "<locals>" in name:
        raise ValueError(
            f"cannot represent pass {name or p!r} in a pipeline spec: "
            "lambdas and closures have no stable fingerprint — spell it as "
            "a registry entry (e.g. 'cse[budget=5]'), a module-level "
            "function, or functools.partial of one")
    mod = getattr(p, "__module__", None)
    if not mod:
        raise ValueError(f"cannot represent pass {name!r}: no module")
    dotted = f"{mod}.{name}"
    _resolve_dotted(dotted)    # must be re-importable to round-trip
    return PassSpec(name=dotted)


# Built-in named pipelines (registered last: registration parses eagerly).
register_pipeline("default", "zeros,prune")
register_pipeline("hw", "zeros,prune,addends,cse")
