"""Static analysis & verification for the netgen compiler.

The paper's generated hardware is only correct because every
accumulator is sized to the *exact* value range of the trained weights
(§IV-§V: scaled inputs, selected addends, the MSB sign step). Before
this module that guarantee rested on scattered ad-hoc checks —
`Circuit.validate()`, `evaluate(check_widths=True)`, per-backend shape
asserts — none of which ran by default. `repro.netgen.analysis` is the
machine-checked invariant layer that replaces them:

  Structural verifier — `verify_circuit`: DAG well-formedness (dense
      unique ids, topological order, src-reference validity), output
      wiring, kind-specific arity/field invariants (pixel ranges, step
      sources, argmax fan-in), and per-pass postconditions ("no
      zero-weight terms after `zeros`", "no |w| != 1 terms after
      `addends`", "no dead hidden units after `prune`"). The promotion
      of `Circuit.validate()` into a diagnostic engine: violations are
      `Diagnostic` records naming the check, the node, and the pipeline
      stage, raised together as one `VerificationError`.

  Range dataflow — `analyze_ranges`: one topological sweep computing,
      per node, the exact value interval [lo, hi] *and* the paper's
      symmetric magnitude bound sum(|w| * bound(src)) that sizes
      hardware registers. The interval is strictly tighter (an
      all-negative-weight accumulator has hi == 0), which is what lets
      `check_ranges` *prove* — not assert at runtime — that every
      WeightedSum fits its inferred `signed_width` and that the
      popcount kernel's int32 accumulation is safe at the actual
      fan-in. `RangeAnalysis.bounds()`/`widths()` reproduce
      `graph.value_bounds`/`graph.node_widths` exactly, so the Verilog
      and cost backends consume THIS analysis instead of recomputing
      (golden Verilog is byte-identical). `check_observed` replaces
      `evaluate(check_widths=True)`: any value the interpreter can
      produce is bracketed by the static interval.

  Plan certification — `verify_plan`: packed lane padding exactness
      (pad rows beyond the true fan-in are zero), `decompose_planes`
      losslessness (bit-planes reconstruct the int32 matrix bit for
      bit, positive/negative planes are disjoint, the plane count
      covers the post-pass magnitude range), layer chaining, and int32
      accumulation safety per layer.

  Tile legality — `tile_legality`: the pallas kernels clamp any block
      size to the (rounded) problem dims, so two candidates that clamp
      to the same effective (bm, bn, bkw) per layer run the *same*
      kernel. The legality closure statically rejects non-positive
      blocks and clamp-duplicates so `KernelTuner` never spends a
      measurement on a candidate that cannot change the outcome.

  Stack compatibility — `diagnose_stack`: the structured report of WHY
      a set of model versions cannot share one stacked dispatch
      (irregular circuit, depth/threshold/input/class disagreement),
      consumed by `NetServer` in place of its former silent
      `except (IrregularCircuitError, ValueError)` fallback.

  Store linting — `lint_store` / `python -m repro.netgen.analysis
      <store-dir>`: re-verify every persisted artifact in an
      `ArtifactStore` (format, schema fields, circuit invariants,
      content-address consistency, cost and proof-summary agreement
      with a recompute), exiting non-zero with structured diagnostics
      on any corrupt or stale entry. CI runs it over the cached
      `.netgen-store`.

Wiring: `PipelineSpec.run(verify=...)` checks invariants between
passes (default from the `NETGEN_VERIFY` env var — on in tests/CI, off
in prod); `Session.compile_resolved` always runs the range analysis
pre-backend, raising under strict verification and otherwise counting
`netgen_verify_failures_total` and proceeding; the proof summary
persists with the artifact (`meta.json`) and prints in
`artifact.report()`.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
from pathlib import Path
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.netgen.graph import (
    Argmax, Circuit, InputCompare, IrregularCircuitError, SignStep,
    WeightedSum, signed_width,
)
from repro.netgen.plan import (
    ARGMAX, PACK_LANES, STEP, ExecutionPlan, lower_circuit,
)

__all__ = [
    "Diagnostic", "FUSEDNET_VMEM_BYTES", "RangeAnalysis", "StackReport",
    "VerificationError", "analyze", "analyze_ranges", "check_envelope",
    "check_observed", "check_ranges", "diagnose_stack",
    "fusednet_vmem_bytes", "lint_store", "proof_summary", "strict_verify",
    "summary_row", "tile_legality", "tile_report", "verify_circuit",
    "verify_plan",
]

_SUMMARY_FORMAT = "netgen-analysis-v1"
INT32_MAX = 2 ** 31 - 1


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One invariant violation: which check, where, and why. `check` is
    a dotted invariant class ("structure.topo-order", "range.envelope",
    "plan.planes-lossless", "stack.depth", "store.key"); `stage` names
    the pipeline pass (or store entry) the violation was detected
    after, `node` the offending IR node when one exists."""
    check: str
    message: str
    node: int | None = None
    stage: str | None = None

    def row(self) -> str:
        where = ""
        if self.stage is not None:
            where += f" after {self.stage!r}"
        if self.node is not None:
            where += f" at node {self.node}"
        return f"[{self.check}]{where}: {self.message}"


class VerificationError(ValueError):
    """A batch of invariant violations, raised together so one broken
    pass reports every consequence, not just the first."""

    def __init__(self, diagnostics: Sequence[Diagnostic]):
        self.diagnostics = tuple(diagnostics)
        shown = [d.row() for d in self.diagnostics[:8]]
        if len(self.diagnostics) > len(shown):
            shown.append(f"... and {len(self.diagnostics) - len(shown)} more")
        super().__init__(
            f"{len(self.diagnostics)} invariant violation(s):\n  "
            + "\n  ".join(shown))


def _finish(diags: list, collect: bool) -> list:
    if diags and not collect:
        raise VerificationError(diags)
    return diags


def strict_verify() -> bool:
    """Whether verification failures should raise (the `NETGEN_VERIFY`
    env var: on by default in tests/CI via conftest/workflow env, off
    in prod where failures only count `netgen_verify_failures_total`)."""
    import os
    v = os.environ.get("NETGEN_VERIFY", "0").strip().lower()
    return v not in ("", "0", "false", "off", "no")


# ---------------------------------------------------------------------------
# Structural verifier
# ---------------------------------------------------------------------------

def _term_arrays(n: WeightedSum) -> tuple[np.ndarray, np.ndarray]:
    """(weights, srcs) of one accumulator as int64 arrays — the hot
    per-term sweeps (verifier, range dataflow, postconditions) are
    vectorized over these instead of looping Python-side (post-addend
    circuits carry sum(|w|) terms; a per-term interpreter loop made the
    analysis cost ~20% of pipeline time, numpy keeps it under 10%)."""
    k = len(n.terms)
    ws = np.fromiter((t.weight for t in n.terms), np.int64, count=k)
    srcs = np.fromiter((t.src for t in n.terms), np.int64, count=k)
    return ws, srcs


def _extract_terms(circuit: Circuit) -> list:
    """Term arrays for every node, aligned with `circuit.nodes` (None
    for non-accumulators). Extraction touches every Term once and
    dominates analysis cost, so `analyze` computes this list one time
    and threads it through the verifier, the postconditions, and the
    range sweep via their private `_terms` parameter."""
    return [_term_arrays(n) if isinstance(n, WeightedSum) else None
            for n in circuit.nodes]


def verify_circuit(circuit: Circuit, *, after_pass: str | None = None,
                   stage: str | None = None,
                   collect: bool = False,
                   _terms: list | None = None) -> list[Diagnostic]:
    """Check every structural invariant of the IR; with `after_pass`
    also the named pass's postconditions. Raises `VerificationError`
    unless `collect=True` (then the diagnostics are returned)."""
    diags: list[Diagnostic] = []

    def bad(check: str, message: str, node: int | None = None) -> None:
        diags.append(Diagnostic(
            check=check, message=message, node=node, stage=stage))

    # kind-by-id array for the vectorized per-term checks (0 = not yet
    # defined at this point of the topological sweep)
    max_id = max((n.id for n in circuit.nodes if n.id >= 0), default=-1)
    kind = np.zeros(max_id + 1, np.int8)
    _BIT, _SUM, _ARGMAX = 1, 2, 3

    terms = _extract_terms(circuit) if _terms is None else _terms
    seen: dict[int, object] = {}
    step_of: dict[int, int] = {}        # sum id -> step id
    pixels: dict[int, int] = {}         # pixel index -> node id
    for i, n in enumerate(circuit.nodes):
        if n.id in seen:
            bad("structure.duplicate-id", f"node id {n.id} defined twice",
                n.id)
        if isinstance(n, InputCompare):
            if not 0 <= n.pixel < circuit.n_inputs:
                bad("structure.input-pixel",
                    f"pixel {n.pixel} outside [0, {circuit.n_inputs})", n.id)
            elif n.pixel in pixels:
                bad("structure.input-pixel",
                    f"pixel {n.pixel} compared twice "
                    f"(also node {pixels[n.pixel]})", n.id)
            else:
                pixels[n.pixel] = n.id
            if not 0 <= n.threshold <= 255:
                bad("structure.input-threshold",
                    f"threshold {n.threshold} outside the uint8 range", n.id)
        elif isinstance(n, WeightedSum):
            if n.layer < 1:
                bad("structure.sum-layer",
                    f"layer tag {n.layer} < 1", n.id)
            _, srcs = terms[i]
            in_range = (srcs >= 0) & (srcs <= max_id)
            kinds = np.zeros(len(srcs), np.int8)
            kinds[in_range] = kind[srcs[in_range]]
            if not np.all(kinds > 0):          # fast path: all defined
                for s in sorted(set(srcs[kinds == 0].tolist())):
                    bad("structure.topo-order",
                        f"reads node {s} before it is defined", n.id)
            if np.any(kinds == _ARGMAX):
                for s in sorted(set(srcs[kinds == _ARGMAX].tolist())):
                    bad("structure.term-src",
                        f"term reads the Argmax node {s}", n.id)
        elif isinstance(n, SignStep):
            src = seen.get(n.src)
            if src is None:
                bad("structure.topo-order",
                    f"reads node {n.src} before it is defined", n.id)
            elif not isinstance(src, WeightedSum):
                bad("structure.step-src",
                    f"step source {n.src} is {type(src).__name__}, "
                    "not a WeightedSum", n.id)
            elif n.src in step_of:
                bad("structure.step-dup",
                    f"sum {n.src} already feeds step {step_of[n.src]}", n.id)
            else:
                step_of[n.src] = n.id
        elif isinstance(n, Argmax):
            if not n.srcs:
                bad("structure.argmax-arity", "argmax over zero scores", n.id)
            if len(set(n.srcs)) != len(n.srcs):
                bad("structure.argmax-dup",
                    "argmax reads a score twice", n.id)
            for s in n.srcs:
                src = seen.get(s)
                if src is None:
                    bad("structure.topo-order",
                        f"reads node {s} before it is defined", n.id)
                elif not isinstance(src, WeightedSum):
                    bad("structure.argmax-src",
                        f"score {s} is {type(src).__name__}, "
                        "not a WeightedSum", n.id)
        seen[n.id] = n
        if 0 <= n.id <= max_id:
            kind[n.id] = (_SUM if isinstance(n, WeightedSum)
                          else _ARGMAX if isinstance(n, Argmax) else _BIT)

    out = seen.get(circuit.output)
    if out is None or not isinstance(out, Argmax):
        bad("structure.output", "output must name an Argmax node",
            circuit.output)

    if after_pass is not None:
        post = _POSTCONDITIONS.get(after_pass)
        if post is not None:
            post(circuit, bad, terms)
    return _finish(diags, collect)


# -- per-pass postconditions (keyed by registry AND function name) ----------

def _post_zeros(circuit: Circuit, bad, terms: list) -> None:
    for i, n in enumerate(circuit.nodes):
        if isinstance(n, WeightedSum) and n.terms:
            ws, _ = terms[i]
            if not ws.all():
                bad("postcondition.zeros",
                    "zero-weight term survived delete_zero_terms", n.id)


def _post_addends(circuit: Circuit, bad, terms: list) -> None:
    for i, n in enumerate(circuit.nodes):
        if isinstance(n, WeightedSum) and n.terms:
            ws, _ = terms[i]
            nonunit = np.abs(ws) != 1
            if nonunit.any():
                w = int(ws[nonunit][0])
                bad("postcondition.addends",
                    f"non-unit weight {w} survived addend_rewrite", n.id)


def _post_prune(circuit: Circuit, bad, terms: list) -> None:
    consumed = {nid for nid, cs in circuit.consumers().items() if cs}
    by_id = circuit._by_id()
    out = by_id.get(circuit.output)
    final = set(out.srcs) if isinstance(out, Argmax) else set()
    for n in circuit.nodes:
        if isinstance(n, SignStep):
            src = by_id.get(n.src)
            if isinstance(src, WeightedSum) and not src.terms:
                bad("postcondition.prune",
                    f"step of the empty (constant-0) sum {n.src} survived "
                    "prune_dead_units", n.id)
            if n.id not in consumed:
                bad("postcondition.prune",
                    "unread hidden step survived prune_dead_units", n.id)
        elif isinstance(n, WeightedSum):
            if n.id not in consumed and n.id not in final:
                bad("postcondition.prune",
                    "unread hidden sum survived prune_dead_units", n.id)


_POSTCONDITIONS: dict[str, Callable] = {
    "zeros": _post_zeros, "delete_zero_terms": _post_zeros,
    "addends": _post_addends, "addend_rewrite": _post_addends,
    "prune": _post_prune, "prune_dead_units": _post_prune,
}


# ---------------------------------------------------------------------------
# Range dataflow
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NodeRange:
    """Per-node result of the dataflow: the exact value interval
    [lo, hi], the paper's symmetric magnitude bound (what hardware
    widths are sized from — `sum(|w| * bound(src))`, identical to
    `graph.value_bounds`), and the signed bit-width sized from it."""
    lo: int
    hi: int
    bound: int
    width: int

    @property
    def max_abs(self) -> int:
        return max(abs(self.lo), abs(self.hi))


@dataclasses.dataclass(frozen=True)
class RangeAnalysis:
    """The full per-node range map for one circuit, with the
    `value_bounds`/`node_widths`-compatible views the Verilog and cost
    backends consume (so wire widths come from ONE analysis)."""
    ranges: dict[int, NodeRange]

    def __getitem__(self, nid: int) -> NodeRange:
        return self.ranges[nid]

    def bounds(self) -> dict[int, int]:
        """Per-node magnitude bound — exactly `graph.value_bounds`."""
        return {nid: r.bound for nid, r in self.ranges.items()}

    def widths(self) -> dict[int, int]:
        """Per-node signed bit-width — exactly `graph.node_widths`."""
        return {nid: r.width for nid, r in self.ranges.items()}

    def output_envelope(self, circuit: Circuit) -> tuple:
        """The (lo, hi) interval of every class score, in argmax
        order — the quantity an exact rewrite may tighten but never
        widen (the pipeline verifier's cross-pass invariant)."""
        out = circuit.node(circuit.output)
        if not isinstance(out, Argmax):
            return ()
        return tuple((self.ranges[s].lo, self.ranges[s].hi)
                     for s in out.srcs)


def analyze_ranges(circuit: Circuit, *,
                   _terms: list | None = None) -> RangeAnalysis:
    """One topological sweep computing every node's `NodeRange` with
    exact integer interval arithmetic (see module doc). Terms reading
    an undefined source contribute nothing — structural breakage is
    `verify_circuit`'s to report; this sweep must not crash on the
    circuit it is diagnosing."""
    terms = _extract_terms(circuit) if _terms is None else _terms
    ranges: dict[int, NodeRange] = {}
    # id-indexed interval arrays for the vectorized accumulator sweep
    # (undefined srcs read a 0-everything slot and contribute nothing)
    max_id = max((n.id for n in circuit.nodes if n.id >= 0), default=-1)
    lo_a = np.zeros(max_id + 1, np.int64)
    hi_a = np.zeros(max_id + 1, np.int64)
    bd_a = np.zeros(max_id + 1, np.int64)
    for i, n in enumerate(circuit.nodes):
        if isinstance(n, (InputCompare, SignStep)):
            ranges[n.id] = NodeRange(lo=0, hi=1, bound=1, width=1)
            if 0 <= n.id <= max_id:
                hi_a[n.id] = bd_a[n.id] = 1
        elif isinstance(n, WeightedSum):
            ws, srcs = terms[i]
            ok = (srcs >= 0) & (srcs <= max_id)
            if not ok.all():
                ws, srcs = ws[ok], srcs[ok]
            slo, shi = lo_a[srcs], hi_a[srcs]
            pos = ws >= 0
            lo = int(np.where(pos, ws * slo, ws * shi).sum())
            hi = int(np.where(pos, ws * shi, ws * slo).sum())
            bound = int((np.abs(ws) * bd_a[srcs]).sum())
            ranges[n.id] = NodeRange(
                lo=lo, hi=hi, bound=bound, width=signed_width(bound))
            if 0 <= n.id <= max_id:
                lo_a[n.id], hi_a[n.id], bd_a[n.id] = lo, hi, bound
        elif isinstance(n, Argmax):
            k = len(n.srcs)
            ranges[n.id] = NodeRange(
                lo=0, hi=max(k - 1, 0), bound=max(k - 1, 1),
                width=max(math.ceil(math.log2(max(k, 2))), 1))
    return RangeAnalysis(ranges=ranges)


def check_ranges(circuit: Circuit, ranges: RangeAnalysis | None = None, *,
                 stage: str | None = None,
                 collect: bool = False) -> list[Diagnostic]:
    """Prove every accumulator fits its inferred signed width and stays
    int32-safe (the popcount kernel accumulates int32 at the actual
    fan-in). The width proof is the theorem the Verilog backend relies
    on: interval ⊆ [-2^(w-1), 2^(w-1) - 1]."""
    if ranges is None:
        ranges = analyze_ranges(circuit)
    diags: list[Diagnostic] = []
    for n in circuit.nodes:
        if not isinstance(n, WeightedSum):
            continue
        r = ranges.ranges.get(n.id)
        if r is None:
            diags.append(Diagnostic(
                check="range.missing", stage=stage, node=n.id,
                message="no range computed for accumulator"))
            continue
        lim = 1 << (r.width - 1)
        if r.lo < -lim or r.hi > lim - 1:
            diags.append(Diagnostic(
                check="range.width-overflow", stage=stage, node=n.id,
                message=f"interval [{r.lo}, {r.hi}] does not fit the "
                        f"inferred {r.width}-bit signed register"))
        if r.bound > INT32_MAX:
            diags.append(Diagnostic(
                check="range.int32", stage=stage, node=n.id,
                message=f"magnitude bound {r.bound} exceeds int32 — the "
                        "popcount kernel's accumulator would overflow"))
    return _finish(diags, collect)


def check_envelope(before: tuple, after: tuple, *, stage: str | None = None,
                   collect: bool = False) -> list[Diagnostic]:
    """Cross-pass invariant: an exact rewrite may tighten a class
    score's interval (pruning a constant-0 unit drops its slack) but
    must never widen it — a widened envelope means the pass changed
    the arithmetic (mis-sized a weight, dropped a source)."""
    diags: list[Diagnostic] = []
    if len(before) != len(after):
        diags.append(Diagnostic(
            check="range.class-count", stage=stage,
            message=f"pass changed the class count: "
                    f"{len(before)} -> {len(after)}"))
        return _finish(diags, collect)
    for k, ((blo, bhi), (alo, ahi)) in enumerate(zip(before, after)):
        if alo < blo or ahi > bhi:
            diags.append(Diagnostic(
                check="range.envelope", stage=stage,
                message=f"class {k} score interval widened from "
                        f"[{blo}, {bhi}] to [{alo}, {ahi}] — the rewrite "
                        "is not value-preserving"))
    return _finish(diags, collect)


def check_observed(circuit: Circuit, x_uint8, *,
                   step_semantics: str = "strict",
                   ranges: RangeAnalysis | None = None) -> None:
    """Execute the circuit on a uint8 batch and check every observed
    node value against its static interval — the dynamic face of the
    range analysis (subsumes `evaluate(check_widths=True)`: the
    interval is proven to fit the width by `check_ranges`, so any
    bracketed value fits too). Raises `VerificationError` on escape."""
    if ranges is None:
        ranges = analyze_ranges(circuit)
    x = np.asarray(x_uint8)
    vals: dict[int, np.ndarray] = {}
    diags: list[Diagnostic] = []
    for n in circuit.nodes:
        if isinstance(n, InputCompare):
            vals[n.id] = (
                x[:, n.pixel].astype(np.int64) > n.threshold).astype(np.int64)
        elif isinstance(n, WeightedSum):
            acc = np.zeros(x.shape[0], dtype=np.int64)
            for t in n.terms:
                acc += t.weight * vals[t.src]
            vals[n.id] = acc
        elif isinstance(n, SignStep):
            v = vals[n.src]
            vals[n.id] = (
                v > 0 if step_semantics == "strict" else v >= 0
            ).astype(np.int64)
        elif isinstance(n, Argmax):
            vals[n.id] = np.argmax(
                np.stack([vals[s] for s in n.srcs], axis=1), axis=1)
        r = ranges.ranges[n.id]
        v = vals[n.id]
        lo, hi = int(v.min(initial=0)), int(v.max(initial=0))
        if lo < r.lo or hi > r.hi:
            diags.append(Diagnostic(
                check="range.observed", node=n.id,
                message=f"observed values span [{lo}, {hi}] outside the "
                        f"static interval [{r.lo}, {r.hi}]"))
    _finish(diags, collect=False)


def analyze(circuit: Circuit, *, after_pass: str | None = None,
            stage: str | None = None, collect: bool = False
            ) -> tuple[RangeAnalysis, list[Diagnostic]]:
    """The compile driver's one-shot: structural verification + range
    proofs in a single call. Returns (ranges, diagnostics); raises
    unless `collect=True`."""
    terms = _extract_terms(circuit)
    diags = verify_circuit(circuit, after_pass=after_pass, stage=stage,
                           collect=True, _terms=terms)
    ranges = analyze_ranges(circuit, _terms=terms)
    diags += check_ranges(circuit, ranges, stage=stage, collect=True)
    return ranges, _finish(diags, collect)


# ---------------------------------------------------------------------------
# Proof summary (persisted with the Artifact)
# ---------------------------------------------------------------------------

def proof_summary(circuit: Circuit,
                  ranges: RangeAnalysis | None = None) -> dict:
    """The JSON-stable certificate `Session.compile_resolved` stamps on
    every Artifact (and `meta.json` persists): what the range analysis
    proved about the shipped circuit. `slack_bits` totals the bits the
    symmetric sizing bound spends beyond what the exact intervals need
    — the headroom a future interval-sized emitter could reclaim."""
    if ranges is None:
        ranges = analyze_ranges(circuit)
    sums = [n for n in circuit.nodes if isinstance(n, WeightedSum)]
    layer_widths: dict[str, int] = {}
    max_abs = 0
    slack = 0
    for n in sums:
        r = ranges.ranges[n.id]
        key = str(n.layer)
        layer_widths[key] = max(layer_widths.get(key, 0), r.width)
        max_abs = max(max_abs, r.max_abs)
        slack += r.width - signed_width(r.max_abs)
    return {
        "format": _SUMMARY_FORMAT,
        "nodes": len(circuit.nodes),
        "sum_nodes": len(sums),
        "terms": sum(len(n.terms) for n in sums),
        "max_width": max((layer_widths[k] for k in layer_widths), default=0),
        "max_abs_acc": max_abs,
        "layer_widths": layer_widths,
        "slack_bits": slack,
        "int32_safe": all(
            ranges.ranges[n.id].bound <= INT32_MAX for n in sums),
        "verified": True,
    }


def summary_row(summary: Mapping) -> str:
    """One-line rendering of a proof summary for `artifact.report()`."""
    return (f"analysis: proved {summary['sum_nodes']} accumulators fit "
            f"<= {summary['max_width']} bits (max |acc| "
            f"{summary['max_abs_acc']}, slack {summary['slack_bits']} bits, "
            f"int32_safe={str(bool(summary['int32_safe'])).lower()})")


# ---------------------------------------------------------------------------
# ExecutionPlan certification
# ---------------------------------------------------------------------------

def _unpack_words(words: np.ndarray) -> np.ndarray:
    """uint32 (..., W, N) -> {0,1} int64 (..., W*32, N) (bit i of word j
    is packed lane 32*j + i, matching `plan.decompose_planes`)."""
    shifts = np.arange(PACK_LANES, dtype=np.uint32)
    bits = (words[..., :, None, :] >> shifts[None, :, None]) & np.uint32(1)
    lead = words.shape[:-2]
    return bits.reshape(
        *lead, words.shape[-2] * PACK_LANES, words.shape[-1]).astype(np.int64)


def verify_plan(plan: ExecutionPlan, *, stage: str | None = None,
                collect: bool = False) -> list[Diagnostic]:
    """Certify an ExecutionPlan's form invariants (see module doc):
    layer chaining, packed lane-padding exactness, bit-plane
    losslessness and magnitude coverage, int32 accumulation safety."""
    diags: list[Diagnostic] = []

    def bad(check: str, message: str, layer: int | None = None) -> None:
        where = message if layer is None else f"layer {layer}: {message}"
        diags.append(Diagnostic(check=check, message=where, stage=stage))

    if not plan.layers:
        bad("plan.empty", "plan has no layers")
        return _finish(diags, collect)

    for i, layer in enumerate(plan.layers):
        want_act = STEP if i < plan.depth - 1 else ARGMAX
        if layer.activation != want_act:
            bad("plan.activation",
                f"activation {layer.activation!r}, expected {want_act!r}", i)
        want_ndim = 3 if plan.stacked else 2
        if layer.weights.ndim != want_ndim:
            bad("plan.stacked",
                f"weights ndim {layer.weights.ndim}, expected {want_ndim}", i)
            return _finish(diags, collect)
        if plan.stacked and layer.weights.shape[0] != plan.n_models:
            bad("plan.stacked",
                f"model axis {layer.weights.shape[0]} != n_models "
                f"{plan.n_models}", i)

    # layer chaining: fan_in of layer l+1 equals fan_out of layer l
    # (padded up to a lane multiple in the packed forms); layer 0 reads
    # the binarized inputs.
    def padded(k: int) -> int:
        if not plan.packed:
            return k
        return -(-k // PACK_LANES) * PACK_LANES if k else 0

    expect = padded(plan.n_inputs)
    true_fan_in = plan.n_inputs
    for i, layer in enumerate(plan.layers):
        if layer.fan_in != expect:
            bad("plan.chain",
                f"fan_in {layer.fan_in} != expected {expect} "
                "(predecessor fan_out)", i)
        if plan.packed:
            if layer.fan_in % PACK_LANES:
                bad("plan.pack",
                    f"packed fan_in {layer.fan_in} is not a multiple of "
                    f"{PACK_LANES}", i)
            if layer.words != layer.fan_in // PACK_LANES:
                bad("plan.pack",
                    f"words {layer.words} != fan_in // {PACK_LANES}", i)
            # lane padding exactness: every pad row must be zero, or a
            # padded activation bit could couple into a real score
            pad = layer.weights[..., true_fan_in:, :]
            if pad.size and np.any(pad != 0):
                bad("plan.pad-exact",
                    f"nonzero weights in the {layer.fan_in - true_fan_in} "
                    "zero-pad rows", i)
        if plan.bitplanes:
            _verify_planes(layer, i, bad)
        # int32 accumulation safety at the actual fan-in: the worst
        # column's sum of |w| bounds what the popcount kernel can
        # accumulate for one output
        mags = np.abs(layer.weights.astype(np.int64)).sum(axis=-2)
        worst = int(mags.max(initial=0))
        if worst > INT32_MAX:
            bad("plan.int32",
                f"max column magnitude {worst} exceeds int32", i)
        true_fan_in = layer.fan_out
        expect = padded(layer.fan_out)
    return _finish(diags, collect)


def _verify_planes(layer, i: int, bad) -> None:
    if layer.pos_planes is None or layer.neg_planes is None \
            or layer.n_planes is None:
        bad("plan.planes", "bit-plane form with no planes materialized", i)
        return
    if layer.pos_planes.shape != layer.neg_planes.shape:
        bad("plan.planes",
            f"pos/neg plane shapes differ: {layer.pos_planes.shape} vs "
            f"{layer.neg_planes.shape}", i)
        return
    if layer.pos_planes.shape[-3] != layer.n_planes:
        bad("plan.planes",
            f"plane axis {layer.pos_planes.shape[-3]} != n_planes "
            f"{layer.n_planes}", i)
        return
    mag = int(np.abs(layer.weights).max(initial=0))
    need = max(1, mag.bit_length())
    if layer.n_planes < need:
        bad("plan.planes-range",
            f"{layer.n_planes} planes cannot cover max |w| = {mag} "
            f"(needs {need})", i)
    if np.any(layer.pos_planes & layer.neg_planes):
        bad("plan.planes-disjoint",
            "a weight bit is set in both the positive and negative "
            "plane", i)
    # losslessness: the planes must reconstruct the int32 matrix bit
    # for bit — w = sum_b 2^b (unpack(pos_b) - unpack(neg_b))
    pos = _unpack_words(layer.pos_planes)
    neg = _unpack_words(layer.neg_planes)
    shifts = (1 << np.arange(layer.pos_planes.shape[-3], dtype=np.int64))
    recon = ((pos - neg)
             * shifts[:, None, None]).sum(axis=-3)
    if not np.array_equal(recon, layer.weights.astype(np.int64)):
        bad("plan.planes-lossless",
            "bit-plane decomposition does not reconstruct the weight "
            "matrix", i)


# ---------------------------------------------------------------------------
# Tile legality (consumed by KernelTuner)
# ---------------------------------------------------------------------------

def _rup(x: int, m: int = 8) -> int:
    # mirrors kernels.binary_matvec's clamping of tiny dims
    return max(m, ((x + m - 1) // m) * m)


def effective_tiles(plan: ExecutionPlan, form: str, blocks: Mapping,
                    batch: int) -> tuple:
    """The per-layer (bm, bn, bk/bkw) the kernels will ACTUALLY run
    after clamping a candidate's block sizes to the problem dims —
    two candidates with equal effective tiles launch identical grids
    (see `binary_matmul*`'s `min(b·, _rup(dim))` clamps). The fusednet
    megakernel has no fan-out tiling, so its per-layer tiles are
    (bm, bkw) pairs — candidates differing only in `bn` clamp to the
    same megakernel and dedupe."""
    bm, bn, bkw = int(blocks["bm"]), int(blocks["bn"]), int(blocks["bkw"])
    tiles = []
    fan_in = plan.n_inputs
    for layer in plan.layers:
        n = layer.fan_out
        if form == "fusednet":
            k_eff = min(bkw, max(-(-fan_in // PACK_LANES), 1))
            tiles.append((min(bm, _rup(batch)), k_eff))
        elif form == "dense":
            k_eff = min(bkw * PACK_LANES, _rup(fan_in))
            tiles.append((min(bm, _rup(batch)), min(bn, _rup(n)), k_eff))
        else:
            # packed/planes kernels see KW = ceil(fan_in / 32) lane words
            k_eff = min(bkw, max(-(-fan_in // PACK_LANES), 1))
            tiles.append((min(bm, _rup(batch)), min(bn, _rup(n)), k_eff))
        fan_in = n
    return tuple(tiles)


# VMEM budget for the whole-net megakernel: everything it keeps resident
# per grid step must fit one TPU core's vector memory (~16 MiB).
FUSEDNET_VMEM_BYTES = 16 * 1024 * 1024


def fusednet_vmem_bytes(plan: ExecutionPlan, *, bm: int,
                        bkw: int | None = None, batch: int | None = None
                        ) -> int:
    """Estimated per-grid-step VMEM residency of the fusednet megakernel
    for this plan, computed analytically from layer geometry and weight
    magnitudes (no plane decomposition is materialized — this runs per
    tuner candidate). Mirrors `MegakernelView.vmem_bytes`: all layers'
    bit-plane weights (one model's worth when stacked) + the input tile
    + the peak per-layer working set."""
    if batch is not None:
        bm = min(bm, _rup(batch))
    weight = 0
    peak = 0
    fan_in = plan.n_inputs
    depth = plan.depth
    for i, layer in enumerate(plan.layers):
        w = max(1, -(-fan_in // PACK_LANES))
        hidden = i < depth - 1
        n = layer.fan_out
        n_pad = (max(1, -(-n // PACK_LANES)) * PACK_LANES if hidden
                 else max(1, n))
        p = max(1, int(np.abs(layer.weights).max(initial=0)).bit_length())
        weight += 2 * p * w * n_pad * 4
        ck = min(bkw, w) if bkw else w
        work = 2 * bm * ck * n_pad * 4 + bm * n_pad * 4 + bm * w * 4
        peak = max(peak, work)
        fan_in = n
    return weight + bm * plan.n_inputs + peak + bm * 4


def tile_report(plan: ExecutionPlan, candidates: Sequence[Mapping], *,
                batch: int, multi: bool = False
                ) -> tuple[list, list]:
    """Split a candidate grid into (legal, rejected) where rejected is
    [(candidate, reason), ...]: non-positive blocks, and clamp-
    duplicates of an earlier candidate (searching both wastes a
    measurement on the same kernel)."""
    legal: list = []
    rejected: list = []
    seen: dict = {}
    for cand in candidates:
        reason = _tile_reason(plan, cand, batch=batch, seen=seen)
        if reason is None:
            legal.append(cand)
        else:
            rejected.append((cand, reason))
    return legal, rejected


def _tile_reason(plan: ExecutionPlan, cand: Mapping, *, batch: int,
                 seen: dict) -> str | None:
    form = cand.get("form", plan.form)
    for k in ("bm", "bn", "bkw"):
        v = cand.get(k)
        if v is not None and int(v) < 1:
            return f"non-positive block size {k}={v}"
    blocks = {k: cand.get(k) for k in ("bm", "bn", "bkw")}
    if any(v is None for v in blocks.values()):
        return None                      # partial candidate: cannot judge
    if form == "fusednet":
        need = fusednet_vmem_bytes(
            plan, bm=int(blocks["bm"]), bkw=int(blocks["bkw"]), batch=batch)
        if need > FUSEDNET_VMEM_BYTES:
            return (f"fusednet residency {need} B exceeds the "
                    f"{FUSEDNET_VMEM_BYTES} B VMEM budget")
    eff = (form, effective_tiles(plan, form, blocks, batch))
    prior = seen.get(eff)
    if prior is not None:
        return (f"clamps to the same effective tiles as candidate "
                f"{prior} — duplicate kernel")
    seen[eff] = dict(cand)
    return None


def tile_legality(plan: ExecutionPlan, *, batch: int,
                  multi: bool = False) -> Callable[[Mapping], str | None]:
    """A fresh legality closure for one tuning search: `legal(cand)`
    returns None (keep) or a rejection reason. Stateful — it remembers
    effective tiles already admitted — so build one per search."""
    seen: dict = {}

    def legal(cand: Mapping) -> str | None:
        return _tile_reason(plan, cand, batch=batch, seen=seen)

    return legal


# ---------------------------------------------------------------------------
# Stack compatibility (consumed by the serving layer)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StackReport:
    """Why a version set can (or cannot) share one stacked dispatch.
    `diagnostics` is empty when `compatible`; otherwise each entry
    names the disagreeing axis (stack.depth / stack.threshold /
    stack.inputs / stack.classes) or the version whose circuit has no
    layered tensor form (stack.irregular)."""
    compatible: bool
    n_versions: int
    diagnostics: tuple = ()

    @property
    def reason(self) -> str:
        return self.diagnostics[0].check if self.diagnostics else "none"

    def describe(self) -> str:
        if self.compatible:
            return f"{self.n_versions} versions stack-compatible"
        return (f"{self.n_versions} versions cannot stack:\n  "
                + "\n  ".join(d.row() for d in self.diagnostics))


def diagnose_stack(items: Sequence) -> StackReport:
    """Structured stack-compatibility report over circuits or dense
    single-net plans — the checks `plan.stack_plans` enforces by
    raising, surfaced as diagnostics the serving layer can record
    instead of swallowing."""
    diags: list[Diagnostic] = []
    plans: list[ExecutionPlan] = []
    for i, item in enumerate(items):
        if isinstance(item, ExecutionPlan):
            plans.append(item)
            continue
        try:
            plans.append(lower_circuit(item))
        except IrregularCircuitError as e:
            diags.append(Diagnostic(
                check="stack.irregular", stage=f"version {i}",
                message=str(e)))
    if not items:
        diags.append(Diagnostic(check="stack.empty",
                                message="no versions to stack"))
    if diags:
        return StackReport(compatible=False, n_versions=len(items),
                           diagnostics=tuple(diags))
    for i, p in enumerate(plans):
        if p.packed or p.stacked:
            diags.append(Diagnostic(
                check="stack.form", stage=f"version {i}",
                message="stacking takes dense single-net plans"))

    def axis(check: str, label: str, values: list) -> None:
        if len(set(values)) > 1:
            diags.append(Diagnostic(
                check=check,
                message=f"versions disagree on {label}: "
                        f"{sorted(set(values))}"))

    axis("stack.depth", "depth", [p.depth for p in plans])
    axis("stack.threshold", "input threshold",
         [p.input_threshold for p in plans])
    axis("stack.inputs", "input width", [p.n_inputs for p in plans])
    axis("stack.classes", "class count", [p.n_classes for p in plans])
    return StackReport(compatible=not diags, n_versions=len(items),
                       diagnostics=tuple(diags))


# ---------------------------------------------------------------------------
# ArtifactStore linter (`python -m repro.netgen.analysis <store>`)
# ---------------------------------------------------------------------------

_META_REQUIRED = ("format", "digest", "pipeline", "target", "kind",
                  "pass_stats", "cost", "timings")


def lint_store(root) -> dict[str, list[Diagnostic]]:
    """Re-verify every entry of an `ArtifactStore` directory. Returns
    {key: diagnostics} for the entries that FAILED (clean stores map to
    {}). Checks: meta schema, circuit invariants + range proofs,
    content-address consistency (a mismatched key is a stale entry
    compiled by different sources or schema), recomputed cost and
    proof-summary agreement, plan-form certification for callables."""
    # lazy imports: session imports this module for the compile driver
    from repro.netgen.backends.cost import logic_cells
    from repro.netgen.graph import circuit_from_arrays
    from repro.netgen.pipeline import PipelineSpec
    from repro.netgen.session import _FORMAT, artifact_key

    root = Path(root).expanduser()
    if not root.is_dir():
        raise FileNotFoundError(f"no artifact store at {root}")
    failures: dict[str, list[Diagnostic]] = {}
    for entry in sorted(p for p in root.iterdir() if p.is_dir()):
        if entry.name.startswith(".tmp-"):
            continue
        diags = _lint_entry(entry, _FORMAT, artifact_key, PipelineSpec,
                            circuit_from_arrays, logic_cells)
        if diags:
            failures[entry.name] = diags
    return failures


def _lint_entry(entry: Path, fmt: str, artifact_key, PipelineSpec,
                circuit_from_arrays, logic_cells) -> list[Diagnostic]:
    key = entry.name
    diags: list[Diagnostic] = []

    def bad(check: str, message: str) -> None:
        diags.append(Diagnostic(check=check, message=message, stage=key[:12]))

    try:
        with open(entry / "meta.json") as f:
            meta = json.load(f)
    except Exception as e:
        bad("store.meta", f"unreadable meta.json: {e}")
        return diags
    if meta.get("format") != fmt:
        bad("store.format",
            f"format {meta.get('format')!r} != expected {fmt!r}")
        return diags
    missing = [k for k in _META_REQUIRED if k not in meta]
    if missing:
        bad("store.fields", f"meta.json missing {missing}")
        return diags

    try:
        with np.load(entry / "circuit.npz") as z:
            circuit = circuit_from_arrays(z)
    except Exception as e:
        bad("store.circuit", f"unreadable circuit.npz: {e}")
        return diags
    for d in verify_circuit(circuit, stage=key[:12], collect=True):
        diags.append(d)
    ranges = analyze_ranges(circuit)
    diags.extend(check_ranges(circuit, ranges, stage=key[:12], collect=True))

    try:
        spec = PipelineSpec.coerce(meta["pipeline"])
        want = artifact_key(meta["digest"], spec, meta["target"])
    except Exception as e:
        bad("store.key", f"cannot recompute content address: {e}")
        want = None
    if want is not None and want != key:
        bad("store.key",
            "stale entry: stored content address does not match the "
            "current compiler sources/spec (recompute "
            f"{want[:12]}... != {key[:12]}...)")

    cost = logic_cells(circuit, analysis=ranges).as_dict()
    if cost != meta["cost"]:
        bad("store.cost",
            f"recomputed cell estimate {cost} != stored {meta['cost']}")
    recorded = meta.get("analysis")
    if recorded is not None and recorded != proof_summary(circuit, ranges):
        bad("store.analysis",
            "stored proof summary does not match a recompute")
    if meta["kind"] == "text" and not (entry / "artifact.txt").exists():
        bad("store.artifact", "text artifact with no artifact.txt")
    if meta["kind"] == "callable":
        form = meta.get("plan_form") or "dense"
        if form not in ("dense", "packed", "planes"):
            bad("store.plan", f"unknown plan_form {form!r}")
        else:
            try:
                plan = lower_circuit(circuit, form=form)
            except IrregularCircuitError as e:
                bad("store.plan", f"callable artifact's circuit has no "
                                  f"layered form: {e}")
            else:
                diags.extend(verify_plan(plan, stage=key[:12], collect=True))
    return diags


def main(argv: Sequence[str] | None = None) -> int:
    """CLI: lint every artifact in a store directory; exit 0 when all
    entries verify, 1 with one structured diagnostic line per failure
    otherwise."""
    parser = argparse.ArgumentParser(
        prog="python -m repro.netgen.analysis",
        description="lint every artifact in a netgen ArtifactStore")
    parser.add_argument("store", help="ArtifactStore root directory")
    parser.add_argument("-q", "--quiet", action="store_true",
                        help="suppress the per-entry OK lines")
    args = parser.parse_args(argv)
    try:
        failures = lint_store(args.store)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    root = Path(args.store).expanduser()
    keys = sorted(p.name for p in root.iterdir()
                  if p.is_dir() and not p.name.startswith(".tmp-"))
    for key in keys:
        if key in failures:
            for d in failures[key]:
                print(f"FAIL {key[:12]} {d.row()}")
        elif not args.quiet:
            print(f"ok   {key[:12]}")
    n_bad = len(failures)
    print(f"linted {len(keys)} artifact(s): "
          f"{len(keys) - n_bad} ok, {n_bad} failed")
    return 1 if failures else 0


if __name__ == "__main__":      # pragma: no cover — exercised in CI
    sys.exit(main())
