"""Typed circuit IR for the netgen compiler.

The paper's "hardware generation" script (§IV-§V) walks trained weight
matrices and prints Verilog directly. Here the same network is first
lowered into an explicit *circuit graph* — the representation every
optimization pass and every backend operates on:

  InputCompare  — paper §III.B / Fig. 6 line 5: `pixel > threshold` -> 1 bit
  WeightedSum   — a signed accumulator node: sum of weighted single-bit (or
                  shared sub-sum) sources. The paper's `hi`/`fi` wires.
  SignStep      — paper §III.A + §V.D: the step activation, realized on
                  hardware as the (negated) MSB of the accumulator.
  Argmax        — paper Fig. 6 line 15: the priority-mux comparison network
                  producing the predicted class index.

Nodes are immutable and identified by dense integer ids; a `Circuit` is a
topologically-ordered tuple of nodes. Every value-carrying node has a
*signed bit-width* inferred exactly from the maximum magnitude it can
reach (`value_bound` / `signed_width`), which is what the Verilog backend
uses to size wires and what the interpreter uses to check that no
emitted accumulator could overflow.

`evaluate` is the reference interpreter: it executes the circuit with
the exact node semantics over a uint8 input batch. It is the arbiter in
backend-parity tests (jnp / pallas / Verilog must all agree with it).

A faithfulness note on the step node: the compiled TPU backends (and the
paper's *software* ladder, `quantize.predict_l3`) compute `acc > 0`,
while the paper's emitted Verilog uses the MSB trick `~acc[msb]`, i.e.
`acc >= 0`. The two differ only when an accumulator is exactly zero —
never observed on trained nets, but reachable on adversarial ones.
`evaluate(..., step_semantics=...)` exposes both so each backend can be
checked against the semantics it actually implements.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Union

import numpy as np

NodeId = int


@dataclasses.dataclass(frozen=True)
class Term:
    """One addend of a WeightedSum: `weight * value(src)`."""
    weight: int
    src: NodeId


@dataclasses.dataclass(frozen=True)
class InputCompare:
    """1-bit comparator on one raw input component: `x[pixel] > threshold`."""
    id: NodeId
    pixel: int
    threshold: int


@dataclasses.dataclass(frozen=True)
class WeightedSum:
    """Signed integer accumulator: `sum(t.weight * value(t.src))`.

    `layer` tags which dense layer the node was lowered from (1-based);
    pass-created sharing nodes keep the layer of their consumers. Backends
    that reconstruct dense matrices group by this tag.
    """
    id: NodeId
    terms: tuple[Term, ...]
    layer: int


@dataclasses.dataclass(frozen=True)
class SignStep:
    """Step activation of one accumulator (1 bit)."""
    id: NodeId
    src: NodeId


@dataclasses.dataclass(frozen=True)
class Argmax:
    """Priority argmax over the final accumulators (first max wins)."""
    id: NodeId
    srcs: tuple[NodeId, ...]


Node = Union[InputCompare, WeightedSum, SignStep, Argmax]


class IrregularCircuitError(ValueError):
    """Raised when a backend needs the regular layered form (dense weight
    matrices) but the circuit has been rewritten into a general DAG
    (e.g. by common-addend sharing)."""


@dataclasses.dataclass(frozen=True)
class Circuit:
    """A complete inference circuit: uint8 input vector -> class index.

    `nodes` is topologically ordered (every Term.src / SignStep.src /
    Argmax.src precedes its consumer). `output` is the Argmax node id.
    """
    n_inputs: int
    input_threshold: int
    nodes: tuple[Node, ...]
    output: NodeId

    # -- structure helpers ---------------------------------------------------

    def node(self, nid: NodeId) -> Node:
        return self._by_id()[nid]

    def _by_id(self) -> dict[NodeId, Node]:
        cache = getattr(self, "_id_cache", None)
        if cache is None or len(cache) != len(self.nodes):
            cache = {n.id: n for n in self.nodes}
            object.__setattr__(self, "_id_cache", cache)
        return cache

    def by_kind(self, kind: type) -> list[Node]:
        return [n for n in self.nodes if isinstance(n, kind)]

    @property
    def depth(self) -> int:
        """Number of dense layers the circuit was lowered from."""
        sums = self.by_kind(WeightedSum)
        return max((n.layer for n in sums), default=0)

    def consumers(self) -> dict[NodeId, list[NodeId]]:
        """Map node id -> ids of nodes that read it."""
        out: dict[NodeId, list[NodeId]] = {n.id: [] for n in self.nodes}
        for n in self.nodes:
            if isinstance(n, WeightedSum):
                for t in n.terms:
                    out[t.src].append(n.id)
            elif isinstance(n, SignStep):
                out[n.src].append(n.id)
            elif isinstance(n, Argmax):
                for s in n.srcs:
                    out[s].append(n.id)
        return out

    def validate(self) -> None:
        """Check topological order, id uniqueness, and output wiring.

        This is the quick inline sanity check; the full structural
        verifier (kind-specific arity/field invariants, pass
        postconditions, range/overflow proofs) lives in
        `repro.netgen.analysis.verify_circuit` and runs at every pass
        boundary under `PipelineSpec.run(verify=True)`."""
        seen: set[NodeId] = set()
        for n in self.nodes:
            if n.id in seen:
                raise ValueError(f"duplicate node id {n.id}")
            if isinstance(n, WeightedSum):
                srcs: Iterable[NodeId] = (t.src for t in n.terms)
            elif isinstance(n, SignStep):
                srcs = (n.src,)
            elif isinstance(n, Argmax):
                srcs = n.srcs
            else:
                srcs = ()
            for s in srcs:
                if s not in seen:
                    raise ValueError(
                        f"node {n.id} reads {s} before it is defined")
            seen.add(n.id)
        if self.output not in seen or not isinstance(self.node(self.output), Argmax):
            raise ValueError("output must name an Argmax node")


# ---------------------------------------------------------------------------
# Bit-width inference
# ---------------------------------------------------------------------------

def value_bounds(circuit: Circuit) -> dict[NodeId, int]:
    """Exact per-node bound on |value|: single-bit nodes are 1; a sum node
    reaches at most `sum(|w| * bound(src))`. One topological sweep."""
    bound: dict[NodeId, int] = {}
    for n in circuit.nodes:
        if isinstance(n, (InputCompare, SignStep)):
            bound[n.id] = 1
        elif isinstance(n, WeightedSum):
            bound[n.id] = sum(abs(t.weight) * bound[t.src] for t in n.terms)
        elif isinstance(n, Argmax):
            bound[n.id] = max(len(n.srcs) - 1, 1)
    return bound


def signed_width(bound: int) -> int:
    """Bits for a signed register holding values in [-bound, bound]."""
    return max(math.ceil(math.log2(bound + 1)) + 1, 2) if bound > 0 else 2


def node_widths(circuit: Circuit) -> dict[NodeId, int]:
    """Per-node signed bit-widths (1 for the single-bit node kinds)."""
    widths: dict[NodeId, int] = {}
    for nid, b in value_bounds(circuit).items():
        n = circuit.node(nid)
        if isinstance(n, (InputCompare, SignStep)):
            widths[nid] = 1
        elif isinstance(n, Argmax):
            widths[nid] = max(math.ceil(math.log2(max(len(n.srcs), 2))), 1)
        else:
            widths[nid] = signed_width(b)
    return widths


# ---------------------------------------------------------------------------
# Layered-form extraction (for dense backends)
# ---------------------------------------------------------------------------

def as_layered_weights(circuit: Circuit) -> list[np.ndarray]:
    """Reconstruct dense int32 weight matrices from a *regular* circuit.

    Regular means: layer-l sums read only layer-(l-1) activations (inputs
    for l == 1), every hidden sum feeds exactly one SignStep, and the
    Argmax reads exactly the last layer's sums. Addend-rewritten circuits
    are fine (duplicate unit terms re-accumulate); shared/CSE circuits are
    not and raise IrregularCircuitError.
    """
    inputs = circuit.by_kind(InputCompare)
    sums = circuit.by_kind(WeightedSum)
    steps = circuit.by_kind(SignStep)
    depth = circuit.depth
    if depth == 0:
        raise IrregularCircuitError("circuit has no WeightedSum nodes")

    step_of = {s.src: s.id for s in steps}
    by_layer: dict[int, list[WeightedSum]] = {}
    for n in sums:
        by_layer.setdefault(n.layer, []).append(n)

    # activation index of each source node for the next layer up. A layer
    # pruned down to zero units yields a zero-width matrix (downstream
    # layers then sum nothing and score 0 — the constant-0 predictor).
    src_index: dict[NodeId, int] = {
        n.id: i for i, n in enumerate(sorted(inputs, key=lambda n: n.pixel))}
    mats: list[np.ndarray] = []
    for layer in range(1, depth + 1):
        cols = by_layer.get(layer, [])
        w = np.zeros((len(src_index), len(cols)), dtype=np.int32)
        next_index: dict[NodeId, int] = {}
        for j, n in enumerate(cols):
            for t in n.terms:
                if t.src not in src_index:
                    raise IrregularCircuitError(
                        f"layer {layer} sum {n.id} reads non-layer source {t.src}")
                w[src_index[t.src], j] += t.weight
            if layer < depth:
                if n.id not in step_of:
                    raise IrregularCircuitError(
                        f"hidden sum {n.id} has no SignStep")
                next_index[step_of[n.id]] = j
        mats.append(w)
        src_index = next_index
    return mats


# ---------------------------------------------------------------------------
# Array codec (for the persistent ArtifactStore)
# ---------------------------------------------------------------------------

_KIND_CODES = {InputCompare: 0, WeightedSum: 1, SignStep: 2, Argmax: 3}


def circuit_to_arrays(circuit: Circuit) -> dict[str, np.ndarray]:
    """Encode a circuit (regular OR irregular DAG) as a flat dict of
    integer arrays — the on-disk form `repro.netgen.session.ArtifactStore`
    persists via `np.savez`. Compact (terms are one (host_row, weight,
    src) int64 triple each, not a Python object) and code-free (no
    pickle: the store stays loadable across refactors and trustworthy
    across processes). `circuit_from_arrays` is the exact inverse.
    """
    kinds, ids = [], []
    cmp_pixel, cmp_thr = [], []
    sum_layer, sum_nterms, term_weight, term_src = [], [], [], []
    step_src, argmax_srcs, argmax_nsrcs = [], [], []
    for n in circuit.nodes:
        kinds.append(_KIND_CODES[type(n)])
        ids.append(n.id)
        if isinstance(n, InputCompare):
            cmp_pixel.append(n.pixel)
            cmp_thr.append(n.threshold)
        elif isinstance(n, WeightedSum):
            sum_layer.append(n.layer)
            sum_nterms.append(len(n.terms))
            for t in n.terms:
                term_weight.append(t.weight)
                term_src.append(t.src)
        elif isinstance(n, SignStep):
            step_src.append(n.src)
        else:
            argmax_nsrcs.append(len(n.srcs))
            argmax_srcs.extend(n.srcs)
    i64 = lambda xs: np.asarray(xs, dtype=np.int64)  # noqa: E731
    return {
        "header": i64([circuit.n_inputs, circuit.input_threshold,
                       circuit.output]),
        "kinds": i64(kinds), "ids": i64(ids),
        "cmp_pixel": i64(cmp_pixel), "cmp_thr": i64(cmp_thr),
        "sum_layer": i64(sum_layer), "sum_nterms": i64(sum_nterms),
        "term_weight": i64(term_weight), "term_src": i64(term_src),
        "step_src": i64(step_src),
        "argmax_nsrcs": i64(argmax_nsrcs), "argmax_srcs": i64(argmax_srcs),
    }


def circuit_from_arrays(arrays) -> Circuit:
    """Rebuild a circuit from `circuit_to_arrays` output (or an opened
    `np.load` of it). Validates the result before returning it."""
    a = {k: np.asarray(arrays[k]) for k in (
        "header", "kinds", "ids", "cmp_pixel", "cmp_thr", "sum_layer",
        "sum_nterms", "term_weight", "term_src", "step_src",
        "argmax_nsrcs", "argmax_srcs")}
    n_inputs, input_threshold, output = (int(v) for v in a["header"])
    nodes: list[Node] = []
    ci = si = ti = pi = ai = aj = 0
    for kind, nid in zip(a["kinds"].tolist(), a["ids"].tolist()):
        if kind == 0:
            nodes.append(InputCompare(
                id=nid, pixel=int(a["cmp_pixel"][ci]),
                threshold=int(a["cmp_thr"][ci])))
            ci += 1
        elif kind == 1:
            k = int(a["sum_nterms"][si])
            terms = tuple(
                Term(weight=int(a["term_weight"][ti + j]),
                     src=int(a["term_src"][ti + j])) for j in range(k))
            nodes.append(WeightedSum(
                id=nid, terms=terms, layer=int(a["sum_layer"][si])))
            si += 1
            ti += k
        elif kind == 2:
            nodes.append(SignStep(id=nid, src=int(a["step_src"][pi])))
            pi += 1
        elif kind == 3:
            k = int(a["argmax_nsrcs"][ai])
            nodes.append(Argmax(id=nid, srcs=tuple(
                int(s) for s in a["argmax_srcs"][aj:aj + k])))
            ai += 1
            aj += k
        else:
            raise ValueError(f"unknown node kind code {kind}")
    circuit = Circuit(n_inputs=n_inputs, input_threshold=input_threshold,
                      nodes=tuple(nodes), output=output)
    circuit.validate()
    return circuit


# ---------------------------------------------------------------------------
# Reference interpreter (the semantic arbiter for every backend)
# ---------------------------------------------------------------------------

def evaluate(
    circuit: Circuit,
    x_uint8: np.ndarray,
    *,
    step_semantics: str = "strict",
    check_widths: bool = False,
) -> np.ndarray:
    """Execute the circuit on a batch of uint8 inputs (B, n_inputs).

    step_semantics: "strict" — step fires on `acc > 0` (the arithmetic the
    compiled jnp/pallas backends and `quantize.predict_l3` implement);
    "msb" — step is `~acc[msb]`, i.e. fires on `acc >= 0` (the emitted
    Verilog's §V.D MSB trick). check_widths asserts every accumulator
    stays inside its inferred signed bit-width.
    """
    if step_semantics not in ("strict", "msb"):
        raise ValueError(f"unknown step_semantics {step_semantics!r}")
    x = np.asarray(x_uint8)
    if x.ndim != 2 or x.shape[1] != circuit.n_inputs:
        raise ValueError(f"expected (B, {circuit.n_inputs}), got {x.shape}")
    widths = node_widths(circuit) if check_widths else None

    vals: dict[NodeId, np.ndarray] = {}
    out = None
    for n in circuit.nodes:
        if isinstance(n, InputCompare):
            vals[n.id] = (x[:, n.pixel].astype(np.int64) > n.threshold).astype(np.int64)
        elif isinstance(n, WeightedSum):
            acc = np.zeros(x.shape[0], dtype=np.int64)
            for t in n.terms:
                acc += t.weight * vals[t.src]
            if widths is not None:
                lim = 2 ** (widths[n.id] - 1)
                assert np.all(acc >= -lim) and np.all(acc < lim), (
                    f"sum node {n.id} overflows its {widths[n.id]}-bit width")
            vals[n.id] = acc
        elif isinstance(n, SignStep):
            v = vals[n.src]
            vals[n.id] = (v > 0 if step_semantics == "strict" else v >= 0).astype(np.int64)
        elif isinstance(n, Argmax):
            stacked = np.stack([vals[s] for s in n.srcs], axis=1)
            out = vals[n.id] = np.argmax(stacked, axis=1)
    if out is None:
        raise ValueError("circuit has no Argmax output node")
    return vals[circuit.output]
