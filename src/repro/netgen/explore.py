"""Joint design-space explorer: pipeline x datapath x tile sizes as ONE
optimization problem.

The paper's core claim is that the *combination* of optimizations buys
inference speed — yet the stack historically tuned each lever in
isolation: `KernelTuner` grid-searched tile sizes under a fixed
pipeline, the `cost` target priced Fig-7 logic cells after the fact,
and `PipelineSpec` exposed pass selection and CSE budgets nobody
searched over. This module closes the loop (ROADMAP item 2), in the
spirit of the FPGA DSE literature where accelerator design IS a joint
knob sweep:

  SearchSpace — the candidate axes: pipeline spec strings (pass
      selection, CSE budget/bucketing), plan form / datapath (dense /
      packed / planes / fusednet), kernel tile sizes (bm, bn, bkw),
      and optionally several nets at once (the ladder-depth sweep:
      accuracy-vs-cells across net depths). The cartesian product is
      the space; strategies sample it.

  Explorer — the seeded, deterministic search driver. Strategies:
      "random" (a seeded permutation of the product, first `budget`
      unique candidates) and "anneal" (simulated annealing: one-axis
      neighbor moves, relative-delta Metropolis acceptance, geometric
      temperature decay). Candidates are pruned BEFORE any measurement
      by the shared legality machinery: a pipeline whose optimized
      circuit has no layer-structured ExecutionPlan
      (`IrregularCircuitError` — CSE'd sharing) cannot back a
      predictor, and tile candidates go through
      `repro.netgen.analysis.tile_legality` (non-positive blocks,
      fusednet VMEM residency over budget, clamp-duplicates). Every
      measured candidate is compiled through `Session.compile`, so
      artifacts land in the `ArtifactStore` and a re-evaluated
      configuration never recompiles.

  Objective — pluggable, lower-is-better: "latency" (measured wall
      clock of the compiled predictor on a fixed batch, best-of-reps),
      "cells" (the Fig-7 logic-cell estimate every Artifact carries —
      fully deterministic, and the only objective that admits
      irregular/CSE'd pipelines, which the FPGA flow can still emit),
      "combined" (us + cells_weight * cells), or any callable over the
      per-candidate `Evaluation` via `make_objective`.

  ExplorationReport — per-candidate objective values, the acceptance
      trace, the prune log with reasons, and the winner as a
      `(PipelineSpec, target)` pair ready for `Session.compile`.

Persistence mirrors the autotuner: the whole search result (winner +
measurement table + trace) is one content-addressed `TuneRecord`
(keyed on net digests, space, objective, strategy, budget, seed,
device kind) written through `KernelTuner.get_or_run`, so a second
process with the same `TuneStore` replays the exploration with ZERO
measurements — and, because artifacts persisted too, zero compiles.
The winner's datapath additionally publishes under the
`pallas-explored` key (`backends.pallas.publish_explored`), which is
what `pallas[explored=true]` — and the serving layer's stacked
dispatch — resolve per plan signature.

Telemetry (scope per explorer): `netgen_explore_candidates_total` ==
`..._pruned_total` + `..._measured_total`, and every measured
candidate backs exactly one artifact (`..._artifacts_total`) — the
identities `benchmarks/check_trace.py` gates CI on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping

import numpy as np

from repro.netgen import telemetry
from repro.netgen.graph import IrregularCircuitError
from repro.netgen.pipeline import PipelineSpec
from repro.netgen.plan import lower_circuit
from repro.netgen.targets import resolve_target, target_string

__all__ = [
    "Candidate", "Evaluation", "ExplorationReport", "Explorer",
    "Objective", "SearchSpace", "make_objective",
]

_STRATEGIES = ("random", "anneal")

# Default pipeline axis: the executable ladder (prune only; prune +
# selected addends) plus CSE'd variants — which only the cells
# objective can evaluate (no ExecutionPlan lowers from shared
# sub-circuits; predictor objectives prune them with the reason).
_DEFAULT_PIPELINES = (
    "default",                               # zeros,prune
    "zeros,prune,addends",
    "zeros,prune,addends,cse[bucketed=true]",
)
_DEFAULT_FORMS = ("dense", "packed", "planes", "fusednet")
_DEFAULT_TILES = (
    {"bm": 128, "bn": 128, "bkw": 8},
    {"bm": 128, "bn": 128, "bkw": 16},
    {"bm": 64, "bn": 128, "bkw": 8},
    {"bm": 128, "bn": 64, "bkw": 8},
)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the joint space. `net` names an entry of the
    explorer's nets mapping (the ladder-depth axis; "net" for the
    common single-net case)."""
    pipeline: str
    form: str
    bm: int
    bn: int
    bkw: int
    net: str = "net"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def target(self, *, interpret=None) -> str:
        """The canonical pallas target string this candidate compiles
        under (form pinned via its flag, blocks pinned explicitly)."""
        opts: dict = {"bm": self.bm, "bn": self.bn, "bkw": self.bkw}
        if self.form != "dense":
            opts[self.form] = True
        if interpret is not None:
            opts["interpret"] = interpret
        tgt, opts = resolve_target("pallas", opts)
        return target_string(tgt, opts)

    @classmethod
    def from_dict(cls, d: Mapping) -> "Candidate":
        return cls(pipeline=d["pipeline"], form=d["form"], bm=int(d["bm"]),
                   bn=int(d["bn"]), bkw=int(d["bkw"]),
                   net=d.get("net", "net"))


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The candidate axes (see module doc). `pipelines` are spec
    strings `PipelineSpec.coerce` accepts; `tiles` are bm/bn/bkw dicts;
    `nets` are names into the explorer's nets mapping."""
    pipelines: tuple = _DEFAULT_PIPELINES
    forms: tuple = _DEFAULT_FORMS
    tiles: tuple = _DEFAULT_TILES
    nets: tuple = ("net",)

    def __post_init__(self):
        if not (self.pipelines and self.forms and self.tiles and self.nets):
            raise ValueError("every SearchSpace axis needs >= 1 entry")
        for form in self.forms:
            if form not in _DEFAULT_FORMS:
                raise ValueError(f"unknown datapath form {form!r} "
                                 f"(expected one of {_DEFAULT_FORMS})")

    def candidates(self) -> list[Candidate]:
        """The full cartesian product, canonical order (net, pipeline,
        form, tiles) — the order strategies permute deterministically."""
        out = []
        for net in self.nets:
            for pipe in self.pipelines:
                spec = PipelineSpec.coerce(pipe).spec_string()
                for form in self.forms:
                    for tile in self.tiles:
                        out.append(Candidate(
                            pipeline=spec, form=form, bm=int(tile["bm"]),
                            bn=int(tile["bn"]), bkw=int(tile["bkw"]),
                            net=net))
        return out

    def as_fields(self) -> dict:
        """JSON-stable identity for the exploration record key."""
        return {
            "pipelines": [PipelineSpec.coerce(p).spec_string()
                          for p in self.pipelines],
            "forms": list(self.forms),
            "tiles": [dict(t) for t in self.tiles],
            "nets": list(self.nets),
        }


@dataclasses.dataclass
class Evaluation:
    """What one measured candidate produced — the objective callable's
    input. `us` is None unless the objective declared needs_latency;
    `artifact` is the compiled predictor Artifact (or the cost-report
    Artifact for non-predictor objectives)."""
    candidate: Candidate
    cells: int
    us: float | None
    artifact: object


@dataclasses.dataclass(frozen=True)
class Objective:
    """Lower-is-better scoring of an Evaluation. `needs_predictor`
    prunes irregular (CSE'd) pipelines pre-measurement and enforces
    tile legality; `needs_latency` additionally times the predictor."""
    name: str
    fn: Callable[[Evaluation], float]
    needs_predictor: bool = True
    needs_latency: bool = True


def make_objective(fn: Callable[[Evaluation], float], *, name: str,
                   needs_predictor: bool = True,
                   needs_latency: bool = True) -> Objective:
    """Wrap a callable objective. `name` is part of the exploration
    record's content address — it must identify the scoring semantics
    (two different callables under one name would replay each other's
    records)."""
    return Objective(name=name, fn=fn, needs_predictor=needs_predictor,
                     needs_latency=needs_latency)


def _resolve_objective(objective, cells_weight: float) -> Objective:
    if isinstance(objective, Objective):
        return objective
    if callable(objective):
        name = getattr(objective, "__name__", None)
        if not name or name == "<lambda>":
            raise ValueError(
                "callable objectives need a stable name — use "
                "make_objective(fn, name=...)")
        return make_objective(objective, name=name)
    if objective == "latency":
        return Objective("latency", lambda ev: float(ev.us))
    if objective == "cells":
        return Objective("cells", lambda ev: float(ev.cells),
                         needs_predictor=False, needs_latency=False)
    if objective == "combined":
        return Objective(
            f"combined[cells_weight={cells_weight}]",
            lambda ev: float(ev.us) + cells_weight * float(ev.cells))
    raise ValueError(f"unknown objective {objective!r} (expected "
                     f"'latency', 'cells', 'combined', or an Objective)")


@dataclasses.dataclass
class ExplorationReport:
    """The search result, replayable from its persisted record.
    `evaluations` is the ((candidate dict, value), ...) table in search
    order; `trace` the per-step acceptance log; `pruned` the
    ((candidate dict, reason), ...) rejections; `source` says whether
    this process searched ("search") or replayed ("memory"/"store")."""
    best: Candidate
    best_value: float
    objective: str
    strategy: str
    budget: int
    seed: int
    evaluations: tuple
    trace: tuple
    pruned: tuple
    source: str
    key: str
    device_kind: str

    @property
    def candidates(self) -> int:
        return len(self.evaluations) + len(self.pruned)

    def best_config(self) -> tuple[PipelineSpec, str]:
        """The winner as the `(PipelineSpec, target)` pair
        `Session.compile(net, target=t, pipeline=spec)` takes — the
        spec object plus the canonical pallas target string with the
        winning form and tile sizes pinned."""
        return (PipelineSpec.coerce(self.best.pipeline),
                self.best.target())

    def as_dict(self) -> dict:
        return {
            "best": self.best.as_dict(),
            "best_value": self.best_value,
            "objective": self.objective,
            "strategy": self.strategy,
            "budget": self.budget,
            "seed": self.seed,
            "evaluations": [[c, v] for c, v in self.evaluations],
            "trace": [dict(t) for t in self.trace],
            "pruned": [[c, r] for c, r in self.pruned],
            "source": self.source,
            "key": self.key,
            "device_kind": self.device_kind,
        }

    def describe(self) -> str:
        spec, tgt = self.best_config()
        return (f"explore[{self.strategy}/{self.objective}] "
                f"{self.candidates} candidates ({len(self.pruned)} pruned, "
                f"{len(self.evaluations)} measured, source={self.source}) "
                f"-> {tgt} under '{spec.spec_string()}' "
                f"(value {self.best_value:.3f})")


class _Base:
    """Per-(net, pipeline) evaluation context, built lazily ONCE: the
    optimized circuit (via the session's cost target — an Artifact, so
    it lands in the store), its cells, and the lowered plan or the
    irregularity reason. The tile-legality closure is stateful on
    purpose: clamp-duplicate detection spans all candidates that share
    this plan."""

    def __init__(self, session, net, pipeline: str, batch: int,
                 input_threshold):
        from repro.netgen.analysis import tile_legality

        self.artifact = session.compile(
            net, target="cost", pipeline=pipeline,
            input_threshold=input_threshold)
        self.cells = int(self.artifact.cost.total)
        self.plan = None
        self.irregular: str | None = None
        try:
            self.plan = lower_circuit(self.artifact.circuit)
            self._legal = tile_legality(self.plan, batch=batch)
        except IrregularCircuitError as e:
            self.irregular = f"no ExecutionPlan for this pipeline: {e}"

    def tile_reason(self, cand: Candidate) -> str | None:
        if self.irregular is not None:
            return self.irregular
        return self._legal({"form": cand.form, "bm": cand.bm,
                            "bn": cand.bn, "bkw": cand.bkw})


class Explorer:
    """The seeded joint-search driver (see module doc). Construct with
    a `Session` (its store/tuner give the zero-compile/zero-measurement
    replay) and run(); or use `Session.explore(...)`."""

    def __init__(self, session, *, net=None, nets: Mapping | None = None,
                 space: SearchSpace | None = None, objective="latency",
                 strategy: str = "anneal", budget: int = 24, seed: int = 0,
                 batch: int = 256, reps: int = 2, cells_weight: float = 0.01,
                 interpret: bool | None = None, input_threshold=None):
        from repro.core.quantize import weights_digest
        from repro.netgen.frontend import _extract_weights
        from repro.netgen.tune import default_tuner

        if (net is None) == (nets is None):
            raise ValueError("pass net= or nets=, not both / neither")
        self.session = session
        self.nets = dict(nets) if nets is not None else {"net": net}
        self.space = space if space is not None else SearchSpace(
            nets=tuple(self.nets))
        missing = [n for n in self.space.nets if n not in self.nets]
        if missing:
            raise ValueError(f"space names unknown nets: {missing}")
        if strategy not in _STRATEGIES:
            raise ValueError(f"unknown strategy {strategy!r} "
                             f"(expected one of {_STRATEGIES})")
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.objective = _resolve_objective(objective, cells_weight)
        self.strategy = strategy
        self.budget = int(budget)
        self.seed = int(seed)
        self.batch = int(batch)
        self.reps = max(1, int(reps))
        self.interpret = interpret
        self.input_threshold = input_threshold
        self.tuner = session.tuner if session.tuner is not None \
            else default_tuner()
        # content identity of each net (compile-free)
        self._digests = {}
        for name in self.space.nets:
            ws, thr = _extract_weights(self.nets[name], input_threshold)
            self._digests[name] = weights_digest(ws, thr)
        self._bases: dict[tuple, _Base] = {}
        self._tel = telemetry.get_registry()
        self._scope = telemetry.new_scope("explorer")
        mk = lambda n: self._tel.counter(n, explorer=self._scope)  # noqa: E731
        self._c_candidates = mk("netgen_explore_candidates_total")
        self._c_pruned = mk("netgen_explore_pruned_total")
        self._c_measured = mk("netgen_explore_measured_total")
        self._c_accepted = mk("netgen_explore_accepted_total")
        self._c_artifacts = mk("netgen_explore_artifacts_total")
        self._c_replays = mk("netgen_explore_replays_total")

    # -- evaluation ----------------------------------------------------------

    def _base(self, cand: Candidate) -> _Base:
        key = (cand.net, cand.pipeline)
        base = self._bases.get(key)
        if base is None:
            base = _Base(self.session, self.nets[cand.net], cand.pipeline,
                         self.batch, self.input_threshold)
            self._bases[key] = base
        return base

    def _prune_reason(self, cand: Candidate, base: _Base) -> str | None:
        """Pre-measurement legality through the shared analysis checks.
        Objectives that never build a predictor (cells) skip both — an
        irregular circuit still has a cell price and tile sizes are
        moot — but still dedupe identical evaluations."""
        if self.objective.needs_predictor:
            return base.tile_reason(cand)
        # cells-only: every candidate of one (net, pipeline) evaluates
        # to the same number; measuring it once is enough
        first = getattr(base, "_cells_claimed", None)
        if first is not None and first != cand:
            return (f"same cells evaluation as {first.as_dict()} — "
                    f"datapath/tiles do not move the cells objective")
        base._cells_claimed = cand
        return None

    def _measure_us(self, artifact, n_inputs: int) -> float:
        import time

        x = np.zeros((self.batch, n_inputs), np.uint8)
        np.asarray(artifact(x))                  # warmup (trace/compile)
        best = math.inf
        for _ in range(self.reps):
            t0 = time.perf_counter()
            np.asarray(artifact(x))
            best = min(best, time.perf_counter() - t0)
        return best * 1e6

    def _evaluate(self, cand: Candidate, base: _Base) -> float:
        """Objective value for one legal candidate. The compile flows
        through `Session.compile` (memory tier -> ArtifactStore ->
        compile_resolved), so re-evaluations and warm processes never
        rebuild."""
        artifact = base.artifact
        us = None
        if self.objective.needs_predictor:
            artifact = self.session.compile(
                self.nets[cand.net], target=cand.target(
                    interpret=self.interpret),
                pipeline=cand.pipeline,
                input_threshold=self.input_threshold)
            if self.objective.needs_latency:
                us = self._measure_us(artifact, artifact.circuit.n_inputs)
        value = float(self.objective.fn(Evaluation(
            candidate=cand, cells=base.cells, us=us, artifact=artifact)))
        if not math.isfinite(value):
            raise ValueError(
                f"objective {self.objective.name!r} returned {value!r} "
                f"for {cand.as_dict()}")
        return value

    def _consider(self, cand: Candidate, state: dict):
        """Evaluate one not-yet-seen candidate: returns (value, reason)
        with exactly one of the two set, and keeps every counter
        identity (candidates == pruned + measured; artifacts ==
        measured) exact."""
        self._c_candidates.inc()
        base = self._base(cand)
        reason = self._prune_reason(cand, base)
        if reason is None:
            try:
                value = self._evaluate(cand, base)
            except (IrregularCircuitError, ValueError) as e:
                reason = f"build failed: {e}"
        if reason is not None:
            self._c_pruned.inc()
            state["pruned"].append((cand.as_dict(), reason))
            state["values"][cand] = (math.inf, reason)
            return math.inf, reason
        self._c_measured.inc()
        self._c_artifacts.inc()          # the artifact backing this value
        state["evals"].append((cand.as_dict(), value))
        state["values"][cand] = (value, None)
        return value, None

    # -- strategies ----------------------------------------------------------

    def _search(self) -> dict:
        rng = np.random.default_rng(self.seed)
        pool = self.space.candidates()
        state: dict = {"evals": [], "pruned": [], "values": {}, "trace": []}
        if self.strategy == "random":
            self._random(rng, pool, state)
        else:
            self._anneal(rng, pool, state)
        if not state["evals"]:
            first = state["pruned"][0][1] if state["pruned"] else "no steps"
            raise ValueError(
                f"exploration measured nothing within budget "
                f"{self.budget} (first prune: {first})")
        return state

    def _trace(self, state, step, cand, value, reason, accepted, best):
        state["trace"].append({
            "step": step, "candidate": cand.as_dict(),
            "value": None if reason is not None else value,
            "pruned": reason, "accepted": bool(accepted),
            "best": None if not math.isfinite(best) else best})
        if accepted:
            self._c_accepted.inc()

    def _random(self, rng, pool, state) -> None:
        """Seeded permutation of the product; first `budget` candidates.
        Acceptance == new incumbent."""
        best = math.inf
        order = rng.permutation(len(pool))
        for step, idx in enumerate(order[:self.budget]):
            cand = pool[idx]
            value, reason = self._consider(cand, state)
            accepted = reason is None and value < best
            best = min(best, value)
            self._trace(state, step, cand, value, reason, accepted, best)

    def _anneal(self, rng, pool, state) -> None:
        """Simulated annealing over the joint space: neighbor = one axis
        re-drawn; Metropolis acceptance on the RELATIVE objective delta
        (latency us and logic cells live on different scales);
        geometric cooling sized to the budget. A pruned proposal spends
        budget (it was considered) but never moves the state."""
        t0, t_end = 0.25, 0.01
        alpha = (t_end / t0) ** (1.0 / max(1, self.budget - 1))
        axes = ("pipeline", "form", "tiles", "net")
        cur = pool[int(rng.integers(len(pool)))]
        cur_v, reason = self._consider(cur, state)
        best = cur_v if reason is None else math.inf
        self._trace(state, 0, cur, cur_v, reason, reason is None, best)
        if reason is not None:
            cur = None                   # no incumbent yet
        temp = t0
        steps, proposals = 1, 0
        while steps < self.budget and proposals < self.budget * 32:
            proposals += 1
            temp *= alpha
            if cur is None:
                cand = pool[int(rng.integers(len(pool)))]
            else:
                cand = self._neighbor(cur, rng)
            prior = state["values"].get(cand)
            if prior is not None:
                # revisit: no budget spent, but an accepted re-walk is
                # a real state move
                value, reason = prior
                if reason is None and cur is not None \
                        and self._accept(value, cur_v, temp, rng):
                    cur, cur_v = cand, value
                continue
            value, reason = self._consider(cand, state)
            accepted = False
            if reason is None:
                if cur is None or self._accept(value, cur_v, temp, rng):
                    accepted = True
                    cur, cur_v = cand, value
            best = min(best, value if reason is None else math.inf)
            self._trace(state, steps, cand, value, reason, accepted, best)
            steps += 1

    def _accept(self, value: float, cur_v: float, temp: float, rng) -> bool:
        if value <= cur_v:
            return True
        rel = (value - cur_v) / max(abs(cur_v), 1e-9)
        return bool(rng.random() < math.exp(-rel / max(temp, 1e-9)))

    def _neighbor(self, cand: Candidate, rng) -> Candidate:
        axis = ("pipeline", "form", "tiles", "net")[int(rng.integers(4))]
        d = cand.as_dict()
        if axis == "pipeline":
            d["pipeline"] = PipelineSpec.coerce(self.space.pipelines[
                int(rng.integers(len(self.space.pipelines)))]).spec_string()
        elif axis == "form":
            d["form"] = self.space.forms[
                int(rng.integers(len(self.space.forms)))]
        elif axis == "net":
            d["net"] = self.space.nets[
                int(rng.integers(len(self.space.nets)))]
        else:
            d.update(self.space.tiles[
                int(rng.integers(len(self.space.tiles)))])
        return Candidate.from_dict(d)

    # -- the persisted problem ----------------------------------------------

    def key_fields(self) -> dict:
        import jax

        return {
            "target": "netgen-explore",
            "device_kind": jax.devices()[0].device_kind,
            "interpret": self.interpret,
            "digests": self._digests,
            "space": self.space.as_fields(),
            "objective": self.objective.name,
            "strategy": self.strategy,
            "budget": self.budget,
            "seed": self.seed,
            "batch": self.batch,
            "reps": self.reps,
        }

    def run(self) -> ExplorationReport:
        """Search (or replay the persisted search) and return the
        report. Fresh searches publish the winner's datapath under the
        `pallas-explored` key so `pallas[explored=true]` and the
        serving layer resolve it by plan signature."""
        import jax

        fields = self.key_fields()

        def _run(key: str):
            with self._tel.span(
                    "netgen.explore", explorer=self._scope,
                    strategy=self.strategy, objective=self.objective.name,
                    budget=self.budget, seed=self.seed) as sp:
                state = self._search()
                best_cand, best_value = min(
                    ((Candidate.from_dict(c), v) for c, v in state["evals"]),
                    key=lambda t: t[1])
                sp.set_attr("best", best_cand.as_dict())
                sp.set_attr("pruned", len(state["pruned"]))
                sp.set_attr("measured", len(state["evals"]))
            self._publish(best_cand, best_value, key)
            extra = {
                "trace": state["trace"],
                "pruned": [[c, r] for c, r in state["pruned"]],
                "objective": self.objective.name,
                "strategy": self.strategy,
                "budget": self.budget,
                "seed": self.seed,
            }
            return ({**best_cand.as_dict(), "value": best_value},
                    state["evals"], extra)

        rec, tier = self.tuner.get_or_run(fields, _run)
        if tier != "run":
            self._c_replays.inc()
        best = Candidate.from_dict(rec.best)
        return ExplorationReport(
            best=best,
            best_value=float(rec.best["value"]),
            objective=rec.extra.get("objective", self.objective.name),
            strategy=rec.extra.get("strategy", self.strategy),
            budget=int(rec.extra.get("budget", self.budget)),
            seed=int(rec.extra.get("seed", self.seed)),
            evaluations=tuple((dict(c), float(v))
                              for c, v in rec.measurements),
            trace=tuple(dict(t) for t in rec.extra.get("trace", ())),
            pruned=tuple((dict(c), r)
                         for c, r in rec.extra.get("pruned", ())),
            source="search" if tier == "run" else tier,
            key=rec.key,
            device_kind=jax.devices()[0].device_kind,
        )

    def _publish(self, best: Candidate, value: float, key: str) -> None:
        """Winner -> `pallas-explored` datapath record (plan-signature
        keyed), unless the winning pipeline has no plan (a cells-only
        winner may be irregular — nothing executable to publish)."""
        from repro.netgen.backends.pallas import publish_explored

        base = self._bases[(best.net, best.pipeline)]
        if base.plan is None:
            return
        publish_explored(
            base.plan, self.tuner,
            {"form": best.form, "bm": best.bm, "bn": best.bn,
             "bkw": best.bkw},
            interpret=self.interpret,
            measurements=[({k: v for k, v in best.as_dict().items()},
                           value)],
            extra={"explore_key": key, "pipeline": best.pipeline,
                   "objective": self.objective.name})
