"""Persistent kernel autotuner: search tile parameters once, reuse forever.

Guo et al.'s FPGA-accelerator survey frames the lesson this module
operationalizes: tile/loop parameters must be *searched per workload*,
not hard-coded. The pallas kernels' block sizes (`bm`, `bn`, `bkw`) and
the datapath form (dense / packed / bit-plane) interact with the plan
shape and the device, so `pallas[tuned=true]` grid-searches them —
and, because the search is pure measurement over content-addressed
inputs, the winner is persisted so it is NEVER re-measured:

  KernelTuner — the search driver. `get_or_tune(key_fields, candidates,
      measure)` consults an in-memory dict, then the persistent
      `TuneStore`, and only on a double miss times each candidate
      (best-of-`reps` wall clock) and records the winner. `stats`
      counts hits / store hits / tunes / individual measurements, so a
      warm-started process can assert it measured NOTHING.

  TuneStore — one JSON file per record under a directory, addressed by
      sha256 over the canonical key fields (tune format version, target,
      device kind, plan signature, candidate grid). Writes are atomic
      (temp file + rename) so concurrent processes share a store the
      same way they share an `ArtifactStore`; corrupt entries degrade
      to a re-tune, never a failure. CI caches this directory alongside
      `.netgen-store`.

  TuneRecord — the persisted artifact: the winning parameter dict plus
      every (candidate, microseconds) measurement, so a benchmark (or a
      curious human) can see the whole search surface, not just the
      argmin.

The tuner is deliberately backend-agnostic: `backends/pallas.py` builds
the candidate list and the measure closure; this module only owns
keying, persistence, and the search loop. `Session(tune_store=...)`
threads a shared tuner through compiles, artifact-store reloads, and
the `NetServer`'s stacked dispatch; without one, a process-wide
in-memory tuner (`default_tuner`) keeps `tuned=true` working, just
without cross-process reuse.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
import uuid
from pathlib import Path
from typing import Callable, Mapping, Sequence

from repro.netgen import telemetry

__all__ = [
    "KernelTuner", "TuneRecord", "TuneStats", "TuneStore", "default_tuner",
    "tune_key",
]

_FORMAT = "netgen-tune-v1"


def tune_key(key_fields) -> str:
    """Content address of one tuning problem: sha256 over the canonical
    JSON of (format, *key_fields). Every field must be JSON-stable —
    shapes and names, not arrays — so the same problem keys identically
    across processes and machines of the same device kind."""
    blob = json.dumps([_FORMAT, key_fields], sort_keys=True,
                      separators=(",", ":"), default=_jsonify)
    return hashlib.sha256(blob.encode()).hexdigest()


def _jsonify(obj):
    if isinstance(obj, tuple):
        return list(obj)
    raise TypeError(f"tune key field {obj!r} is not JSON-stable")


@dataclasses.dataclass(frozen=True)
class TuneRecord:
    """One persisted search result: the problem's content address, the
    winning parameters, and the full measurement table (each candidate's
    best-of-reps wall clock in microseconds, search order preserved).

    `extra` carries driver-specific payload beyond the argmin — the
    design-space explorer stores its acceptance trace and prune log
    there so a warm start replays the whole report, not just the
    winner. Pre-`extra` records load with an empty dict."""
    key: str
    best: dict
    measurements: tuple          # ((params_dict, value), ...)
    device_kind: str
    created_unix: float
    extra: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return {
            "format": _FORMAT,
            "key": self.key,
            "best": self.best,
            "measurements": [[p, us] for p, us in self.measurements],
            "device_kind": self.device_kind,
            "created_unix": self.created_unix,
            "extra": self.extra,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TuneRecord":
        return cls(
            key=d["key"],
            best=dict(d["best"]),
            measurements=tuple((dict(p), float(us))
                               for p, us in d["measurements"]),
            device_kind=d["device_kind"],
            created_unix=float(d["created_unix"]),
            extra=dict(d.get("extra") or {}),
        )


@dataclasses.dataclass
class TuneStats:
    """Point-in-time snapshot of one tuner's telemetry counters (the
    live values are atomic `telemetry.Counter`s under the tuner's
    scope; `KernelTuner.stats` builds this)."""
    hits: int = 0              # in-memory record reuse
    store_hits: int = 0        # records loaded from the persistent store
    tunes: int = 0             # full searches actually performed
    measurements: int = 0      # individual candidate timings taken
    rejected: int = 0          # candidates statically rejected, unmeasured
    measure_seconds: float = 0.0

    def row(self) -> str:
        return (f"tune: {self.hits} hits, {self.store_hits} store hits, "
                f"{self.tunes} tunes ({self.measurements} measurements, "
                f"{self.rejected} rejected, "
                f"{self.measure_seconds * 1e3:.1f} ms measuring)")


class TuneStore:
    """On-disk tuning records: `<root>/<key>.json`, atomic writes, a
    corrupt or stale-format entry reads as a miss and is evicted (a
    tuning cache must degrade to a re-tune, never fail the compile)."""

    def __init__(self, root):
        self.root = Path(root).expanduser()
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: str) -> Path:
        return self.root / f"{key}.json"

    def __contains__(self, key: str) -> bool:
        return self._path(key).exists()

    def keys(self) -> list[str]:
        return sorted(p.stem for p in self.root.glob("*.json"))

    def get(self, key: str) -> TuneRecord | None:
        path = self._path(key)
        try:
            with open(path) as f:
                d = json.load(f)
            if d.get("format") != _FORMAT or d.get("key") != key:
                raise ValueError(f"stale tune record {key}")
            return TuneRecord.from_dict(d)
        except FileNotFoundError:
            return None
        except Exception:
            try:
                os.unlink(path)
            except OSError:
                pass
            return None

    def put(self, record: TuneRecord) -> None:
        tmp = self.root / f".tmp-{record.key[:16]}-{uuid.uuid4().hex[:8]}"
        try:
            with open(tmp, "w") as f:
                json.dump(record.as_dict(), f, indent=1)
            os.replace(tmp, self._path(record.key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


class KernelTuner:
    """Two-tier tuning cache + the grid-search driver (see module doc).

    Thread-safe: a tuner-wide lock guards the record tiers and stats,
    while searches measure under a per-key lock — concurrent callers of
    the same key search once, and a long search for one shape never
    blocks lookups or searches for other shapes.
    """

    def __init__(self, store: TuneStore | None = None):
        if store is not None and not isinstance(store, TuneStore):
            store = TuneStore(store)
        self.store = store
        self._mem: dict[str, TuneRecord] = {}
        self._lock = threading.RLock()
        self._inflight: dict[str, threading.Lock] = {}   # per-key searches
        self._tel = telemetry.get_registry()
        scope = telemetry.new_scope("tuner")
        self._c_hits = self._tel.counter(
            "netgen_tune_hits_total", tuner=scope)
        self._c_store_hits = self._tel.counter(
            "netgen_tune_store_hits_total", tuner=scope)
        self._c_tunes = self._tel.counter(
            "netgen_tune_searches_total", tuner=scope)
        self._c_measurements = self._tel.counter(
            "netgen_tune_measurements_total", tuner=scope)
        self._c_rejected = self._tel.counter(
            "netgen_tune_rejected_total", tuner=scope)
        self._h_measure = self._tel.histogram(
            "netgen_tune_measure_seconds", tuner=scope)

    @property
    def stats(self) -> TuneStats:
        """Snapshot of the tuner's counters (atomic; safe to read while
        other threads search)."""
        return TuneStats(
            hits=int(self._c_hits.value),
            store_hits=int(self._c_store_hits.value),
            tunes=int(self._c_tunes.value),
            measurements=int(self._c_measurements.value),
            rejected=int(self._c_rejected.value),
            measure_seconds=float(self._h_measure.sum))

    def record_for(self, key: str) -> TuneRecord | None:
        """The resident (memory or store) record under `key`, without
        triggering a search; counts no hit/miss."""
        with self._lock:
            rec = self._mem.get(key)
        if rec is None and self.store is not None:
            rec = self.store.get(key)
        return rec

    def _lookup(self, key: str) -> tuple[TuneRecord | None, str]:
        """(record, tier) under the tuner lock; counts the hit. Tier is
        "memory", "store", or "" on a double miss."""
        rec = self._mem.get(key)
        if rec is not None:
            self._c_hits.inc()
            return rec, "memory"
        if self.store is not None:
            rec = self.store.get(key)
            if rec is not None:
                self._mem[key] = rec
                self._c_store_hits.inc()
                return rec, "store"
        return None, ""

    def get_or_run(self, key_fields,
                   run: Callable[[str], tuple[Mapping, Sequence, Mapping]],
                   ) -> tuple[TuneRecord, str]:
        """Content-addressed caller-driven search: the generalization of
        `get_or_tune` for drivers that own their OWN search loop (the
        design-space explorer). Returns `(record, tier)` where tier is
        "memory", "store", or "run".

        On a double miss the per-key in-flight lock is taken and
        `run(key)` performs the search, returning `(best, measurements,
        extra)` — the winning params dict, the ((params, value), ...)
        table, and a JSON-stable payload stored on the record. The
        driver's measurement count rides the shared
        `netgen_tune_measurements_total` counter (one per table row), so
        `TuneStats.measurements == 0` still certifies a warm start."""
        key = tune_key(key_fields)
        with self._lock:
            rec, tier = self._lookup(key)
            if rec is not None:
                return rec, tier
            key_lock = self._inflight.setdefault(key, threading.Lock())
        with key_lock:
            with self._lock:
                rec, tier = self._lookup(key)
            if rec is not None:
                return rec, tier
            t0 = time.perf_counter()
            best, measurements, extra = run(key)
            dt = time.perf_counter() - t0
            rec = TuneRecord(
                key=key,
                best=dict(best),
                measurements=tuple((dict(p), float(v))
                                   for p, v in measurements),
                device_kind=_field(key_fields, "device_kind"),
                created_unix=time.time(),
                extra=dict(extra),
            )
            self._c_measurements.inc(len(rec.measurements))
            self._c_tunes.inc()
            self._h_measure.observe(dt)
            with self._lock:
                self._mem[key] = rec
                self._inflight.pop(key, None)
            if self.store is not None:
                self.store.put(rec)
            return rec, "run"

    def publish(self, key_fields, best: Mapping, *,
                measurements: Sequence = (), extra: Mapping | None = None,
                ) -> TuneRecord:
        """Unconditionally upsert a record for this problem — no search,
        no measurement counters. The design-space explorer publishes its
        winning datapath under the `pallas-explored` key this way: a
        re-exploration with a different objective may legitimately
        REPLACE the resident winner (unlike `get_or_tune`/`get_or_run`
        records, which are immutable functions of their key)."""
        key = tune_key(key_fields)
        rec = TuneRecord(
            key=key,
            best=dict(best),
            measurements=tuple((dict(p), float(v)) for p, v in measurements),
            device_kind=_field(key_fields, "device_kind"),
            created_unix=time.time(),
            extra=dict(extra or {}),
        )
        with self._lock:
            self._mem[key] = rec
        if self.store is not None:
            self.store.put(rec)
        return rec

    def get_or_tune(self, key_fields, candidates: Sequence[Mapping],
                    measure: Callable[[Mapping], float], *,
                    reps: int = 2,
                    legal: Callable[[Mapping], str | None] | None = None,
                    ) -> dict:
        """The winning parameter dict for this problem — from memory,
        then the store, then by timing every candidate.

        `key_fields` is the JSON-stable problem identity (target, device
        kind, plan signature, the candidate grid itself — so a changed
        grid re-tunes instead of serving a winner the new grid cannot
        express). `measure(params)` runs one candidate once and returns
        its wall-clock seconds; the driver takes best-of-`reps` after
        one untimed warmup call (jit tracing must not pollute the
        measurement).

        `legal(params)`, when given, is a static legality check (see
        `repro.netgen.analysis.tile_legality`): it returns None for a
        candidate worth measuring or a reason string for one that is
        statically illegal / a duplicate kernel launch — rejected
        candidates are skipped without spending a measurement and
        counted in `netgen_tune_rejected_total`. The problem key is
        computed over the FULL declared grid either way, so adding a
        legality filter does not invalidate persisted records. All
        candidates rejected is an error (the grid cannot express a
        launchable kernel).
        """
        if not candidates:
            raise ValueError("no tuning candidates")
        key = tune_key(key_fields)

        with self._lock:
            rec, _ = self._lookup(key)
            if rec is not None:
                return dict(rec.best)
            key_lock = self._inflight.setdefault(key, threading.Lock())

        # Measure OUTSIDE the tuner-wide lock (a paper-sized interpret
        # search takes seconds — unrelated keys must not queue behind
        # it); the per-key lock still ensures concurrent compiles of the
        # SAME shape run one search, with losers re-reading the result.
        with key_lock:
            with self._lock:
                rec, _ = self._lookup(key)
            if rec is not None:
                return dict(rec.best)
            kept, rejected = list(candidates), []
            if legal is not None:
                kept = []
                for cand in candidates:
                    reason = legal(cand)
                    (kept if reason is None else rejected).append(
                        cand if reason is None else (cand, reason))
                if rejected:
                    self._c_rejected.inc(len(rejected))
                if not kept:
                    first = rejected[0][1]
                    raise ValueError(
                        f"all {len(candidates)} tuning candidates are "
                        f"statically illegal (first: {first})")
            t0 = time.perf_counter()
            with self._tel.span("netgen.tune.search", key=key[:12],
                                candidates=len(kept),
                                rejected=len(rejected)) as sp:
                table = []
                for cand in kept:
                    cand = dict(cand)
                    measure(cand)                  # warmup (trace/compile)
                    best = min(measure(cand) for _ in range(max(1, reps)))
                    table.append((cand, best * 1e6))
                winner = dict(min(table, key=lambda t: t[1])[0])
                sp.set_attr("winner", winner)
            dt = time.perf_counter() - t0
            rec = TuneRecord(
                key=key,
                best=winner,
                measurements=tuple(table),
                device_kind=_field(key_fields, "device_kind"),
                created_unix=time.time(),
            )
            self._c_measurements.inc(len(table))
            self._c_tunes.inc()
            self._h_measure.observe(dt)
            with self._lock:
                self._mem[key] = rec
                self._inflight.pop(key, None)
            if self.store is not None:
                self.store.put(rec)
            return dict(rec.best)


def _field(key_fields, name: str) -> str:
    if isinstance(key_fields, Mapping):
        return str(key_fields.get(name, "unknown"))
    return "unknown"


_DEFAULT_TUNER: KernelTuner | None = None
_DEFAULT_LOCK = threading.Lock()


def default_tuner() -> KernelTuner:
    """The process-wide in-memory tuner `tuned=true` compiles fall back
    to when no `Session(tune_store=...)` tuner is threaded through —
    same-process reuse only; configure a store for cross-process."""
    global _DEFAULT_TUNER
    with _DEFAULT_LOCK:
        if _DEFAULT_TUNER is None:
            _DEFAULT_TUNER = KernelTuner()
        return _DEFAULT_TUNER
