"""Optimization passes over the circuit IR, with per-pass statistics.

Each pass is a pure function `Circuit -> Circuit` performing an *exact*
rewrite (predictions are unchanged under the strict step semantics; see
`graph.evaluate`). The paper's structural tricks map onto them:

  delete_zero_terms     — paper L4, per-term: a `0 * x` addend is deleted
                          from the generated program (~50% of terms).
  prune_dead_units      — paper L4, per-unit: a hidden unit with no inputs
                          is constant 0 and vanishes downstream; a hidden
                          unit nothing reads is deleted outright.
  addend_rewrite        — paper L5: `w * x` with x in {0,1} becomes |w|
                          repeated ±x addends — multiplication-free form.
  share_common_addends  — CSE over addends: a (w_a·a + w_b·b) pair that
                          occurs in several accumulators is computed once
                          in a shared sub-sum node (adder sharing; the
                          natural next rewrite after L5, cf. common-
                          subexpression elimination in multiple-constant-
                          multiplication synthesis). Makes the circuit an
                          irregular DAG: fine for the Verilog backend and
                          the interpreter, rejected by the dense jnp /
                          pallas backends.

`run_pipeline` threads a circuit through a pass list and records a
`PassStats` entry per pass (the successor of the old flat `NetgenStats`):
node / term / multiply / add counts before and after, so benchmarks can
attribute savings to individual rewrites instead of one lump figure.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Callable, Sequence

from repro.netgen.graph import (
    Argmax, Circuit, SignStep, Term, WeightedSum,
)

Pass = Callable[[Circuit], Circuit]


# ---------------------------------------------------------------------------
# Cost model (the paper counts logic cells; we count the arithmetic the
# cell counts are proportional to)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CircuitOps:
    """Arithmetic cost of one circuit, per prediction."""
    nodes: int          # all IR nodes
    sum_nodes: int      # accumulators (the paper's hi/fi wires)
    terms: int          # weighted addends across all accumulators
    mults: int          # terms needing a real multiplier (|w| > 1)
    adds: int           # two-input adders: sum over nodes of (terms - 1)
    addend_units: int   # adders after full L5 expansion: sum of |w|

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def ops(circuit: Circuit) -> CircuitOps:
    sums = circuit.by_kind(WeightedSum)
    terms = sum(len(n.terms) for n in sums)
    return CircuitOps(
        nodes=len(circuit.nodes),
        sum_nodes=len(sums),
        terms=terms,
        mults=sum(1 for n in sums for t in n.terms if abs(t.weight) > 1),
        adds=sum(max(len(n.terms) - 1, 0) for n in sums),
        addend_units=sum(abs(t.weight) for n in sums for t in n.terms),
    )


@dataclasses.dataclass(frozen=True)
class PassStats:
    """Before/after cost of one pass application."""
    name: str
    before: CircuitOps
    after: CircuitOps

    @property
    def terms_deleted(self) -> int:
        return self.before.terms - self.after.terms

    @property
    def adds_saved(self) -> int:
        return self.before.adds - self.after.adds

    def row(self) -> str:
        b, a = self.before, self.after
        return (f"{self.name}: terms {b.terms}->{a.terms}, "
                f"mults {b.mults}->{a.mults}, adds {b.adds}->{a.adds}, "
                f"nodes {b.nodes}->{a.nodes}")


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------

def delete_zero_terms(circuit: Circuit) -> Circuit:
    """Drop `0 * x` addends (paper L4 term deletion). Exact trivially."""
    nodes = tuple(
        dataclasses.replace(
            n, terms=tuple(t for t in n.terms if t.weight != 0))
        if isinstance(n, WeightedSum) else n
        for n in circuit.nodes)
    return dataclasses.replace(circuit, nodes=nodes)


def prune_dead_units(circuit: Circuit) -> Circuit:
    """Remove structurally dead hidden units (paper L4 unit deletion).

    * empty accumulator: value is constant 0, step(0) = 0 under the
      strict semantics, so every downstream term that reads its step
      contributes nothing — delete those terms, then the unit.
    * unread unit: a hidden step no accumulator reads (its output weights
      were all zero) is deleted with its accumulator.

    Final-layer accumulators and InputCompare nodes are never removed:
    the argmax needs every class score, and the input comparators are
    part of the module interface (the paper's Verilog keeps unused `in`
    wires too). Runs to fixpoint — removing one unit can strand another.
    """
    by_id = {n.id: n for n in circuit.nodes}
    final = set(by_id[circuit.output].srcs)

    while True:
        # steps whose accumulator is empty -> their value is constant 0
        zero_steps = {
            n.id for n in by_id.values()
            if isinstance(n, SignStep) and not by_id[n.src].terms}
        if zero_steps:
            for nid, n in list(by_id.items()):
                if isinstance(n, WeightedSum):
                    kept = tuple(t for t in n.terms if t.src not in zero_steps)
                    if len(kept) != len(n.terms):
                        by_id[nid] = dataclasses.replace(n, terms=kept)

        consumers: Counter = Counter()
        for n in by_id.values():
            if isinstance(n, WeightedSum):
                consumers.update(t.src for t in n.terms)
            elif isinstance(n, SignStep):
                consumers.update((n.src,))
            elif isinstance(n, Argmax):
                consumers.update(n.srcs)

        dead = {
            nid for nid, n in by_id.items()
            if consumers[nid] == 0
            and (isinstance(n, SignStep)
                 or (isinstance(n, WeightedSum) and nid not in final))}
        if not dead:
            break
        for nid in dead:
            del by_id[nid]

    nodes = tuple(by_id[n.id] for n in circuit.nodes if n.id in by_id)
    return dataclasses.replace(circuit, nodes=nodes)


def addend_rewrite(circuit: Circuit) -> Circuit:
    """Paper L5: expand `w * x` into |w| repeated ±1 addends. Exact; after
    this pass no accumulator needs a multiplier (`ops().mults == 0`)."""
    def expand(n: WeightedSum) -> WeightedSum:
        units = tuple(
            Term(weight=1 if t.weight > 0 else -1, src=t.src)
            for t in n.terms for _ in range(abs(t.weight)))
        return dataclasses.replace(n, terms=units)

    nodes = tuple(
        expand(n) if isinstance(n, WeightedSum) else n for n in circuit.nodes)
    return dataclasses.replace(circuit, nodes=nodes)


def share_common_addends(circuit: Circuit, *, max_new_nodes: int = 4096,
                         bucketed: bool = False) -> Circuit:
    """Greedy two-term CSE: extract the most frequent addend pair into a
    shared sub-sum until no pair repeats (or max_new_nodes is hit).

    A pair key is the unordered combination of two distinct (weight, src)
    terms; a node counts each key at most once per round. Every extraction
    strictly reduces total adds (k co-occurrences save k adders and spend
    one in the shared node), so the loop terminates. Exact: the shared
    node computes precisely the sub-sum it replaces.

    The default (exhaustive) candidate search is O(sum_nodes * terms^2)
    per round and extracts ONE pair per round — intended for post-addend
    hardware circuits of moderate size. `bucketed=True` selects the
    scalable variant (ROADMAP "Scale" item): per node, candidate pairs
    are indexed by their (sign, magnitude) weight bucket — only terms
    with the SAME signed weight pair up — so one counting sweep costs
    ~O(terms * bucket) instead of O(terms^2), and every pair that repeats
    is extracted in that same sweep (batch extraction) instead of one per
    round. Same-weight pairs are exactly the ones the addend form
    produces en masse, so on L5 circuits the restriction loses little
    sharing while making the full 784-input net tractable. Still an
    exact rewrite; still an irregular DAG result (see
    graph.IrregularCircuitError).
    """
    nodes = list(circuit.nodes)
    next_id = max(n.id for n in nodes) + 1
    created = 0

    while created < max_new_nodes:
        counts: Counter = Counter()
        for n in nodes:
            if not isinstance(n, WeightedSum):
                continue
            distinct = sorted(set(n.terms), key=lambda t: (t.src, t.weight))
            if bucketed:
                buckets: dict[int, list[Term]] = {}
                for t in distinct:
                    buckets.setdefault(t.weight, []).append(t)
                groups = buckets.values()
            else:
                groups = (distinct,)
            for group in groups:
                for i in range(len(group)):
                    for j in range(i + 1, len(group)):
                        counts[(group[i], group[j])] += 1

        if bucketed:
            repeated = [(pair, k) for pair, k in counts.most_common()
                        if k >= 2]
        else:
            # classic greedy: one pair per round (most_common(1) is a
            # heap scan, not a full sort of the O(terms^2) counter)
            repeated = [(pair, k) for pair, k in counts.most_common(1)
                        if k >= 2]
        if not repeated:
            break

        progressed = False
        for (ta, tb), _ in repeated:
            if created >= max_new_nodes:
                break
            # membership may have changed within this sweep — recheck
            hosts = [
                i for i, n in enumerate(nodes)
                if isinstance(n, WeightedSum)
                and ta in n.terms and tb in n.terms]
            if len(hosts) < 2:
                continue
            shared = WeightedSum(
                id=next_id, terms=(ta, tb),
                layer=min(nodes[i].layer for i in hosts))
            next_id += 1
            created += 1
            progressed = True

            for i in hosts:
                n = nodes[i]
                kept = list(n.terms)
                kept.remove(ta)
                kept.remove(tb)
                kept.append(Term(weight=1, src=shared.id))
                nodes[i] = dataclasses.replace(n, terms=tuple(kept))
            nodes.insert(min(hosts), shared)
        if not progressed:
            break

    out = dataclasses.replace(circuit, nodes=tuple(nodes))
    out.validate()
    return out


# ---------------------------------------------------------------------------
# Pipeline driver
# ---------------------------------------------------------------------------

# Exact rewrites safe for every backend (dense layered form preserved).
DEFAULT_PASSES: tuple[Pass, ...] = (delete_zero_terms, prune_dead_units)

# Full hardware pipeline: multiplication-free form plus adder sharing.
# Produces an irregular DAG — Verilog / interpreter only.
HW_PASSES: tuple[Pass, ...] = (
    delete_zero_terms, prune_dead_units, addend_rewrite, share_common_addends)


def run_pipeline(
    circuit: Circuit, passes: Sequence[Pass] = DEFAULT_PASSES,
    *, verify: bool = False,
) -> tuple[Circuit, tuple[PassStats, ...]]:
    """Apply `passes` in order, recording per-pass cost deltas.

    `verify=True` runs the `repro.netgen.analysis` structural verifier
    (plus the pass's postconditions, matched by function name) after
    every pass — the legacy-driver face of `PipelineSpec.run(verify=)`.
    """
    if verify:
        from repro.netgen import analysis
        analysis.verify_circuit(circuit, stage="lowered")
    stats = []
    for p in passes:
        before = ops(circuit)
        circuit = p(circuit)
        name = getattr(p, "__name__", str(p))
        if verify:
            analysis.verify_circuit(circuit, after_pass=name, stage=name)
        stats.append(PassStats(
            name=name, before=before,
            after=ops(circuit)))
    return circuit, tuple(stats)
