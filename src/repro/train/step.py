"""Train-step factory: gradient-accumulation microbatch scan + remat +
AdamW, built for pjit (all sharding via logical annotations + in/out specs).

Memory strategy for the big cells (DESIGN.md §5): the global batch is
split into `accum` microbatches scanned sequentially; each microbatch's
logits/activations exist only inside its scan iteration (vocab-sized
logits never materialize globally), and layer activations inside each
microbatch are remat'ed (`nothing_saveable`) over the layer scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import api, runtime
from repro.models.base import ArchConfig, ShapeConfig
from repro.optim import adamw


def make_train_step(cfg: ArchConfig, shape: ShapeConfig, oc: adamw.OptConfig,
                    *, remat: str = "full"):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt": {m, v, step}}; batch per data.pipeline.
    """
    accum = max(shape.accum, 1)

    def micro_loss(params, mb):
        return api.loss_fn(cfg, params, mb, remat=remat)

    def train_step(state, batch):
        params = state["params"]
        B = batch["tokens"].shape[0]
        assert B % accum == 0, (B, accum)

        def split(x):
            return x.reshape((accum, B // accum) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def acc_fn(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = jax.value_and_grad(micro_loss, has_aux=True)(
                params, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum, gsum, grads)
            return (gsum, lsum + loss / accum), None

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if accum == 1:
            mb0 = jax.tree.map(lambda x: x[0], mbs)
            (loss, metrics), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, mb0)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            (grads, loss), _ = jax.lax.scan(acc_fn, (gzero, 0.0), mbs,
                                            **runtime.scan_kwargs())
            metrics = {}

        new_params, new_opt, opt_metrics = adamw.apply_updates(
            params, grads, state["opt"], oc)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def abstract_state(cfg: ArchConfig):
    """Abstract train state (ParamInfo trees) for init/dry-run/sharding."""
    ap = api.abstract_params(cfg)
    return {"params": ap, "opt": adamw.abstract_opt_state(ap)}
