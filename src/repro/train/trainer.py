"""Fault-tolerant training loop.

Production posture (DESIGN.md §5), scaled down to this container:

  * checkpoint/restart: periodic atomic checkpoints + resume-from-latest;
    the data pipeline is a pure function of step, so replayed steps are
    bit-identical (verified by tests/test_checkpoint.py::test_kill_resume).
  * failure handling: any exception in a step triggers an emergency
    checkpoint of the last good state before re-raising; a supervisor
    (or this trainer re-invoked with resume=True) continues from there.
    `fail_at_step` injects a synthetic failure for testing.
  * straggler mitigation: per-step wall-time EMA; steps slower than
    `straggler_factor` x EMA are flagged. On a real cluster the flag feeds
    the elastic controller (drop/replace the slow host and restart from
    the latest checkpoint on the resized mesh — restore() already reshards
    to whatever mesh is active); here we record the events.
  * elastic scaling: restore() reshards to the active mesh, so resuming on
    a different device count "just works".
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.checkpoint import ckpt as ckpt_lib
from repro.data import pipeline
from repro.models.base import ArchConfig, ShapeConfig, tree_init
from repro.optim import adamw
from repro.train import step as step_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    seed: int = 0
    data_seed: int = 1234
    log_every: int = 10
    fail_at_step: int = -1          # failure injection (testing)
    straggler_factor: float = 3.0
    remat: str = "none"             # smoke scale doesn't need remat


class InjectedFailure(RuntimeError):
    pass


def run(cfg: ArchConfig, shape: ShapeConfig, oc: adamw.OptConfig,
        tc: TrainerConfig, *, resume: bool = False, donate: bool = True):
    """Train; returns (final_state, history dict)."""
    mgr = ckpt_lib.CheckpointManager(tc.ckpt_dir, keep=tc.keep)
    abstract = step_lib.abstract_state(cfg)

    start_step = 0
    state = None
    if resume:
        s, restored = mgr.restore_latest(abstract)
        if restored is not None:
            start_step, state = int(s), restored
    if state is None:
        state = tree_init(abstract, jax.random.PRNGKey(tc.seed))
        start_step = 0

    train_step = step_lib.make_train_step(cfg, shape, oc, remat=tc.remat)
    jitted = jax.jit(train_step, donate_argnums=(0,) if donate else ())

    history = {"loss": [], "steps": [], "stragglers": [], "failures": []}
    ema = None
    step = start_step
    try:
        for step, batch_np in pipeline.batch_iterator(
                cfg, shape, seed=tc.data_seed, start_step=start_step):
            if step >= tc.total_steps:
                break
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            if step == tc.fail_at_step:
                raise InjectedFailure(f"injected failure at step {step}")
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > tc.straggler_factor * ema and step > start_step + 2:
                history["stragglers"].append((step, dt, ema))
            history["loss"].append(loss)
            history["steps"].append(step)
            if (step + 1) % tc.ckpt_every == 0:
                mgr.save(step + 1, state, metadata={"loss": loss})
    except InjectedFailure as e:
        # emergency checkpoint of the last good state, then surface the
        # failure to the supervisor (tests re-enter with resume=True)
        history["failures"].append(str(e))
        mgr.save(step, state, tag="emergency")
        raise
    return state, history
