"""Deterministic synthetic data pipeline.

Design goals for fault tolerance (DESIGN.md §5): the batch for step N is a
pure function of (seed, step, shape), so a restarted run replays the exact
stream with no data-loader state to checkpoint, and an elastically-resized
run keeps per-step determinism (batches are generated globally and sharded
by the runtime, not generated per-host).

The token stream is a structured Markov-ish source (not uniform noise) so
training losses have signal: token t+1 depends on t via a fixed permuted
affine map plus noise, giving a learnable bigram structure.
"""
from __future__ import annotations

import numpy as np

from repro.models.base import ArchConfig, ShapeConfig


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def make_batch(cfg: ArchConfig, shape: ShapeConfig, step: int, *,
               seed: int = 1234, batch_override: int | None = None) -> dict:
    """Training batch for `step`: dict of numpy arrays (runtime shards them)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    rng = _rng(seed, step)
    V = cfg.vocab

    # learnable bigram chain: x_{t+1} = (a * x_t + b) % V with eps-noise
    a = 31337 % V or 7
    x0 = rng.integers(0, V, size=(B, 1))
    noise = rng.random((B, S)) < 0.1
    rand_tok = rng.integers(0, V, size=(B, S))
    toks = np.empty((B, S + 1), np.int32)
    toks[:, 0] = x0[:, 0]
    for t in range(S):
        nxt = (toks[:, t] * a + 17) % V
        toks[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)

    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.modality == "vlm":
        n_img = max(S // 4, 1)
        pe = rng.normal(0, 1, size=(B, S, cfg.d_model)).astype(np.float32)
        mask = np.zeros((B, S), bool)
        mask[:, :n_img] = True                       # image prefix
        batch["pixel_embeds"] = pe
        batch["pixel_mask"] = mask
        base = np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S))
        batch["positions"] = np.stack([base] * 3, axis=1).copy()   # (B, 3, S)
        lm = np.ones((B, S), np.float32)
        lm[:, :n_img] = 0.0                          # loss only on text
        batch["loss_mask"] = lm
    elif cfg.modality == "audio":
        batch["frame_embeds"] = rng.normal(
            0, 0.02, size=(B, S, cfg.d_model)).astype(np.float32)
    return batch


def batch_iterator(cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 1234,
                   start_step: int = 0):
    """Infinite deterministic stream, resumable at any step."""
    step = start_step
    while True:
        yield step, make_batch(cfg, shape, step, seed=seed)
        step += 1
