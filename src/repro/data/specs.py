"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

`input_specs(cfg, shape)` returns weak-type-correct, shardable abstract
values for each cell kind — no device allocation ever happens:

  train   -> {tokens, targets, (+vlm/audio extras)}
  prefill -> {tokens, (+extras)}           (cache passed separately)
  decode  -> {tokens (B,1), pos (B,)}      (cache passed separately)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, ShapeConfig
from repro.parallel import sharding as shd


def _sds(shape, dtype, logical):
    sh = shd.named_sharding(shape, logical)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    if kind == "train":
        d = {
            "tokens": _sds((B, S), jnp.int32, ("batch", "seq")),
            "targets": _sds((B, S), jnp.int32, ("batch", "seq")),
        }
        if cfg.modality == "vlm":
            d["pixel_embeds"] = _sds((B, S, cfg.d_model), cfg.cdtype(),
                                     ("batch", "seq", None))
            d["pixel_mask"] = _sds((B, S), jnp.bool_, ("batch", "seq"))
            # (B, 3, S): batch-leading so grad-accum microbatching can split
            d["positions"] = _sds((B, 3, S), jnp.int32, ("batch", None, "seq"))
            d["loss_mask"] = _sds((B, S), jnp.float32, ("batch", "seq"))
        elif cfg.modality == "audio":
            d["frame_embeds"] = _sds((B, S, cfg.d_model), cfg.cdtype(),
                                     ("batch", "seq", None))
        return d
    if kind == "prefill":
        d = {"tokens": _sds((B, S), jnp.int32, ("batch", "seq"))}
        if cfg.modality == "vlm":
            d["pixel_embeds"] = _sds((B, S, cfg.d_model), cfg.cdtype(),
                                     ("batch", "seq", None))
            d["pixel_mask"] = _sds((B, S), jnp.bool_, ("batch", "seq"))
            d["positions"] = _sds((B, 3, S), jnp.int32, ("batch", None, "seq"))
        elif cfg.modality == "audio":
            d["frame_embeds"] = _sds((B, S, cfg.d_model), cfg.cdtype(),
                                     ("batch", "seq", None))
        return d
    if kind == "decode":
        return {
            "tokens": _sds((B, 1), jnp.int32, ("batch", None)),
            "pos": _sds((B,), jnp.int32, ("batch",)),
        }
    raise ValueError(kind)
