"""Synthetic MNIST-like digit dataset.

The paper evaluates on MNIST (28x28 grayscale handwritten digits, pixel
values 0..255). MNIST is not available offline in this container, so we
procedurally render a drop-in replacement: digit glyphs from a 5x7 bitmap
font, upscaled to 28x28 with random translation, scale, stroke thickness,
and pixel noise. The resulting arrays have the exact MNIST interface the
paper's pipeline expects: uint8 images in [0, 255], integer labels 0..9.

Deterministic given a seed, so every experiment is reproducible.
"""
from __future__ import annotations

import numpy as np

# 5x7 bitmap font for digits 0-9 (classic hex display font).
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}

IMG = 28  # matches the paper: 28x28 input, 784 input nodes


def _glyph(digit: int) -> np.ndarray:
    rows = _FONT[digit]
    return np.array([[int(c) for c in r] for r in rows], dtype=np.float32)


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 28x28 uint8 image with random geometry + noise."""
    g = _glyph(digit)  # (7, 5)
    # Random target glyph size (stroke scale), keep aspect roughly 7:5.
    # Narrow ranges: MNIST digits are size-normalized, and the paper's 98%
    # from 1000 training images implies an easy, well-normalized task.
    h = int(rng.integers(18, 21))
    w = int(rng.integers(12, 15))
    # Nearest-neighbour upscale.
    ri = (np.arange(h) * g.shape[0] // h)
    ci = (np.arange(w) * g.shape[1] // w)
    big = g[np.ix_(ri, ci)]
    # Random stroke thickening via max-pool style dilation.
    if rng.random() < 0.5:
        pad = np.pad(big, 1)
        big = np.maximum.reduce(
            [pad[1:-1, 1:-1], pad[:-2, 1:-1], pad[2:, 1:-1], pad[1:-1, :-2], pad[1:-1, 2:]]
        )
    img = np.zeros((IMG, IMG), dtype=np.float32)
    # Centered placement with small jitter (MNIST digits are centered; full
    # translation invariance would make the task much harder than MNIST).
    rc, cc = (IMG - h) // 2, (IMG - w) // 2
    r0 = int(np.clip(rc + rng.integers(-2, 3), 0, IMG - h))
    c0 = int(np.clip(cc + rng.integers(-2, 3), 0, IMG - w))
    img[r0 : r0 + h, c0 : c0 + w] = big
    # Intensity: ink pixels get high-but-varied values, paper, low noise.
    ink = rng.uniform(170, 255, size=img.shape).astype(np.float32)
    bg = np.abs(rng.normal(0.0, 18.0, size=img.shape)).astype(np.float32)
    out = np.where(img > 0.5, ink, bg)
    # Slight blur to soften edges (3x3 box, cheap).
    p = np.pad(out, 1)
    out = (
        p[1:-1, 1:-1] * 0.6
        + (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]) * 0.1
    )
    return np.clip(out, 0, 255).astype(np.uint8)


def make_dataset(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Return (images uint8 (n, 784), labels int32 (n,))."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    imgs = np.stack([_render(int(d), rng).reshape(-1) for d in labels])
    return imgs, labels


def train_test_split(
    n_train: int = 1000, n_test: int = 1000, seed: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Paper protocol: train on 1000 images; test on a disjoint set.

    Disjointness is by construction (independent random draws from the
    generative process with different seeds), matching the paper's
    train/test separation requirement.
    """
    xtr, ytr = make_dataset(n_train, seed=seed)
    xte, yte = make_dataset(n_test, seed=seed + 10_000)
    return xtr, ytr, xte, yte
