"""The paper's optimization ladder (§III): inference-time simplifications.

Stages (cumulative, exactly as the paper applies them):

  L0  baseline       — sigmoid activations, scaled float inputs, fp32 weights
  L1  step act       — hidden sigmoid -> step(x > 0); output argmax unchanged
                       (paper §III.A: 98% -> 95%)
  L2  binary input   — raw pixel > 128 -> {0,1} instead of float scaling
                       (paper §III.B: 95% -> 94%)
  L3  integer weights— weights cast to small integers
                       (paper §III.C: 94% -> 92%)

L4 (zero pruning) and L5 (multiplication-free addend form) are *exact
rewrites* of the L3 network — they change resources, not accuracy — and
live in `repro.core.netgen`.

A note on L3 faithfulness: the paper's Verilog comments bound weights as
-10 < w < 10, i.e. the float weights are affinely scaled into a small
integer range before casting (raw trained weights have |w| << 1 and a
direct cast would zero the network). Positive per-layer scaling commutes
with both the step threshold at 0 and the final argmax, so the scaled cast
is mathematically the paper's transform.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mlp as mlp_lib

INPUT_THRESHOLD = 128  # paper: pixel cutoff value
WEIGHT_BOUND = 9       # paper: -10 < weights < 10


def step(x: jnp.ndarray) -> jnp.ndarray:
    """Paper's activation: comparator at 0. On hardware this is the MSB
    (sign bit) of the signed accumulator; here a VPU compare."""
    return (x > 0).astype(jnp.int32)


def binarize_input(x_uint8: jnp.ndarray, threshold: int = INPUT_THRESHOLD) -> jnp.ndarray:
    """Paper §III.B: raw pixel in [0,255] -> {0,1} at cutoff 128."""
    return (x_uint8.astype(jnp.int32) > threshold).astype(jnp.int32)


def int_cast_weights(w: np.ndarray, bound: int = WEIGHT_BOUND) -> np.ndarray:
    """Paper §III.C: cast weights to integers, scaled into (-10, 10).

    Scale is per-matrix (a single positive scalar), preserving the sign of
    every pre-activation and the argmax of the output layer.
    """
    w = np.asarray(w, dtype=np.float64)
    s = bound / max(np.abs(w).max(), 1e-12)
    return np.round(w * s).astype(np.int32)


# ---------------------------------------------------------------------------
# Ladder predictors. Each returns a jitted fn: uint8 images -> int predictions.
# ---------------------------------------------------------------------------

def predict_l1(params: dict):
    """L1: step hidden activation, float weights, scaled float input."""
    w1 = jnp.asarray(params["w1"], jnp.float32)
    w2 = jnp.asarray(params["w2"], jnp.float32)

    @jax.jit
    def f(x_uint8):
        x = mlp_lib.scale_inputs(x_uint8)
        hi = x @ w1
        ho = step(hi).astype(jnp.float32)
        fi = ho @ w2
        return jnp.argmax(fi, axis=-1)

    return f


def predict_l2(params: dict):
    """L2: + binary inputs (pixel > 128)."""
    w1 = jnp.asarray(params["w1"], jnp.float32)
    w2 = jnp.asarray(params["w2"], jnp.float32)

    @jax.jit
    def f(x_uint8):
        x = binarize_input(x_uint8).astype(jnp.float32)
        hi = x @ w1
        ho = step(hi).astype(jnp.float32)
        fi = ho @ w2
        return jnp.argmax(fi, axis=-1)

    return f


def predict_l3(params: dict):
    """L3: + integer weights. The whole network is now integer arithmetic:
    binary inputs, int weights, int accumulators, sign-bit activations —
    exactly the arithmetic the paper's Verilog implements."""
    w1 = jnp.asarray(int_cast_weights(params["w1"]), jnp.int32)
    w2 = jnp.asarray(int_cast_weights(params["w2"]), jnp.int32)

    @jax.jit
    def f(x_uint8):
        x = binarize_input(x_uint8)                 # {0,1} int32
        hi = x @ w1                                 # int32 accumulate
        ho = step(hi)                               # {0,1} int32
        fi = ho @ w2
        return jnp.argmax(fi, axis=-1)

    return f


@dataclasses.dataclass(frozen=True)
class QuantizedNet:
    """Frozen integer network produced by the ladder (input to netgen)."""
    w1: np.ndarray  # int32 (n_in, n_hidden)
    w2: np.ndarray  # int32 (n_hidden, n_out)
    input_threshold: int = INPUT_THRESHOLD

    @property
    def shapes(self) -> tuple:
        return (self.w1.shape, self.w2.shape)


def quantize(params: dict) -> QuantizedNet:
    return QuantizedNet(
        w1=int_cast_weights(params["w1"]),
        w2=int_cast_weights(params["w2"]),
    )
