"""The paper's optimization ladder (§III): inference-time simplifications.

Stages (cumulative, exactly as the paper applies them):

  L0  baseline       — sigmoid activations, scaled float inputs, fp32 weights
  L1  step act       — hidden sigmoid -> step(x > 0); output argmax unchanged
                       (paper §III.A: 98% -> 95%)
  L2  binary input   — raw pixel > 128 -> {0,1} instead of float scaling
                       (paper §III.B: 95% -> 94%)
  L3  integer weights— weights cast to small integers
                       (paper §III.C: 94% -> 92%)

L4 (zero pruning) and L5 (multiplication-free addend form) are *exact
rewrites* of the L3 network — they change resources, not accuracy — and
live in `repro.netgen` (compat shim: `repro.core.netgen`).

The ladder generalizes past the paper's 784-500-10 topology: every
predictor accepts a params dict with any number of weight matrices
("w1".."wN", see `param_weights`), applying the step activation between
all layers and argmax at the output, and `QuantizedNet` holds the full
integer stack. The 2-layer construction (`w1=`/`w2=`) keeps working.

A note on L3 faithfulness: the paper's Verilog comments bound weights as
-10 < w < 10, i.e. the float weights are affinely scaled into a small
integer range before casting (raw trained weights have |w| << 1 and a
direct cast would zero the network). Positive per-layer scaling commutes
with both the step threshold at 0 and the final argmax, so the scaled cast
is mathematically the paper's transform.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mlp as mlp_lib

INPUT_THRESHOLD = 128  # paper: pixel cutoff value
WEIGHT_BOUND = 9       # paper: -10 < weights < 10


def step(x: jnp.ndarray) -> jnp.ndarray:
    """Paper's activation: comparator at 0. On hardware this is the MSB
    (sign bit) of the signed accumulator; here a VPU compare."""
    return (x > 0).astype(jnp.int32)


def binarize_input(x_uint8: jnp.ndarray, threshold: int = INPUT_THRESHOLD) -> jnp.ndarray:
    """Paper §III.B: raw pixel in [0,255] -> {0,1} at cutoff 128."""
    return (x_uint8.astype(jnp.int32) > threshold).astype(jnp.int32)


def int_cast_weights(w: np.ndarray, bound: int = WEIGHT_BOUND) -> np.ndarray:
    """Paper §III.C: cast weights to integers, scaled into (-10, 10).

    Scale is per-matrix (a single positive scalar), preserving the sign of
    every pre-activation and the argmax of the output layer.
    """
    w = np.asarray(w, dtype=np.float64)
    s = bound / max(np.abs(w).max(), 1e-12)
    return np.round(w * s).astype(np.int32)


def weights_digest(weights, input_threshold: int = INPUT_THRESHOLD) -> str:
    """Stable content digest of a quantized stack (the compile-cache key).

    Covers the integer weight *values*, shapes, layer order, and the input
    threshold — nothing else. Values are canonicalized to int64 before
    hashing, so the digest is identical across storage dtypes (an int8
    and an int32 copy of the same matrix hash equal) and across processes
    and machines (sha256 over little-endian bytes, no Python `hash`).
    """
    h = hashlib.sha256()
    weights = list(weights)
    h.update(f"netgen-v1:thr={int(input_threshold)}:depth={len(weights)}"
             .encode())
    for w in weights:
        w = np.asarray(w)
        if not np.issubdtype(w.dtype, np.integer):
            raise TypeError(
                f"weights_digest hashes *quantized* stacks; got dtype {w.dtype}")
        w = np.ascontiguousarray(w.astype("<i8"))
        h.update(f":{w.shape}:".encode())
        h.update(w.tobytes())
    return h.hexdigest()


def param_weights(params: dict) -> list:
    """Ordered weight matrices of a params dict: keys "w1".."wN"."""
    keys = mlp_lib._weight_keys(params)
    if not keys:
        raise ValueError(f"no w<i> keys in params: {sorted(params)}")
    return [params[k] for k in keys]


# ---------------------------------------------------------------------------
# Ladder predictors. Each returns a jitted fn: uint8 images -> int predictions.
# ---------------------------------------------------------------------------

def _step_chain(x, ws, dtype):
    """Shared ladder arithmetic: step between layers, argmax at the end."""
    for w in ws[:-1]:
        x = step(x @ w).astype(dtype)
    return jnp.argmax(x @ ws[-1], axis=-1)


def predict_l1(params: dict):
    """L1: step hidden activations, float weights, scaled float input."""
    ws = [jnp.asarray(w, jnp.float32) for w in param_weights(params)]

    @jax.jit
    def f(x_uint8):
        return _step_chain(mlp_lib.scale_inputs(x_uint8), ws, jnp.float32)

    return f


def predict_l2(params: dict):
    """L2: + binary inputs (pixel > 128)."""
    ws = [jnp.asarray(w, jnp.float32) for w in param_weights(params)]

    @jax.jit
    def f(x_uint8):
        return _step_chain(binarize_input(x_uint8).astype(jnp.float32), ws,
                           jnp.float32)

    return f


def predict_l3(params: dict):
    """L3: + integer weights. The whole network is now integer arithmetic:
    binary inputs, int weights, int accumulators, sign-bit activations —
    exactly the arithmetic the paper's Verilog implements."""
    ws = [jnp.asarray(int_cast_weights(w), jnp.int32)
          for w in param_weights(params)]

    @jax.jit
    def f(x_uint8):
        return _step_chain(binarize_input(x_uint8), ws, jnp.int32)

    return f


@dataclasses.dataclass(frozen=True, init=False)
class QuantizedNet:
    """Frozen integer network produced by the ladder (input to netgen).

    Holds any number of layers in `weights`; the original 2-layer
    construction `QuantizedNet(w1=..., w2=...)` and the `.w1`/`.w2`
    accessors keep working (and `.w2` means *the second of two* — it
    raises on deeper stacks rather than silently aliasing a layer).
    """
    weights: tuple            # int32 matrices, (fan_in, fan_out) each
    input_threshold: int

    def __init__(self, w1=None, w2=None, *, weights=None,
                 input_threshold: int = INPUT_THRESHOLD):
        if weights is None:
            if w1 is None or w2 is None:
                raise TypeError("pass w1= and w2=, or weights=[...]")
            weights = (w1, w2)
        elif w1 is not None or w2 is not None:
            raise TypeError("pass either w1/w2 or weights=, not both")
        object.__setattr__(
            self, "weights", tuple(np.asarray(w) for w in weights))
        object.__setattr__(self, "input_threshold", int(input_threshold))

    @property
    def depth(self) -> int:
        return len(self.weights)

    def _pair(self) -> tuple:
        if self.depth != 2:
            raise AttributeError(
                f".w1/.w2 are 2-layer accessors; this net has depth "
                f"{self.depth} — use .weights")
        return self.weights

    @property
    def w1(self) -> np.ndarray:
        return self._pair()[0]

    @property
    def w2(self) -> np.ndarray:
        return self._pair()[1]

    @property
    def shapes(self) -> tuple:
        return tuple(w.shape for w in self.weights)

    def digest(self) -> str:
        """Content digest of this net (see `weights_digest`)."""
        return weights_digest(self.weights, self.input_threshold)


def quantize(params: dict) -> QuantizedNet:
    """Cast a trained float stack (any depth) to the frozen integer net."""
    return QuantizedNet(
        weights=[int_cast_weights(w) for w in param_weights(params)])


def predict_quantized(net: QuantizedNet):
    """Reference L3 arithmetic for an already-quantized net: the dense
    (matmul-based) path the compiled netgen backends must match bit-exactly."""
    ws = [jnp.asarray(w, jnp.int32) for w in net.weights]
    thr = net.input_threshold

    @jax.jit
    def f(x_uint8):
        return _step_chain(binarize_input(x_uint8, thr), ws, jnp.int32)

    return f
