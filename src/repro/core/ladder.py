"""End-to-end harness for the paper's optimization ladder (§III).

Trains the 784-500-10 net with the paper's protocol (1000 images,
5 epochs), then evaluates every ladder stage on held-out data and checks
the paper's structural claims:

  * accuracy decreases monotonically-ish and modestly L0 -> L3
    (paper: 98 / 95 / 94 / 92),
  * L4 (pruning) and L5 (mult-free/specialized) are EXACT rewrites of L3
    (identical predictions),
  * pruning removes a large fraction of weight terms (paper: ~50%).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import dataset, mlp, netgen, quantize


@dataclasses.dataclass
class LadderResult:
    acc: dict            # stage name -> accuracy
    stats: netgen.NetgenStats
    prune_info: netgen.PruneInfo
    exact_l4_l5: bool    # L4/L5 predictions identical to L3

    def table(self) -> str:
        rows = ["stage,accuracy,paper_accuracy"]
        paper = {"L0_baseline": 0.98, "L1_step_act": 0.95,
                 "L2_binary_input": 0.94, "L3_int_weights": 0.92,
                 "L4_pruned": 0.92, "L5_multfree": 0.92}
        for k, v in self.acc.items():
            rows.append(f"{k},{v:.4f},{paper.get(k, float('nan')):.2f}")
        return "\n".join(rows)


def run_ladder(
    n_train: int = 1000,
    n_test: int = 1000,
    epochs: int = 60,
    seed: int = 0,
    backends: tuple = ("jnp",),
    n_hidden: int | tuple = 500,
) -> LadderResult:
    """Train, quantize, and check every ladder stage. `n_hidden` may be a
    tuple of layer sizes — the whole ladder (and the netgen rewrites it
    feeds) runs on deeper stacks too; "fused" is 2-layer only."""
    xtr, ytr, xte, yte = dataset.train_test_split(n_train, n_test, seed=seed)
    cfg = mlp.MLPConfig(epochs=epochs, seed=seed + 1, n_hidden=n_hidden)
    params = mlp.train(cfg, xtr, ytr)

    acc = {}
    acc["L0_baseline"] = mlp.accuracy(mlp.predict_l0(params), xte, yte)
    acc["L1_step_act"] = mlp.accuracy(quantize.predict_l1(params), xte, yte)
    acc["L2_binary_input"] = mlp.accuracy(quantize.predict_l2(params), xte, yte)
    l3_fn = quantize.predict_l3(params)
    acc["L3_int_weights"] = mlp.accuracy(l3_fn, xte, yte)

    qnet = quantize.quantize(params)
    qnet_pruned, pinfo = netgen.prune(qnet)
    st = netgen.stats(qnet)

    import jax.numpy as jnp
    l3_preds = np.asarray(l3_fn(jnp.asarray(xte)))
    exact = True
    for backend in backends:
        fn = netgen.specialize(qnet, backend=backend)
        preds = np.asarray(fn(jnp.asarray(xte)))
        key = {"jnp": "L4_pruned", "pallas": "L5_multfree",
               "fused": "L5_fused"}.get(backend, backend)
        acc[key] = float(np.mean(preds == yte))
        exact = exact and bool(np.array_equal(preds, l3_preds))

    return LadderResult(acc=acc, stats=st, prune_info=pinfo, exact_l4_l5=exact)
