"""The paper's network: a 784-500-10 feed-forward classifier.

Matches the setup in paper §II.A (sampled from Rashid, *Make Your Own
Neural Network*): 784 input nodes (28x28 vectorized image), 500 hidden
nodes, 10 output nodes, sigmoid activations, trained by standard
backpropagation (SGD). Inputs are scaled to (0, 1) for training, exactly
as in the book (0.01 + x/255 * 0.99).

Training is plain JAX; the trained weights are the input to the
optimization ladder (`repro.core.quantize`) and the hardware generator
(`repro.core.netgen`).
"""
from __future__ import annotations

import dataclasses
import functools
import re

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    n_in: int = 784
    # One int reproduces the paper's single hidden layer; a tuple of ints
    # builds a deeper stack (e.g. (256, 64)) — the netgen compiler lowers
    # either through the same ladder.
    n_hidden: int | tuple = 500
    n_out: int = 10
    lr: float = 2.0
    # The paper trains 5 epochs on 1000 MNIST images for 98%. On our
    # synthetic stand-in dataset (see dataset.py) the same protocol needs
    # more epochs to converge; 60 epochs reaches ~96%, the closest match
    # to the paper's baseline. Recorded in DESIGN.md §7.
    epochs: int = 60
    seed: int = 42


def layer_sizes(cfg: MLPConfig) -> tuple[int, ...]:
    hidden = (cfg.n_hidden,) if isinstance(cfg.n_hidden, int) else tuple(cfg.n_hidden)
    return (cfg.n_in, *hidden, cfg.n_out)


def _weight_keys(params: dict) -> list[str]:
    return sorted((k for k in params if re.fullmatch(r"w\d+", k)),
                  key=lambda k: int(k[1:]))


def init_params(cfg: MLPConfig) -> dict:
    """Rashid-style init: normal(0, 1/sqrt(fan_in)). No biases (as in the
    book's network and the paper's Verilog, which has no bias addends).
    Returns {"w1": ..., "wN": ...}, one matrix per layer."""
    sizes = layer_sizes(cfg)
    keys = jax.random.split(jax.random.PRNGKey(cfg.seed), len(sizes) - 1)
    return {
        f"w{i+1}": (jax.random.normal(k, (m, n)) * (m ** -0.5)).astype(jnp.float32)
        for i, (k, m, n) in enumerate(zip(keys, sizes, sizes[1:]))
    }


def scale_inputs(x_uint8: jnp.ndarray) -> jnp.ndarray:
    """Book/paper input scaling: (0, 1] range, never exactly 0."""
    return x_uint8.astype(jnp.float32) / 255.0 * 0.99 + 0.01


def forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Full-precision forward pass (ladder stage L0), any depth. x: scaled
    floats; sigmoid after every layer, as in the book's network."""
    for k in _weight_keys(params):
        x = jax.nn.sigmoid(x @ params[k])
    return x


def _targets(y: jnp.ndarray, n_out: int) -> jnp.ndarray:
    """Book-style targets: 0.99 for the true class, 0.01 elsewhere."""
    return jnp.where(jax.nn.one_hot(y, n_out) > 0, 0.99, 0.01)


@functools.partial(jax.jit, static_argnames=("lr",))
def _sgd_batch(params: dict, x: jnp.ndarray, y: jnp.ndarray, lr: float) -> dict:
    def loss_fn(p):
        pred = forward(p, x)
        t = _targets(y, pred.shape[-1])
        return jnp.mean((pred - t) ** 2)

    grads = jax.grad(loss_fn)(params)
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)


def train(
    cfg: MLPConfig, x_uint8: np.ndarray, y: np.ndarray, batch_size: int = 10
) -> dict:
    """Standard backprop training (paper §II.A). Returns trained params."""
    params = init_params(cfg)
    x = scale_inputs(jnp.asarray(x_uint8))
    y = jnp.asarray(y)
    n = x.shape[0]
    rng = np.random.default_rng(cfg.seed)
    for _ in range(cfg.epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i : i + batch_size]
            params = _sgd_batch(params, x[idx], y[idx], cfg.lr)
    return jax.tree.map(lambda a: np.asarray(a), params)


def accuracy(predict_fn, x_uint8: np.ndarray, y: np.ndarray) -> float:
    """Paper's accuracy metric: fraction of argmax predictions correct."""
    preds = np.asarray(predict_fn(jnp.asarray(x_uint8)))
    return float(np.mean(preds == np.asarray(y)))


def predict_l0(params: dict):
    """Baseline predictor (L0): float sigmoid net on scaled inputs."""
    frozen = {k: jnp.asarray(v) for k, v in params.items()}

    @jax.jit
    def f(x_uint8):
        out = forward(frozen, scale_inputs(x_uint8))
        return jnp.argmax(out, axis=-1)

    return f
