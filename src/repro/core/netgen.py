"""netgen — compatibility shim over the `repro.netgen` compiler.

The paper's "hardware generation" step (walk the trained weight
matrices, apply the L4/L5 structural rewrites, print a clockless Verilog
netlist) used to live here as a hardwired 2-layer implementation. It is
now a real compiler in `repro.netgen`: a typed circuit IR, a pass
pipeline with per-pass statistics, and pluggable backends (verilog /
jnp / pallas / fused). See that package's docstring for the
paper-section map.

Since the Session redesign, `repro.netgen`'s front door is
`netgen.Session(...).compile(net, target=..., pipeline=...)` — this shim
(like the deprecated `netgen.compile_net`) routes through the package's
default Session, so repeated shim calls reuse its in-memory tier.

This module keeps the original entry points working, now for nets of any
depth:

  * `emit_verilog`  — the faithful artifact: paper Figure-6 style module,
    byte-identical to the old emitter for the 2-layer paper net.
  * `specialize`    — the TPU-native artifact: a jitted adds-only
    inference function with the integer weights as program constants.
  * `prune`/`stats` — the old flat resource model, computed by running
    the IR passes (use `repro.netgen.run_pipeline` for per-pass stats).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import netgen as _ng
from repro.core.quantize import QuantizedNet


# ---------------------------------------------------------------------------
# L4: structural pruning (exact rewrites only)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PruneInfo:
    n_hidden_before: int
    n_hidden_after: int
    dead_inputs: int            # input pixels ignored by every hidden node
    zero_w1: int                # zeros left inside the first weight matrix
    zero_w2: int                # zeros left inside the last weight matrix

    @property
    def hidden_removed(self) -> int:
        return self.n_hidden_before - self.n_hidden_after

def _n_hidden(circuit: _ng.Circuit) -> int:
    depth = circuit.depth
    return sum(1 for n in circuit.by_kind(_ng.WeightedSum) if n.layer < depth)


def prune(net: QuantizedNet) -> tuple[QuantizedNet, PruneInfo]:
    """Remove structurally dead hidden units (any depth). Exact rewrite:
    a unit with no nonzero input weights is constant 0 and vanishes
    downstream; a unit with no nonzero output weights is never read.
    Per-entry zeros inside surviving rows/cols stay as zeros in the dense
    arrays (the generated programs skip them term by term)."""
    circuit = _ng.lower(net)
    before = _n_hidden(circuit)
    circuit, _ = _ng.run_pipeline(circuit, _ng.DEFAULT_PASSES)
    ws = _ng.as_layered_weights(circuit)
    info = PruneInfo(
        n_hidden_before=before,
        n_hidden_after=_n_hidden(circuit),
        dead_inputs=int(np.sum(np.all(ws[0] == 0, axis=1))),
        zero_w1=int(np.sum(ws[0] == 0)),
        zero_w2=int(np.sum(ws[-1] == 0)),
    )
    pruned = QuantizedNet(weights=ws, input_threshold=circuit.input_threshold)
    return pruned, info


# ---------------------------------------------------------------------------
# Resource model (the paper's logic-cell counting, in arithmetic-op units)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetgenStats:
    """Flat op counts for one generated network, per prediction. The
    pass-pipeline successor is `repro.netgen.PassStats` (per-pass)."""
    mults_dense: int        # naive: one multiply per weight
    adds_dense: int
    mults_pruned: int       # after zero-weight deletion (still multiplying)
    adds_pruned: int
    mults_addend: int       # after the multiplication-free rewrite (== 0)
    adds_addend: int        # one add per |w| unit: sum(|w|) over nonzeros
    zero_fraction: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def stats(net: QuantizedNet) -> NetgenStats:
    circuit = _ng.lower(net)
    dense = _ng.ops(circuit)
    nz = _ng.ops(_ng.delete_zero_terms(circuit))
    return NetgenStats(
        mults_dense=dense.terms,
        adds_dense=dense.terms,          # accumulator adds
        mults_pruned=nz.terms,
        adds_pruned=nz.terms,
        mults_addend=0,                  # the point of L5
        adds_addend=nz.addend_units,
        zero_fraction=1.0 - nz.terms / dense.terms,
    )


# ---------------------------------------------------------------------------
# Faithful artifact: Verilog emission (paper Figures 6/7)
# ---------------------------------------------------------------------------

def emit_verilog(net: QuantizedNet, *, addend: bool = True,
                 module_name: str = "nn_inference") -> str:
    """Emit a clockless combinational Verilog module for the whole net.

    For 2-layer nets this reproduces the paper's Figure 6 byte-for-byte
    (wires, comparator assigns, weight sums, MSB step, priority-mux
    argmax); deeper or CSE-rewritten nets use the generic style of
    `repro.netgen.backends.verilog`.
    """
    return _ng.emit_verilog(net, addend=addend, module_name=module_name)


# ---------------------------------------------------------------------------
# TPU-native artifact: specialized jitted inference function
# ---------------------------------------------------------------------------

def specialize(net: QuantizedNet, *, backend: str = "jnp"):
    """Generate the specialized inference function for a frozen net.

    The weights are embedded as program constants (XLA literals) — the
    analogue of the paper's weights-as-wiring — after the exact pruning
    passes. Arithmetic is adds-only.

    backend: "jnp" (oracle), "pallas" (TPU kernel chain, interpret-mode
             on CPU), "fused" (whole-net single Pallas launch — the
             combinational-circuit analogue; 2-layer nets only).
    """
    return _ng.specialize(net, backend=backend)
