"""netgen — the paper's "hardware generation" step, adapted to TPU.

The paper's Python script walks the trained weight matrices and emits a
clockless Verilog netlist (one `assign` per node), applying two purely
structural rewrites on the way:

  L4  zero-weight pruning      — terms with w == 0 are deleted from the
                                 generated program (paper: ~50% cell cut)
  L5  multiplication-free form — `w*x` with x in {0,1} becomes |w| repeated
                                 addends of x (paper: 38k -> <16k cells)

This module reproduces that step twice over:

  * `emit_verilog`  — the faithful artifact: a Verilog module in the exact
    style of the paper's Figure 6 (wires, comparator assigns, weight sums,
    priority-mux argmax), with pruning and the addend rewrite applied.
  * `specialize`    — the TPU-native artifact: a jitted inference function
    in which the integer weights are *constants of the program* (XLA sees
    them as literals, the analogue of weights-as-wiring), dead hidden units
    are structurally removed, and the arithmetic is the masked column-sum
    (adds only — no multiplies) via the Pallas `binary_matvec` kernel or a
    jnp reference path.
  * `stats`         — the resource model: the paper counts logic cells; we
    count multiplies / adds / addend terms before and after each rewrite,
    which is the quantity the paper's cell counts are proportional to.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import QuantizedNet


# ---------------------------------------------------------------------------
# L4: structural pruning (exact rewrites only)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PruneInfo:
    n_hidden_before: int
    n_hidden_after: int
    dead_inputs: int            # input pixels ignored by every hidden node
    zero_w1: int
    zero_w2: int

    @property
    def hidden_removed(self) -> int:
        return self.n_hidden_before - self.n_hidden_after


def prune(net: QuantizedNet) -> tuple[QuantizedNet, PruneInfo]:
    """Remove structurally dead hidden units. Exact rewrite:

    * hidden unit j with w1[:, j] all zero: hi_j = 0, step(0) = 0, so it
      contributes nothing downstream -> delete column j and row j of w2.
    * hidden unit j with w2[j, :] all zero: its output is multiplied by
      zero everywhere -> delete likewise.

    Per-entry zeros inside surviving rows/cols are counted (they are what
    the paper deletes term-by-term in the generated netlist) and skipped
    by the generated program; the dense arrays keep them as zeros.
    """
    w1, w2 = net.w1, net.w2
    alive = ~((np.all(w1 == 0, axis=0)) | (np.all(w2 == 0, axis=1)))
    w1p, w2p = w1[:, alive], w2[alive, :]
    info = PruneInfo(
        n_hidden_before=w1.shape[1],
        n_hidden_after=int(alive.sum()),
        dead_inputs=int(np.sum(np.all(w1p == 0, axis=1))),
        zero_w1=int(np.sum(w1p == 0)),
        zero_w2=int(np.sum(w2p == 0)),
    )
    return QuantizedNet(w1=w1p, w2=w2p, input_threshold=net.input_threshold), info


# ---------------------------------------------------------------------------
# Resource model (the paper's logic-cell counting, in arithmetic-op units)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NetgenStats:
    """Op counts for one generated network, per prediction."""
    mults_dense: int        # naive: one multiply per weight
    adds_dense: int
    mults_pruned: int       # after zero-weight deletion (still multiplying)
    adds_pruned: int
    mults_addend: int       # after the multiplication-free rewrite (== 0)
    adds_addend: int        # one add per |w| unit: sum(|w|) over nonzeros
    zero_fraction: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def stats(net: QuantizedNet) -> NetgenStats:
    ws = [net.w1, net.w2]
    total = sum(w.size for w in ws)
    nnz = sum(int(np.count_nonzero(w)) for w in ws)
    addends = sum(int(np.abs(w).sum()) for w in ws)
    return NetgenStats(
        mults_dense=total,
        adds_dense=total,                # accumulator adds
        mults_pruned=nnz,
        adds_pruned=nnz,
        mults_addend=0,                  # the point of L5
        adds_addend=addends,
        zero_fraction=1.0 - nnz / total,
    )


# ---------------------------------------------------------------------------
# Faithful artifact: Verilog emission (paper Figures 6/7)
# ---------------------------------------------------------------------------

def _acc_width(w: np.ndarray) -> int:
    """Bit width for a signed accumulator of one output node."""
    bound = int(np.abs(w).sum(axis=0).max()) + 1
    return max(int(np.ceil(np.log2(bound + 1))) + 1, 2)


def _sum_expr(col: np.ndarray, names: list[str], addend: bool) -> str:
    """Expression for one node: sum of weighted inputs, pruned, optionally
    in multiplication-free addend form (w=3 -> x+x+x; negatives subtract)."""
    units: list[tuple[int, str]] = []  # (sign, name-or-term)
    for i, w in enumerate(col):
        w = int(w)
        if w == 0:
            continue  # L4: pruned at generation time
        name = names[i]
        if addend:
            units.extend((1 if w > 0 else -1, name) for _ in range(abs(w)))
        else:
            term = f"{abs(w)}*{name}" if abs(w) != 1 else name
            units.append((1 if w > 0 else -1, term))
    if not units:
        return "0"
    parts = [units[0][1] if units[0][0] > 0 else f"-{units[0][1]}"]
    for sign, term in units[1:]:
        parts.append(("+ " if sign > 0 else "- ") + term)
    return " ".join(parts)


def emit_verilog(net: QuantizedNet, *, addend: bool = True,
                 module_name: str = "nn_inference") -> str:
    """Emit a clockless combinational Verilog module for the whole net.

    Structure mirrors the paper's Figure 6 exactly:
      wires -> input comparators -> hidden-input sums -> MSB step ->
      final-input sums -> priority-mux argmax prediction.
    The MSB trick from §V.D is applied: the step activation is the negated
    sign bit of the signed accumulator, not a LUT.
    """
    w1, w2 = net.w1, net.w2
    n_in, n_h = w1.shape
    n_out = w2.shape[1]
    bw1, bw2 = _acc_width(w1), _acc_width(w2)
    pw = max(int(np.ceil(np.log2(n_out))), 1)

    L: list[str] = []
    L.append(f"// Auto-generated by repro.core.netgen — do not edit.")
    L.append(f"// {n_in}-{n_h}-{n_out} feed-forward classifier, clockless.")
    L.append(f"module {module_name} (")
    L.append("    input  wire [7:0] " + ", ".join(f"px{i}" for i in range(n_in)) + ",")
    L.append(f"    output wire [{pw-1}:0] prediction")
    L.append(");")
    L.append(f"  wire " + ", ".join(f"in{i}" for i in range(n_in)) + ";")
    L.append(f"  wire signed [{bw1-1}:0] " + ", ".join(f"hi{j}" for j in range(n_h)) + ";")
    L.append(f"  wire " + ", ".join(f"ho{j}" for j in range(n_h)) + ";")
    L.append(f"  wire signed [{bw2-1}:0] " + ", ".join(f"fi{k}" for k in range(n_out)) + ";")
    L.append("")
    L.append("  // input comparators (paper L2: pixel > threshold)")
    for i in range(n_in):
        L.append(f"  assign in{i} = (px{i} > {net.input_threshold}) ? 1'b1 : 1'b0;")
    L.append("")
    L.append("  // hidden-input sums (L4 pruned" + (", L5 addend form)" if addend else ")"))
    in_names = [f"in{i}" for i in range(n_in)]
    for j in range(n_h):
        L.append(f"  assign hi{j} = {_sum_expr(w1[:, j], in_names, addend)};")
    L.append("")
    L.append("  // step activation via sign bit (paper §V.D MSB trick)")
    for j in range(n_h):
        L.append(f"  assign ho{j} = ~hi{j}[{bw1-1}];")
    L.append("")
    L.append("  // final-input sums")
    ho_names = [f"ho{j}" for j in range(n_h)]
    for k in range(n_out):
        L.append(f"  assign fi{k} = {_sum_expr(w2[:, k], ho_names, addend)};")
    L.append("")
    L.append("  // prediction: index of the maximum final input (paper Figure 6 line 15)")
    expr = _argmax_mux(n_out, pw)
    L.append(f"  assign prediction = {expr};")
    L.append("endmodule")
    return "\n".join(L) + "\n"


def _argmax_mux(n_out: int, pw: int) -> str:
    """Priority chain of comparators computing argmax(fi_0..fi_{n-1}).

    The paper encodes this comparison network in a single wide LUT
    (18 inputs for its 3x6-bit example); we emit the equivalent flat
    nested-ternary chain, generalized to n_out outputs."""
    expr = f"{pw}'d{n_out-1}"
    for k in range(n_out - 2, -1, -1):
        conds = " && ".join(f"fi{k} >= fi{m}" for m in range(k + 1, n_out))
        expr = f"(({conds}) ? {pw}'d{k} : {expr})"
    return expr


# ---------------------------------------------------------------------------
# TPU-native artifact: specialized jitted inference function
# ---------------------------------------------------------------------------

def specialize(net: QuantizedNet, *, backend: str = "jnp"):
    """Generate the specialized inference function for a frozen net.

    The weights are embedded as program constants (XLA literals) — the
    analogue of the paper's weights-as-wiring. Arithmetic is adds-only:
    with x in {0,1}, `x @ W == sum of W rows where x==1`, realized as a
    masked accumulate (jnp `where`+sum) or the Pallas binary_matvec kernel.

    backend: "jnp" (oracle), "pallas" (TPU kernel, interpret-mode on CPU),
             "fused" (whole-net single Pallas launch — the combinational-
             circuit analogue).
    """
    netp, _ = prune(net)
    w1 = jnp.asarray(netp.w1, jnp.int32)
    w2 = jnp.asarray(netp.w2, jnp.int32)
    thr = netp.input_threshold

    if backend == "jnp":
        @jax.jit
        def predict(x_uint8):
            x = (x_uint8.astype(jnp.int32) > thr)
            # masked column-sum: adds only, no multiplies
            hi = jnp.sum(jnp.where(x[:, :, None], w1[None], 0), axis=1)
            ho = hi > 0
            fi = jnp.sum(jnp.where(ho[:, :, None], w2[None], 0), axis=1)
            return jnp.argmax(fi, axis=-1)
        return predict

    if backend == "pallas":
        from repro.kernels.binary_matvec import ops as bmv

        @jax.jit
        def predict(x_uint8):
            x = (x_uint8.astype(jnp.int32) > thr).astype(jnp.int8)
            hi = bmv.binary_matmul(x, w1)
            ho = (hi > 0).astype(jnp.int8)
            fi = bmv.binary_matmul(ho, w2)
            return jnp.argmax(fi, axis=-1)
        return predict

    if backend == "fused":
        from repro.kernels.fused_mlp import ops as fused

        @jax.jit
        def predict(x_uint8):
            return fused.fused_mlp_predict(x_uint8, w1, w2, threshold=thr)
        return predict

    raise ValueError(f"unknown backend {backend!r}")
