"""int8 gradient compression with error feedback.

For cross-pod data parallelism the gradient all-reduce crosses the slowest
link (DCN / inter-pod ICI). Quantizing the summand to int8 with per-block
scales cuts those bytes 4x vs fp32 (2x vs bf16); the quantization error is
carried in a local error-feedback buffer and re-added next step, which
keeps SGD convergence (error feedback makes the compression unbiased in
the long run — Karimireddy et al. 2019).

`compressed_psum` is built for use inside shard_map where the data/pod
axis is manual: quantize -> psum int32 -> dequantize. The model axis stays
in GSPMD's hands (auto axes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 2048


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8. Returns (q int8 (n_blocks, BLOCK), scales)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    amax = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(x: jnp.ndarray, err: jnp.ndarray):
    """One error-feedback round locally (used in tests and to model the
    lossy channel): returns (what the wire carries decoded, new error)."""
    xc = x.astype(jnp.float32) + err
    q, s = quantize_int8(xc)
    decoded = dequantize_int8(q, s, x.shape, jnp.float32)
    new_err = xc - decoded
    return decoded.astype(x.dtype), new_err


def compressed_psum(x: jnp.ndarray, axis_name: str, err: jnp.ndarray):
    """int8-compressed psum over `axis_name` with error feedback.
    Returns (psum result (approx), new local error buffer)."""
    xc = x.astype(jnp.float32) + err
    q, s = quantize_int8(xc)
    # each participant contributes int8 * its scale; sum in int32 would need
    # a shared scale, so we psum the dequantized-but-int8-rounded values:
    # wire bytes ~= int8 payload + per-block fp32 scale (amortized 1/2048).
    decoded = dequantize_int8(q, s, x.shape, jnp.float32)
    new_err = xc - decoded
    total = jax.lax.psum(decoded, axis_name)
    return total.astype(x.dtype), new_err
