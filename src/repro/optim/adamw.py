"""AdamW with fully-sharded optimizer state (ZeRO-3-equivalent under
GSPMD: m/v/master inherit the parameters' fsdp x TP sharding specs), global
gradient clipping, and a warmup-cosine schedule.

fp32 master params + fp32 moments; the forward casts to bf16 at use sites
(mixed precision, MaxText-style).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.base import ParamInfo, is_info


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def abstract_opt_state(abstract_params) -> dict:
    """m/v mirror the parameter tree (same shapes, logical axes)."""
    def zero_like(i: ParamInfo) -> ParamInfo:
        return ParamInfo(i.shape, jnp.float32, i.logical, init="zeros")

    return {
        "m": jax.tree.map(zero_like, abstract_params, is_leaf=is_info),
        "v": jax.tree.map(zero_like, abstract_params, is_leaf=is_info),
        "step": ParamInfo((), jnp.int32, (), init="zeros"),
    }


def schedule(oc: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(oc.warmup_steps, 1)
    prog = jnp.clip((s - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos
    return oc.lr * jnp.where(s < oc.warmup_steps, warm, decayed)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt_state, oc: OptConfig):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(oc, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)

    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
