"""Llama-3.2-3B [dense] — GQA kv=8, tied embeddings, small llama3.
[hf:meta-llama/Llama-3.2-3B; unverified]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    act="swiglu",
    norm="rmsnorm",
    norm_eps=1e-5,
    tie_embeddings=True,
    rope_theta=5.0e5,
)
