"""Mamba2-2.7B [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    norm="rmsnorm",
    norm_eps=1e-5,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
)
