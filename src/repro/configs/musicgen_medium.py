"""MusicGen-medium [audio] — decoder-only over EnCodec tokens; the EnCodec
frontend is a STUB (`input_specs` provides precomputed frame embeddings;
backbone only per assignment). LayerNorm + GELU + sinusoidal positions.
[arXiv:2306.05284; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="dense",
    modality="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    act="gelu",
    norm="layernorm",
    norm_eps=1e-5,
    pos="sin",
)
