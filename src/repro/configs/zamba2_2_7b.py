"""Zamba2-2.7B [hybrid] — Mamba2 backbone + shared attention blocks
(54 mamba layers, shared attn+MLP applied every 6). [arXiv:2411.15242; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    act="gelu",
    norm="rmsnorm",
    norm_eps=1e-5,
    rope_theta=1.0e4,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
    attn_every=6,
)
