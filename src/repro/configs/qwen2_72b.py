"""Qwen2-72B [dense] — GQA kv=8, QKV bias. [arXiv:2407.10671; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    act="swiglu",
    norm="rmsnorm",
    norm_eps=1e-6,
    qkv_bias=True,
    rope_theta=1.0e6,
)
