"""Qwen1.5-4B [dense] — QKV bias, full-head GQA (kv == heads).
[hf:Qwen/Qwen1.5-4B; hf-verified family config]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    act="swiglu",
    norm="rmsnorm",
    norm_eps=1e-6,
    qkv_bias=True,
    rope_theta=5.0e6,
)
