"""Architecture config registry.

`get_config(name)` returns the full published config; `smoke(name)` a
reduced same-family variant for CPU tests (small widths/depths/vocabs,
same structural features: GQA ratios, MoE routing, SSD state, hybrid
sharing)."""
from __future__ import annotations

import dataclasses

from repro.models.base import ArchConfig, SHAPES, ShapeConfig, supports_shape

from repro.configs import (  # noqa: F401
    qwen1_5_4b, qwen2_72b, gemma_2b, llama3_2_3b, qwen2_vl_2b,
    granite_moe_1b_a400m, qwen3_moe_30b_a3b, mamba2_2_7b, zamba2_2_7b,
    musicgen_medium, mnist_fpga,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen1_5_4b, qwen2_72b, gemma_2b, llama3_2_3b, qwen2_vl_2b,
        granite_moe_1b_a400m, qwen3_moe_30b_a3b, mamba2_2_7b, zamba2_2_7b,
        musicgen_medium,
    )
}


def get_config(name: str) -> ArchConfig:
    return ARCHS[name]


def smoke(name: str) -> ArchConfig:
    """Reduced config preserving the family's structure."""
    c = ARCHS[name]
    kv = max(1, (4 * c.n_kv_heads) // max(c.n_heads, 1)) if c.n_heads else 0
    repl: dict = dict(
        name=c.name + "-smoke",
        n_layers=4 if c.family == "hybrid" else 2,
        d_model=64,
        n_heads=4 if c.n_heads else 0,
        n_kv_heads=kv,
        head_dim=16 if c.n_heads else 0,
        d_ff=96 if c.d_ff else 0,
        vocab=512,
    )
    if c.family == "moe":
        repl.update(n_experts=8, experts_per_token=2)
    if c.family in ("ssm", "hybrid"):
        repl.update(ssm_state=16, ssm_headdim=16, ssm_groups=1)
    if c.family == "hybrid":
        repl.update(attn_every=2)
    if c.pos == "mrope":
        repl.update(mrope_sections=(2, 3, 3))     # sums to head_dim//2 = 8
    return dataclasses.replace(c, **repl)


def all_cells() -> list[tuple[ArchConfig, ShapeConfig]]:
    """Every supported (architecture x input-shape) pair (the dry-run grid)."""
    cells = []
    for cfg in ARCHS.values():
        for shp in SHAPES.values():
            if supports_shape(cfg, shp):
                cells.append((cfg, shp))
    return cells
