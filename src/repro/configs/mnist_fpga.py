"""The paper's own network: 784-500-10 feed-forward MNIST classifier
(Adiletta & Flanagan 2020). Kept in the registry so the paper's technique
is a first-class selectable arch next to the assigned LM configs; its
pipeline lives in repro.core (training, quantization ladder, netgen)."""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="mnist-fpga",
    family="mlp",           # handled by repro.core, not the LM runtime
    n_layers=2,
    d_model=500,            # hidden width
    vocab=10,               # output classes
)
