"""Qwen3-30B-A3B [moe] — 128 experts, top-8, GQA kv=4, head_dim=128.
(Qwen3's q/k RMSNorm is omitted — it does not change sharding or roofline
structure; noted in DESIGN.md §7.) [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,                  # per-expert FFN width
    vocab=151936,
    act="swiglu",
    norm="rmsnorm",
    norm_eps=1e-6,
    rope_theta=1.0e6,
    n_experts=128,
    experts_per_token=8,
    moe_norm_topk=True,
)
