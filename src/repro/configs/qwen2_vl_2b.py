"""Qwen2-VL-2B [vlm] — M-RoPE, dynamic-resolution vision frontend (STUB:
`input_specs` provides precomputed patch embeddings; backbone only per
assignment). [arXiv:2409.12191; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="dense",
    modality="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    act="swiglu",
    norm="rmsnorm",
    norm_eps=1e-6,
    qkv_bias=True,
    tie_embeddings=True,
    pos="mrope",
    mrope_sections=(16, 24, 24),   # t/h/w half-dim sections, sum = hd//2
    rope_theta=1.0e6,
)
