"""Granite-3.0-1B-A400M [moe] — 32 experts, top-8, GQA kv=8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,                  # per-expert FFN width
    vocab=49155,
    act="swiglu",
    norm="rmsnorm",
    norm_eps=1e-6,
    tie_embeddings=True,
    rope_theta=1.0e4,
    n_experts=32,
    experts_per_token=8,
)
