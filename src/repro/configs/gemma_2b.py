"""Gemma-2B [dense] — GeGLU, head_dim=256, MQA (kv=1), tied + scaled
embeddings, (1+w) RMSNorm. [arXiv:2403.08295; hf]"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    act="geglu",
    norm="rmsnorm",
    norm_eps=1e-6,
    tie_embeddings=True,
    scale_embedding=True,
    rope_theta=1.0e4,
)
