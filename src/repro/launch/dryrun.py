import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the very first statements — jax locks the
device count at first initialization, and the production meshes need 512
placeholder host devices (and ONLY the dry-run may see them; tests and
benches run with 1 device).

Per cell this driver runs two kinds of lowerings:

  PRODUCTION (scan-over-layers, full grad-accum): proves the real artifact
  compiles on the mesh; memory_analysis() proves fit; post-opt HLO gives
  the collective schedule.

  ANALYSIS (multi-point, layer scans unrolled): XLA cost analysis counts
  while bodies ONCE, so flops/bytes/collectives from the production graph
  under-count by the trip counts. We therefore lower small unrolled
  variants — train: (L, accum) in {L1,L2}x{1,2}; serve: L in {L1,L2} —
  and solve the linear cost model
      cost(L, accum) = accum*(L*layer_micro + head_micro) + L*layer_opt + g
  for exact per-step totals, then add analytic corrections for the
  per-layer inner scans (flash blocks / SSD chunks) that remain rolled.

Usage:
  python -m repro.launch.dryrun --mesh single_pod [--arch A] [--shape S]
  python -m repro.launch.dryrun --mesh multi_pod  --arch qwen2-72b
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs
from repro.data import specs as specs_lib
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import api, runtime
from repro.models.base import tree_sds
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.serve.engine import make_serve_step
from repro.train import step as step_lib

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")


def _rules_for(mesh) -> dict:
    if "pod" in mesh.shape:
        return {}                       # default rules already include pod
    return {"batch": ("data",)}


def _serve_params_sds(cfg, variant: dict):
    """Abstract serving params under a variant: optional dtype cast
    (fp32 master -> bf16 serving copy) and/or W8 int8 specialization."""
    import dataclasses as _dc
    import jax.numpy as jnp
    from repro.models.base import ParamInfo, is_info
    if variant.get("quant"):
        from repro.quantized.apply import abstract_quantized_params
        tree = abstract_quantized_params(cfg)
    else:
        tree = api.abstract_params(cfg)
    dt = variant.get("serve_dtype")
    if dt:
        def cast(i: ParamInfo) -> ParamInfo:
            if i.dtype == jnp.float32 and len(i.shape) >= 2:
                return _dc.replace(i, dtype=jnp.dtype(dt))
            return i
        tree = jax.tree.map(cast, tree, is_leaf=is_info)
    return tree_sds(tree)


def build_lowered(cfg, shape, mesh, *, remat: str = "full",
                  variant: dict | None = None):
    """Lower one cell's step on `mesh` (no compile). `variant` is the
    perf-hillclimb switchboard: {"flags": runtime flags, "rules": logical
    rule overrides, "serve_dtype": "bfloat16", "quant": True}."""
    from repro.models import runtime as rt
    variant = variant or {}
    rules = dict(_rules_for(mesh))
    rules.update(variant.get("rules", {}))
    with rt.with_flags(**variant.get("flags", {})), shd.use_mesh(mesh, rules):
        if shape.kind == "train":
            oc = adamw.OptConfig()
            train_step = step_lib.make_train_step(cfg, shape, oc, remat=remat)
            state_sds = tree_sds(step_lib.abstract_state(cfg))
            batch_sds = specs_lib.input_specs(cfg, shape)
            with mesh:
                return jax.jit(train_step, donate_argnums=(0,)).lower(
                    state_sds, batch_sds)
        if shape.kind == "prefill":
            params_sds = _serve_params_sds(cfg, variant)
            cache_sds = tree_sds(api.abstract_cache(
                cfg, shape.global_batch, shape.seq_len))
            batch_sds = specs_lib.input_specs(cfg, shape)

            def prefill_fn(params, batch, cache):
                return api.prefill(cfg, params, batch, cache)

            with mesh:
                return jax.jit(prefill_fn, donate_argnums=(2,)).lower(
                    params_sds, batch_sds, cache_sds)
        if shape.kind == "decode":
            params_sds = _serve_params_sds(cfg, variant)
            cache_sds = tree_sds(api.abstract_cache(
                cfg, shape.global_batch, shape.seq_len))
            io = specs_lib.input_specs(cfg, shape)
            serve_step = make_serve_step(cfg)
            with mesh:
                return jax.jit(serve_step, donate_argnums=(1,)).lower(
                    params_sds, cache_sds, io["tokens"], io["pos"])
        raise ValueError(shape.kind)


def _extract(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    colls = rl.collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(v for k, v in colls.items() if not k.startswith("_"))),
        "breakdown": colls,
    }


def _reduced(cfg, n_layers: int):
    return dataclasses.replace(cfg, n_layers=n_layers)


def _analysis_Ls(cfg) -> tuple:
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every
    return 2, 4


def analyze_cell(cfg, shape, mesh, *, remat: str = "full",
                 variant: dict | None = None) -> dict:
    """Multi-point unrolled lowerings -> exact per-step cost totals."""
    L1, L2 = _analysis_Ls(cfg)

    def measure(L, accum_override=None, batch_override=None):
        c = _reduced(cfg, L)
        s = shape
        if accum_override is not None:
            s = dataclasses.replace(shape, accum=accum_override,
                                    global_batch=batch_override)
        with runtime.unrolled_scans():
            lowered = build_lowered(c, s, mesh, remat=remat, variant=variant)
            return _extract(lowered.compile())

    out = {}
    if shape.kind == "train":
        micro = shape.global_batch // shape.accum
        A = measure(L1, 1, micro)
        B = measure(L2, 1, micro)
        C = measure(L1, 2, 2 * micro)
        D = measure(L2, 2, 2 * micro)
        dL = L2 - L1
        for key in ("flops", "bytes", "coll"):
            lm = ((D[key] - C[key]) - (B[key] - A[key])) / dL
            hm = (C[key] - A[key]) - L1 * lm
            lo = (B[key] - A[key]) / dL - lm
            g = A[key] - (L1 * lm + hm) - L1 * lo
            out[key] = (shape.accum * (cfg.n_layers * lm + hm)
                        + cfg.n_layers * lo + g)
        corr_batch = micro
        scale_corr = shape.accum
    else:
        A = measure(L1)
        B = measure(L2)
        dL = L2 - L1
        for key in ("flops", "bytes", "coll"):
            per_layer = (B[key] - A[key]) / dL
            out[key] = A[key] + (cfg.n_layers - L1) * per_layer
        corr_batch = shape.global_batch
        scale_corr = 1

    corr = rl.inner_scan_corrections(
        cfg, batch=corr_batch, seq=shape.seq_len, kind=shape.kind)
    chips = mesh.devices.size
    out["flops"] += scale_corr * corr["flops"] / chips
    out["bytes"] += scale_corr * corr["bytes"] / chips
    out["corrections_per_device"] = {
        k: scale_corr * v / chips for k, v in corr.items()}
    return out


def run_cell(cfg, shape, mesh, *, remat: str = "full", analysis: bool = True,
             verbose: bool = True, variant: dict | None = None) -> tuple:
    """Production compile + (optional) analysis. Returns (record, meta)."""
    lowered = build_lowered(cfg, shape, mesh, remat=remat, variant=variant)
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    raw = _extract(compiled)

    ana = (analyze_cell(cfg, shape, mesh, remat=remat, variant=variant)
           if analysis else None)
    eff = ana if ana is not None else raw

    chips = mesh.devices.size
    record = rl.Roofline(
        arch=cfg.name,
        shape=shape.name,
        mesh="x".join(str(s) for s in mesh.devices.shape),
        chips=chips,
        flops_per_device=eff["flops"],
        bytes_per_device=eff["bytes"],
        collective_bytes=eff["coll"],
        collective_breakdown=raw["breakdown"],
        model_flops=rl.model_flops(cfg, shape),
        # memory_analysis (like cost_analysis) reports PER-DEVICE numbers
        # on a GSPMD-partitioned executable.
        peak_mem_per_device=float(
            (mem.argument_size_in_bytes + mem.output_size_in_bytes
             - mem.alias_size_in_bytes + mem.temp_size_in_bytes)),
    )
    meta = {
        "compile_s": compile_s,
        "arg_bytes": mem.argument_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "out_bytes": mem.output_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "raw_scan_counted_once": raw,
    }
    if verbose:
        print(f"  memory_analysis: args={meta['arg_bytes']/2**30:.2f}GiB "
              f"temp={meta['temp_bytes']/2**30:.2f}GiB "
              f"alias={meta['alias_bytes']/2**30:.2f}GiB "
              f"-> peak/device={record.peak_mem_per_device/2**30:.3f}GiB")
        print(f"  per-step/device: flops={record.flops_per_device:.3e} "
              f"bytes={record.bytes_per_device:.3e} "
              f"coll={record.collective_bytes:.3e} "
              f"({raw['breakdown'].get('_num_ops', 0)} coll ops in HLO)")
        print(f"  roofline: t_comp={record.t_compute*1e3:.2f}ms "
              f"t_mem={record.t_memory*1e3:.2f}ms "
              f"t_coll={record.t_collective*1e3:.2f}ms "
              f"bottleneck={record.bottleneck} "
              f"frac={record.roofline_fraction:.3f} "
              f"useful={record.useful_flops_ratio:.3f}")
    return record, meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single_pod", "multi_pod"],
                    default="single_pod")
    ap.add_argument("--arch", default=None, help="run one arch only")
    ap.add_argument("--shape", default=None, help="run one shape only")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-analysis", action="store_true",
                    help="production compile only (multi-pod proof runs)")
    ap.add_argument("--serve-opt", action="store_true",
                    help="serve cells use the optimized inference config "
                         "(bf16 serving copy, TP-only weights — §Perf)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi_pod"))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, f"dryrun_{args.mesh}{args.tag}.json")
    done: dict[str, dict] = {}
    if os.path.exists(out_path) and not args.force:
        with open(out_path) as f:
            done = {r["cell"]: r for r in json.load(f)}

    cells = configs.all_cells()
    if args.arch:
        cells = [(c, s) for c, s in cells if c.name == args.arch]
    if args.shape:
        cells = [(c, s) for c, s in cells if s.name == args.shape]

    n_fail = 0
    for cfg, shape in cells:
        key = f"{cfg.name}/{shape.name}"
        if key in done and done[key].get("ok"):
            print(f"[skip] {key}")
            continue
        print(f"[cell] {key} on {args.mesh} "
              f"(B={shape.global_batch}, S={shape.seq_len}, {shape.kind})",
              flush=True)
        t0 = time.time()
        variant = None
        if args.serve_opt and shape.kind in ("prefill", "decode"):
            variant = {"serve_dtype": "bfloat16", "rules": {"fsdp": ()}}
        try:
            record, meta = run_cell(cfg, shape, mesh, remat=args.remat,
                                    analysis=not args.no_analysis,
                                    variant=variant)
            done[key] = {"cell": key, "ok": True, **record.as_dict(), **meta}
        except Exception as e:  # noqa: BLE001 — record and continue the sweep
            traceback.print_exc()
            done[key] = {"cell": key, "ok": False,
                         "error": f"{type(e).__name__}: {e}"}
            n_fail += 1
        print(f"  [{time.time()-t0:.1f}s total]", flush=True)
        with open(out_path, "w") as f:
            json.dump(list(done.values()), f, indent=1, default=float)

    ok = sum(1 for r in done.values() if r.get("ok"))
    print(f"\n== {ok}/{len(done)} cells OK ({n_fail} new failures) -> {out_path}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
