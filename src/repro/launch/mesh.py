"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — required because the dry-run must
set XLA_FLAGS before any jax initialization, while unit tests import the
same code under a single real device.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes) -> jax.sharding.Mesh:
    """`jax.make_mesh` across jax versions: newer releases take (and for
    explicit-sharding meshes need) `axis_types`; older ones (<= 0.4.x)
    reject the kwarg and are implicitly Auto everywhere."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh_compat((data, model), ("data", "model"))


# TPU v5e hardware model used by the roofline (per chip).
HW = {
    "peak_bf16_flops": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link (~4 links/chip on v5e)
}
