"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN/EXPERIMENTS):

    compute    = HLO_FLOPs_per_device / peak_bf16_flops
    memory     = HLO_bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / ici_bw

`cost_analysis()` on a GSPMD-partitioned executable reports PER-DEVICE
flops/bytes (verified empirically: a 512-way sharded matmul reports
total/512). Collective bytes are not in cost_analysis, so we parse the
post-optimization HLO: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op's operand bytes are summed (per-device
traffic; each occurrence in the per-shard module executes once per device).
"""
from __future__ import annotations

import dataclasses
import json
import re

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.MULTILINE)

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64)"
                       r"\[([0-9,]*)\]")


def _head_bytes(line: str, end: int) -> int:
    """Sum output-shape bytes in line[:end] (covers tuple outputs)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(line[:end]):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind byte totals from post-optimization HLO.
    Bytes counted = the op's OUTPUT shapes (the payload crossing links;
    ring/algorithm factors are absorbed into the link-bw constant)."""
    out: dict[str, int] = {}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        b = _head_bytes(line, m.start(1))
        out[kind] = out.get(kind, 0) + b
        count += 1
    out["_num_ops"] = count
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_breakdown: dict
    model_flops: float             # 6*N*D (dense) / 6*N_active*D (moe)
    peak_mem_per_device: float     # bytes (from memory_analysis)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / HW["peak_bf16_flops"]

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HW["hbm_bw"]

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / HW["ici_bw"]

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops x chips): how much compiled compute is
        'useful'. Catches remat recompute and redundant/replicated work."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / max(all terms): the score we hillclimb."""
        t_useful = (self.model_flops / self.chips) / HW["peak_bf16_flops"]
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / bound if bound else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for inference forward, where N = active
    params (excluding embeddings' gather) and D = tokens processed."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def active_param_count(cfg) -> float:
    """Parameters touched per token (MoE counts top-k experts only),
    excluding the embedding table (its gather is O(d), not O(vocab*d)) but
    including the LM head matmul."""
    from repro.models import api  # local import to avoid cycles
    from repro.models.base import count_params
    tree = api.abstract_params(cfg)
    total = count_params(tree)
    emb = cfg.vocab * cfg.d_model
    total -= emb                       # embedding gather
    if cfg.tie_embeddings:
        total += emb                   # tied head still does the matmul
    if cfg.family == "moe":
        moe_params = tree["layers"]["moe"]
        moe_total = count_params({k: v for k, v in moe_params.items()
                                  if k != "router"})
        active = moe_total * cfg.experts_per_token / cfg.n_experts
        total = total - moe_total + active
    return float(total)


def write_report(records: list[dict], path: str):
    with open(path, "w") as f:
        json.dump(records, f, indent=1, default=float)


# ---------------------------------------------------------------------------
# Analytic corrections for inner scans (flash attention, SSD chunk loop).
#
# XLA cost analysis counts a while body once; the LAYER scans are unrolled
# in the analysis lowerings, but the per-layer inner scans (flash blocks,
# SSD chunks) stay rolled — their true totals are added here analytically.
# Conventions: bf16 activations (2B); train includes full-remat recompute
# (fwd happens twice) and the two-pass flash backward.
# ---------------------------------------------------------------------------

Q_BLK, K_BLK = 512, 1024          # must match layers/flash.py defaults
SSD_CHUNK = 128                    # must match layers/mamba2.py default


def flash_correction(cfg, *, batch: int, seq: int, kind: str) -> dict:
    """Per-STEP flash totals for one attention layer x n_attn_layers."""
    if cfg.family in ("dense", "moe"):
        n_attn = cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
    else:
        return {"flops": 0.0, "bytes": 0.0}
    from repro.layers.attention import FLASH_MIN_SEQ
    if seq < FLASH_MIN_SEQ or kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}

    B, S, H, KV, hd = batch, seq, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    U = B * H * S * S * hd           # one qk-sized einsum = 2U flops
    nq, nk = S // min(Q_BLK, S), S // min(K_BLK, S)
    # forward: qk + pv = 4U ; backward pass1 (p,dv,dp,dk) = 8U ;
    # pass2 (p,dp,dq) = 6U ; remat recompute of fwd = 4U
    fwd, bwd, rematf = 4 * U, 14 * U, 4 * U
    flops = fwd + (bwd + rematf if kind == "train" else 0.0)
    qbytes = 2 * B * H * S * hd
    kvbytes = 2 * B * KV * S * hd * 2
    by_fwd = qbytes * 2 + nq * kvbytes          # q,out once; k/v per q-block
    by_bwd = (nk * qbytes * 2 + kvbytes * 2     # pass1: q,do per kv-blk
              + nq * kvbytes + qbytes * 2)      # pass2: k/v per q-blk; dq
    bytes_ = by_fwd + ((by_bwd + by_fwd) if kind == "train" else 0.0)
    return {"flops": float(flops * n_attn), "bytes": float(bytes_ * n_attn)}


def ssd_correction(cfg, *, batch: int, seq: int, kind: str) -> dict:
    """Per-STEP SSD chunk-scan totals across mamba layers."""
    if cfg.family not in ("ssm", "hybrid") or kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    B, S = batch, seq
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    Q = min(SSD_CHUNK, S)
    # per layer fwd: scores 2BSQHN + y_intra 2BSQHP + (y_inter+states) 4BSHNP
    fwd = 2 * B * S * H * (Q * N + Q * P + 2 * N * P) + 3 * B * S * Q * H
    flops = fwd * (4.0 if kind == "train" else 1.0)   # bwd 2x + recompute 1x
    io = 4 * B * S * (H * P + H + 2 * cfg.ssm_groups * N) * 2   # in+out, fp32-ish
    bytes_ = io * (4.0 if kind == "train" else 1.0)
    return {"flops": float(flops * cfg.n_layers), "bytes": float(bytes_ * cfg.n_layers)}


def inner_scan_corrections(cfg, *, batch: int, seq: int, kind: str) -> dict:
    f = flash_correction(cfg, batch=batch, seq=seq, kind=kind)
    s = ssd_correction(cfg, batch=batch, seq=seq, kind=kind)
    return {"flops": f["flops"] + s["flops"], "bytes": f["bytes"] + s["bytes"]}
