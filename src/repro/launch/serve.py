"""Production serving launcher.

  python -m repro.launch.serve --arch qwen1.5-4b --smoke \
      [--batch 8] [--prompt-len 16] [--new-tokens 16] [--w8]

--w8 applies the paper's integer-weight specialization to the checkpoint
before serving (repro.quantized). With --smoke the reduced config runs on
this container; the production path builds the 16x16 mesh and shards
params TP-only (fsdp replicated — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api, base
from repro.parallel import sharding as shd
from repro.quantized import apply as qapply
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--w8", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = configs.smoke(args.arch)
        mesh = make_host_mesh()
        rules = {"batch": ("data",), "fsdp": ()}
    else:
        cfg = configs.get_config(args.arch)
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = ({} if args.multi_pod else {"batch": ("data",)}) | {"fsdp": ()}

    with shd.use_mesh(mesh, rules), mesh:
        params = base.tree_init(api.abstract_params(cfg), jax.random.PRNGKey(0))
        if args.w8:
            params = qapply.quantize_params_for_serving(cfg, params, min_size=0)
            print("serving W8-specialized checkpoint (paper technique)")
        eng = Engine(cfg, params, ServeConfig(
            max_len=args.prompt_len + args.new_tokens + 8,
            max_new_tokens=args.new_tokens))
        prompts = (np.arange(args.batch * args.prompt_len, dtype=np.int32)
                   .reshape(args.batch, args.prompt_len) * 17) % cfg.vocab
        t0 = time.time()
        out = eng.generate(prompts)
        dt = time.time() - t0
    print(f"generated {out.size} tokens in {dt:.2f}s "
          f"({out.size/dt:.1f} tok/s); sample: {out[0][:12].tolist()}")


if __name__ == "__main__":
    main()
