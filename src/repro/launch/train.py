"""Production training launcher.

  python -m repro.launch.train --arch llama3.2-3b --shape train_4k \
      [--smoke] [--steps N] [--resume] [--mesh-data D --mesh-model M]

On this container (1 CPU device) use --smoke, which runs the reduced
same-family config on a trivial mesh — the code path (mesh + sharded
train_step + checkpoint manager + fault tolerance) is identical to the
production one; only the mesh shape differs. On a real cluster the same
entry point builds the 16x16 (or 2x16x16 with --multi-pod) mesh from
`repro.launch.mesh` and proceeds unchanged.
"""
from __future__ import annotations

import argparse

from repro import configs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.base import SHAPES, ShapeConfig
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    if args.smoke:
        cfg = configs.smoke(args.arch)
        shape = ShapeConfig("smoke", seq_len=64, global_batch=4, kind="train")
        mesh = make_host_mesh()
    else:
        cfg = configs.get_config(args.arch)
        shape = SHAPES[args.shape]
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    oc = adamw.OptConfig(lr=args.lr, total_steps=args.steps)
    tc = trainer.TrainerConfig(total_steps=args.steps, ckpt_every=25,
                               ckpt_dir=args.ckpt_dir,
                               remat="none" if args.smoke else "full")
    rules = {"batch": ("data",)} if not args.multi_pod else {}
    with shd.use_mesh(mesh, rules), mesh:
        state, hist = trainer.run(cfg, shape, oc, tc, resume=args.resume)
    if hist["loss"]:
        print(f"steps={len(hist['loss'])} "
              f"loss {hist['loss'][0]:.4f} -> {hist['loss'][-1]:.4f} "
              f"stragglers={len(hist['stragglers'])}")


if __name__ == "__main__":
    main()
