"""Batched serving demo: prefill + decode with the serving engine, plus
the paper's technique applied to the checkpoint (int8 weight
specialization) with quality and size deltas.

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import make_batch
from repro.models import api, base
from repro.quantized import apply as qapply
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = configs.smoke("qwen1.5-4b")
    params = base.tree_init(api.abstract_params(cfg), jax.random.PRNGKey(0))

    print("== batched generation ==")
    eng = Engine(cfg, params, ServeConfig(max_len=128, max_new_tokens=16))
    prompts = (np.arange(32, dtype=np.int32).reshape(8, 4) * 13) % cfg.vocab
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    total_new = out.size
    print(f"batch={prompts.shape[0]} prompt_len={prompts.shape[1]} "
          f"new_tokens={out.shape[1]} -> {total_new/dt:.1f} tok/s (CPU)")
    print("sample:", out[0].tolist())

    print("\n== paper technique on the LM checkpoint (W8 specialization) ==")
    shape = base.ShapeConfig("eval", 64, 4, "train")
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, 0).items()}
    loss_fp, _ = api.loss_fn(cfg, params, batch)
    qt, stats = qapply.quantize_tree(params, min_size=0)
    loss_q, _ = api.loss_fn(cfg, qapply.dequantize_tree(qt), batch)
    print(f"storage: {stats['bytes_before']/1e6:.2f} MB -> "
          f"{stats['bytes_after']/1e6:.2f} MB "
          f"({stats['compression']:.2f}x, {stats['n_quantized']} tensors)")
    print(f"loss: fp32={float(loss_fp):.4f}  int8-weights={float(loss_q):.4f} "
          f"(delta {abs(float(loss_q)-float(loss_fp))/float(loss_fp):.2%})")
    ps = qapply.prune_stats(params)
    print(f"structurally dead channels: {ps['dead_fraction']:.2%} "
          "(netgen would delete these at specialization)")


if __name__ == "__main__":
    main()
