"""End-to-end driver: train a ~120M-parameter LM for a few hundred steps
on the synthetic pipeline, with checkpointing and fault-tolerant resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--resume]

The model is a llama-style dense decoder (12L x 768d, GQA 12/4, 32k
vocab ~ 121M params). On this CPU container a step takes seconds; the
same driver, pointed at the production mesh via repro.launch, is the
multi-pod entry point.
"""
import argparse
import time

from repro.models.base import ArchConfig, ShapeConfig
from repro.optim import adamw
from repro.train import trainer

CFG_100M = ArchConfig(
    name="repro-120m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab=32768,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1e4,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    shape = ShapeConfig("e2e", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    tc = trainer.TrainerConfig(
        total_steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
        log_every=10)

    from repro.models import api
    from repro.models.base import count_params
    n = count_params(api.abstract_params(CFG_100M))
    print(f"model: {CFG_100M.name}, {n/1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step")

    t0 = time.time()
    state, hist = trainer.run(CFG_100M, shape, oc, tc, resume=args.resume)
    dt = time.time() - t0
    losses = hist["loss"]
    print(f"\ntrained {len(losses)} steps in {dt:.0f}s "
          f"({dt/max(len(losses),1):.1f}s/step)")
    if losses:
        k = min(10, len(losses))
        print(f"loss: first{k}={sum(losses[:k])/k:.4f} "
              f"last{k}={sum(losses[-k:])/k:.4f}")
        assert sum(losses[-k:]) < sum(losses[:k]), "loss did not improve"
        print("loss improved ✓  (checkpoints in", tc.ckpt_dir + ")")


if __name__ == "__main__":
    main()
