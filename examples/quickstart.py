"""Quickstart: the paper's pipeline end-to-end in ~1 minute.

Trains the 784-500-10 classifier, walks the optimization ladder
(sigmoid -> step -> binary input -> integer weights), then "generates
hardware": the netgen specializer emits (a) a clockless Verilog module in
the paper's Figure-6 style and (b) a TPU-ready specialized inference
function, and verifies both are exact rewrites.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import netgen, quantize
from repro.core.ladder import run_ladder


def main():
    print("== paper ladder (reduced size for speed; benchmarks run full) ==")
    r = run_ladder(n_train=600, n_test=400, epochs=30, seed=0,
                   backends=("jnp", "pallas"))
    print(r.table())
    print(f"\nL4/L5 exact rewrites of L3: {r.exact_l4_l5}")
    print(f"zero weights pruned at generation: {r.stats.zero_fraction:.1%}")
    print(f"multiplies after addend rewrite:  {r.stats.mults_addend}")

    print("\n== hardware generation (paper Figure 6 artifact) ==")
    rng = np.random.default_rng(0)
    demo = quantize.QuantizedNet(
        w1=rng.integers(-9, 10, size=(3, 3)).astype(np.int32),
        w2=rng.integers(-9, 10, size=(3, 3)).astype(np.int32))
    verilog = netgen.emit_verilog(demo, addend=True)
    print(verilog)
    out = "/tmp/nn_inference_3x3.v"
    with open(out, "w") as f:
        f.write(verilog)
    print(f"[written to {out}]")


if __name__ == "__main__":
    main()
