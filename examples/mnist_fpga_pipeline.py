"""The paper's full pipeline at full size: train 784-500-10, apply the
ladder, compile through the `repro.netgen` Session API (frontend ->
declarative PipelineSpec -> Target), emit the full-network Verilog
artifact, price the circuit with the `cost` target (paper Figure 7),
compare software vs specialized throughput — everything in paper §II-§V
— and finally serve TWO ladder depths through the compile cache: two
trained stacks become registered model versions behind one `NetServer`,
re-registration is a cache hit, and same-topology versions share one
stacked multi-net dispatch.

  PYTHONPATH=src python examples/mnist_fpga_pipeline.py [--fast] [--deep]
      [--store DIR] [--trace DIR]

--deep swaps in a 3-layer hidden stack, which the paper's hardwired
script could not express — the IR compiles it through the same passes
and backends. --store points the Session at a persistent ArtifactStore
directory: a second run (or a second process — CI caches this directory
between workflow runs) warm-starts every compilation from disk.
--trace DIR turns on `repro.netgen.telemetry` span tracing (plus the
jit cost_analysis profiling hook) and writes DIR/trace.jsonl (one JSON
span per line — `benchmarks/check_trace.py` gates CI on it) and
DIR/metrics.prom (Prometheus text exposition), then prints the
telemetry report table.
"""
import argparse
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import dataset, mlp, quantize
from repro import netgen
from repro.netgen import telemetry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--deep", action="store_true",
                    help="3-layer hidden stack instead of the paper's one")
    ap.add_argument("--store", default=None,
                    help="ArtifactStore directory (persist compilations "
                         "across runs/processes)")
    ap.add_argument("--tune-store", default=None,
                    help="TuneStore directory (persist kernel tuning "
                         "records; a second run re-measures nothing)")
    ap.add_argument("--verilog-out", default="/tmp/nn_inference_full.v")
    ap.add_argument("--trace", default=None, metavar="DIR",
                    help="enable telemetry tracing + profiling; write "
                         "DIR/trace.jsonl and DIR/metrics.prom and print "
                         "the telemetry report at the end")
    args = ap.parse_args()
    if args.trace:
        telemetry.enable(profile=True)
    if args.deep:
        n_hidden = (128, 64) if args.fast else (500, 128)
    else:
        n_hidden = 128 if args.fast else 500
    epochs = 25 if args.fast else 60

    session = netgen.Session(store=args.store, tune_store=args.tune_store)
    if args.store:
        print(f"== artifact store: {args.store} "
              f"({len(session.store.keys())} artifacts resident) ==")
    if args.tune_store:
        print(f"== tune store: {args.tune_store} "
              f"({len(session.tuner.store.keys())} records resident) ==")

    print("== train (paper §II.A: 1000 imgs, backprop) ==")
    xtr, ytr, xte, yte = dataset.train_test_split(1000, 1000, seed=0)
    cfg = mlp.MLPConfig(n_hidden=n_hidden, epochs=epochs, lr=2.0, seed=42)
    t0 = time.time()
    params = mlp.train(cfg, xtr, ytr)
    print(f"trained in {time.time()-t0:.0f}s (layers: {mlp.layer_sizes(cfg)})")

    accs = {
        "L0 sigmoid fp32 (paper 98%)": mlp.predict_l0(params),
        "L1 step act    (paper 95%)": quantize.predict_l1(params),
        "L2 binary in   (paper 94%)": quantize.predict_l2(params),
        "L3 int weights (paper 92%)": quantize.predict_l3(params),
    }
    for name, fn in accs.items():
        print(f"  {name}: {mlp.accuracy(fn, xte, yte):.1%}")

    print("\n== netgen compile (paper §IV-§V as a Session compile) ==")
    qnet = quantize.quantize(params)
    art = session.compile(qnet, target="jnp")      # pipeline="default"
    for s in art.pass_stats:
        print(f"  {s.row()}")
    zero_del = art.pass_stats[0]               # the "zeros" pass
    final = art.pass_stats[-1].after
    print(f"  zero weights deleted at generation: "
          f"{1 - zero_del.after.terms / zero_del.before.terms:.1%} (paper: ~50%)")
    print(f"  multiplies: {zero_del.before.terms} -> 0 (addend form); "
          f"adds: {final.addend_units}")
    if art.source == "store":
        print(f"  loaded from store in {art.timings['load_s']*1e3:.0f} ms "
              f"(original compile: {art.timings['total_s']*1e3:.0f} ms)")
    else:
        print(f"  compile: {art.timings['total_s']*1e3:.0f} ms")

    # one hardware pipeline string, used by BOTH the cost report and the
    # Verilog emission so they price/emit the same circuit: the paper's
    # L4 pruning, plus the L5 addend rewrite unless --fast (it inflates
    # the Verilog text ~5x)
    hw_pipeline = "zeros,prune" if args.fast else "zeros,prune,addends"

    cost = session.compile(qnet, target="cost", pipeline=hw_pipeline).artifact
    print("  logic-cell estimate per pass (paper Fig. 7):")
    for stage, cells in cost.per_pass:
        print(f"    {stage}: {cells.total}")

    t0 = time.time()
    v = session.compile(
        qnet, target="verilog", pipeline=hw_pipeline,
        addend=not args.fast).artifact
    with open(args.verilog_out, "w") as f:
        f.write(v)
    print(f"  full Verilog artifact: {len(v)/1e6:.1f} MB, "
          f"{len(v.splitlines())} lines in {time.time()-t0:.0f}s "
          f"-> {args.verilog_out}")

    print("\n== specialized inference (exactness + throughput) ==")
    l3 = quantize.predict_l3(params)(jnp.asarray(xte))
    targets = ["jnp", "pallas", "pallas[tuned=true,planes=true]"]
    if not args.deep:
        targets.append("fused")
    for target in targets:
        art = session.compile(qnet, target=target)
        fn = art.artifact
        n = 1000 if target == "jnp" else 64
        preds = fn(jnp.asarray(xte[:n]))
        exact = bool(np.array_equal(np.asarray(preds), np.asarray(l3)[:n]))
        t0 = time.perf_counter()
        fn(jnp.asarray(xte[:n])).block_until_ready()
        dt = time.perf_counter() - t0
        form = f" form={art.plan_form}" if "tuned" in target else ""
        print(f"  target={target:30s} exact={exact} "
              f"{n/dt:,.0f} preds/s{form}"
              + ("  (interpret-mode Python, not TPU speed)" if target != "jnp" else ""))
    if session.tuner is not None:
        print(f"  {session.tuner.stats.row()}")

    print("\n== serve: two ladder depths through the Session ==")
    # a second net at the OTHER ladder depth, sharing the same server
    if args.deep:
        n_hidden_b = 96 if args.fast else 256
    else:
        n_hidden_b = (96, 48) if args.fast else (256, 96)
    cfg_b = mlp.MLPConfig(n_hidden=n_hidden_b, epochs=max(epochs // 2, 8),
                          lr=2.0, seed=43)
    params_b = mlp.train(cfg_b, xtr, ytr)
    qnet_b = quantize.quantize(params_b)

    server = netgen.NetServer(session=session, slot_capacity=256)
    t0 = time.perf_counter()
    server.register("ladder-a", qnet)           # memory hit: compiled above
    server.register("ladder-b", qnet_b)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    session.compile(qnet, target="jnp")         # same weights -> cache hit
    warm = time.perf_counter() - t0
    print(f"  register (2 versions, jit warm): {cold*1e3:.0f} ms; "
          f"warm predictor acquisition: {warm*1e6:.0f} us")

    # a same-topology variant (coarser weight quantization) to show the
    # stacked multi-net dispatch; the deeper net routes via fallback
    qnet_v2 = quantize.QuantizedNet(weights=[
        quantize.int_cast_weights(w, bound=5)
        for w in quantize.param_weights(params)])
    server.register("ladder-a-b5", qnet_v2)
    out = server.predict_many(                       # one jitted call (stacked)
        {"ladder-a": xte[:512], "ladder-a-b5": xte[:512]})
    out.update(server.predict_many(                  # other depth: routed alone
        {"ladder-b": xte[:512]}))
    for version in ("ladder-a", "ladder-a-b5", "ladder-b"):
        acc = float(np.mean(out[version] == yte[:512]))
        print(f"  {version:12s} acc={acc:.1%} ({len(out[version])} preds)")
    print(f"  dispatch: {server.dispatch_counts}  |  {session.stats().row()}")
    if session.store is not None:
        print(f"  {session.store.stats.row()}  "
              f"({len(session.store.keys())} artifacts on disk)")

    print("\n== online serving: single requests, continuous slot batching ==")
    # the async front door: clients submit ONE image at a time, the
    # engine forms slot blocks (fill the slot or wait max_batch_delay)
    # and serves them through the same stacked dispatch as above
    n_online = 64 if args.fast else 256
    with netgen.ServingEngine(server, max_batch_delay=0.002,
                              max_queue_depth=4096) as eng:
        futs = [(i, eng.submit("ladder-a" if i % 2 else "ladder-b", x))
                for i, x in enumerate(xte[:n_online])]
        online = np.array([f.result(timeout=30) for _, f in futs])
        acc = float(np.mean(online == yte[:n_online]))
        st = eng.stats()
    print(f"  {st.row()}")
    print(f"  acc={acc:.1%} over {n_online} single-request submits "
          f"({st.batches} dispatches — continuous batching amortized "
          f"{n_online}/{st.batches} requests per round)")

    if args.trace:
        trace_dir = Path(args.trace)
        trace_dir.mkdir(parents=True, exist_ok=True)
        n = telemetry.export_jsonl(trace_dir / "trace.jsonl")
        (trace_dir / "metrics.prom").write_text(telemetry.prometheus())
        print(f"\n== telemetry ({n} spans -> {trace_dir}/trace.jsonl) ==")
        print(telemetry.report())


if __name__ == "__main__":
    main()
