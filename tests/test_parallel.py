"""Sharding-rule unit tests + MoE dispatch correctness + property tests."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro import configs
from repro.layers import moe as moe_lib
from repro.models import base
from repro.parallel import sharding as shd


class FakeMesh:
    """Minimal stand-in with a .shape mapping (rules only need sizes)."""
    def __init__(self, shape):
        self.shape = shape


def test_spec_basic_mapping():
    mesh = FakeMesh({"data": 16, "model": 16})
    with shd.use_mesh(mesh, {"batch": ("data",)}):
        s = shd.spec((256, 4096, 1024), ("batch", "seq", None))
        assert s == P("data", "model")


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    with shd.use_mesh(mesh, {"batch": ("data",)}):
        # 20 heads don't divide 16 -> heads dropped, seq takes model
        s = shd.spec((32, 20, 4096, 128), ("batch", "heads", "seq", None))
        assert s == P("data", None, "model")
        assert any(f[0] == "heads" for f in shd.fallbacks())


def test_spec_axis_used_once():
    mesh = FakeMesh({"data": 16, "model": 16})
    with shd.use_mesh(mesh):
        s = shd.spec((64, 64), ("ffn", "vocab"))   # both want model
        assert s == P("model")                      # second dim replicated


def test_spec_multi_pod_batch():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    with shd.use_mesh(mesh):
        s = shd.spec((256, 128), ("batch", None))
        assert s == P(("pod", "data"))
        # batch=8 can't take 32-way -> falls back to prefix ("pod",)... 8%2==0
        s2 = shd.spec((8, 128), ("batch", None))
        # spec() collapses a single-axis group to the bare name; on older
        # jax P("pod") and P(("pod",)) don't compare equal, so pin the
        # collapsed form both spellings mean.
        assert s2 == P("pod")


def test_no_mesh_is_noop():
    x = jnp.ones((4, 4))
    assert shd.shard(x, "batch", None) is x


def _moe_ref(cfg, p, x):
    """Dense per-token reference for the sort-based MoE dispatch."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    if cfg.moe_norm_topk:
        gates = gates / gates.sum(-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((D,))
        for j in range(cfg.experts_per_token):
            e = int(ids[t, j])
            h = xt[t] @ p["wi"][e]
            g = xt[t] @ p["wg"][e]
            acc += float(gates[t, j]) * ((jax.nn.silu(g) * h) @ p["wo"][e])
        out = out.at[t].set(acc)
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference():
    cfg = configs.smoke("granite-moe-1b-a400m")
    key = jax.random.PRNGKey(0)
    p = base.tree_init(moe_lib.moe_params(cfg), key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    # capacity 4x => nothing dropped -> must match dense routing exactly
    got, aux = moe_lib.moe(cfg, p, x, capacity_factor=4.0)
    want = _moe_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux["lb_loss"]) > 0


def test_moe_capacity_drops_gracefully():
    cfg = configs.smoke("granite-moe-1b-a400m")
    p = base.tree_init(moe_lib.moe_params(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    out, _ = moe_lib.moe(cfg, p, x, capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(out)))


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([4, 8]),
       seed=st.integers(0, 10_000))
def test_moe_property_gate_weighted_norm(b, s, seed):
    """Property: MoE output norm is bounded by sum of expert outputs (gates
    are a convex combination when norm_topk)."""
    cfg = configs.smoke("qwen3-moe-30b-a3b")
    p = base.tree_init(moe_lib.moe_params(cfg), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, cfg.d_model))
    out, _ = moe_lib.moe(cfg, p, x, capacity_factor=4.0)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.abs(out).max()) < 1e4
