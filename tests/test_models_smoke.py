"""Per-architecture smoke tests: reduced configs, one forward + one
train-gradient step + a prefill->decode consistency check on CPU.
Asserts output shapes and finiteness (no NaNs/Infs)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import make_batch
from repro.models import api, base

ARCH_NAMES = sorted(configs.ARCHS.keys())
SMOKE_SHAPE = base.ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _setup(name):
    cfg = configs.smoke(name)
    params = base.tree_init(api.abstract_params(cfg), jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, SMOKE_SHAPE, step=0, seed=7).items()}
    return cfg, params, batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg, params, batch = _setup(name)
    logits, aux = api.forward(cfg, params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_grad_step(name):
    cfg, params, batch = _setup(name)

    def loss(p):
        return api.loss_fn(cfg, p, batch)[0]

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0)), name
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, name
    # one SGD step must reduce loss on the same batch (sanity of gradients)
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    l1 = loss(params2)
    assert float(l1) < float(l0), (name, float(l0), float(l1))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(name):
    """Greedy next-token from prefill == teacher-forced forward argmax at
    the last position; then one decode step advances without NaNs."""
    cfg, params, batch = _setup(name)
    B, S = batch["tokens"].shape
    cache = base.tree_init(api.abstract_cache(cfg, B, S + 8), jax.random.PRNGKey(1))

    extras = {k: v for k, v in batch.items() if k not in ("tokens", "targets", "loss_mask")}
    pre_batch = {"tokens": batch["tokens"], **extras}
    last_logits, cache2 = api.prefill(cfg, params, pre_batch, cache)
    assert last_logits.shape == (B, cfg.vocab)

    full_logits, _ = api.forward(cfg, params, batch)
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(full_logits[:, -1, :], np.float32), rtol=2e-2, atol=2e-2)

    nxt = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    step_logits, cache3 = api.decode_step(cfg, params, nxt, pos, cache2)
    assert step_logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(step_logits.astype(jnp.float32)))), name


def test_all_archs_present():
    assert len(ARCH_NAMES) == 10, ARCH_NAMES


def test_cell_grid():
    """40 declared cells; long_500k runs only for ssm/hybrid (32 compiled)."""
    cells = configs.all_cells()
    assert len(cells) == 10 * 3 + 2, len(cells)
    skipped = [c.name for c in configs.ARCHS.values()
               for s in [base.SHAPES["long_500k"]]
               if not base.supports_shape(c, s)]
    assert len(skipped) == 8
