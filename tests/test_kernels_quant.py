"""quant_matmul kernel vs oracle: exactness of int core + dequant epilogue."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro.kernels.quant_matmul import ops, ref

SHAPES = [(1, 64, 64), (8, 256, 128), (3, 100, 50), (16, 512, 256), (2, 2048, 64)]


@pytest.mark.parametrize("m,k,n", SHAPES)
def test_quant_matmul_matches_oracle(m, k, n):
    rng = np.random.default_rng(m * 7 + k + n)
    xq = rng.integers(-127, 128, size=(m, k)).astype(np.int8)
    wq = rng.integers(-127, 128, size=(k, n)).astype(np.int8)
    sx = np.float32(0.013)
    sw = rng.uniform(0.001, 0.1, size=(n,)).astype(np.float32)
    got = ops.quant_matmul(jnp.asarray(xq), jnp.asarray(wq), sx, jnp.asarray(sw))
    want = ref.quant_matmul_ref(jnp.asarray(xq), jnp.asarray(wq), sx, jnp.asarray(sw))
    # int32 accumulation is exact; only the fp32 epilogue can differ by ulps.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qlinear_close_to_float(dtype):
    """End-to-end W8A8 linear stays close to the fp matmul (the paper's
    'integer weights cost little accuracy' claim, in relative-error form)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 256)), dtype)
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    wq, sw = ops.quantize_weight(w)
    y = ops.qlinear(x, wq, sw)
    want = x.astype(jnp.float32) @ w
    err = np.linalg.norm(np.asarray(y, np.float32) - np.asarray(want)) / np.linalg.norm(
        np.asarray(want)
    )
    assert err < 0.02, err


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 8), k=st.integers(8, 128), n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_matmul_property(m, k, n, seed):
    """Property: integer core is exact for any int8 operands/shapes."""
    rng = np.random.default_rng(seed)
    xq = rng.integers(-127, 128, size=(m, k)).astype(np.int8)
    wq = rng.integers(-127, 128, size=(k, n)).astype(np.int8)
    sw = np.ones((n,), np.float32)
    got = np.asarray(ops.quant_matmul(jnp.asarray(xq), jnp.asarray(wq), np.float32(1.0), jnp.asarray(sw)))
    want = xq.astype(np.int64) @ wq.astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)
