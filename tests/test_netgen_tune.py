"""Persistent kernel autotuner tests (ISSUE 5): the `repro.netgen.tune`
search driver and its two-tier (memory -> TuneStore) reuse, the
`pallas[tuned=true]` / `fused[tuned=true]` target options, the zero
re-measurement warm start across PROCESSES (the tuning analogue of the
PR-3 zero-compile test), the tuned stacked dispatch through NetServer,
and the session-level async compile queue satellite."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.netgen.tune import KernelTuner, TuneRecord, TuneStore, tune_key

from _netgen_helpers import images, random_net

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _random_net(seed: int, sizes=(20, 16, 4), lo=-5, hi=5):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=77)


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


# ---------------------------------------------------------------------------
# Search driver
# ---------------------------------------------------------------------------

def test_tuner_picks_argmin_and_caches_in_memory():
    tuner = KernelTuner()
    costs = {"a": 0.003, "b": 0.001, "c": 0.002}
    calls = []

    def measure(cand):
        calls.append(cand["name"])
        return costs[cand["name"]]

    cands = [{"name": n} for n in costs]
    fields = {"target": "t", "device_kind": "cpu", "candidates": cands}
    best = tuner.get_or_tune(fields, cands, measure, reps=1)
    assert best == {"name": "b"}
    # warmup + 1 timed rep per candidate
    assert calls == ["a", "a", "b", "b", "c", "c"]
    assert tuner.stats.tunes == 1 and tuner.stats.measurements == 3

    calls.clear()
    assert tuner.get_or_tune(fields, cands, measure) == {"name": "b"}
    assert calls == [] and tuner.stats.hits == 1
    assert tuner.stats.measurements == 3       # nothing re-measured


def test_tuner_key_distinguishes_problems():
    base = {"target": "pallas", "device_kind": "cpu",
            "signature": {"widths": [9, 4]}}
    assert tune_key(base) == tune_key(dict(base))
    assert tune_key(base) != tune_key({**base, "device_kind": "tpu-v4"})
    assert tune_key(base) != tune_key(
        {**base, "signature": {"widths": [9, 5]}})
    with pytest.raises(ValueError, match="no tuning candidates"):
        KernelTuner().get_or_tune(base, [], lambda c: 0.0)


def test_tune_store_round_trip_and_corruption(tmp_path):
    store = TuneStore(tmp_path / "tune")
    rec = TuneRecord(key=tune_key({"q": 1}), best={"bm": 64},
                     measurements=(({"bm": 64}, 12.5), ({"bm": 128}, 20.0)),
                     device_kind="cpu", created_unix=1.0)
    store.put(rec)
    assert rec.key in store and store.keys() == [rec.key]
    back = store.get(rec.key)
    assert back.best == {"bm": 64} and back.measurements == rec.measurements
    # corrupt entry: evicted, read as a miss
    (tmp_path / "tune" / f"{rec.key}.json").write_text("{not json")
    assert store.get(rec.key) is None
    assert rec.key not in store
    assert store.get("0" * 64) is None


def test_tuner_second_instance_reuses_store(tmp_path):
    """A fresh KernelTuner over the same TuneStore serves the persisted
    winner with zero measurements — the in-process version of the
    cross-process guarantee below."""
    store_dir = tmp_path / "tune"
    cands = [{"bm": 64}, {"bm": 128}]
    fields = {"target": "t", "device_kind": "cpu", "candidates": cands}

    first = KernelTuner(store=store_dir)
    first.get_or_tune(fields, cands, lambda c: 0.001 * c["bm"])
    assert first.stats.tunes == 1

    def boom(cand):
        raise AssertionError("a warm tuner must not measure")

    warm = KernelTuner(store=TuneStore(store_dir))
    assert warm.get_or_tune(fields, cands, boom) == {"bm": 64}
    assert warm.stats.store_hits == 1 and warm.stats.measurements == 0


# ---------------------------------------------------------------------------
# tuned=true through the Session / targets
# ---------------------------------------------------------------------------

def test_tuned_pallas_compile_is_bit_exact_and_records_choice(tmp_path):
    net = _random_net(0)
    x = _images(0, 12, 20)
    session = netgen.Session(store=tmp_path / "art",
                             tune_store=tmp_path / "tune")
    art = session.compile(net, target="pallas[tuned=true]")
    assert art.plan_form in ("dense", "packed", "planes")
    assert set(art.artifact.blocks) == {"bm", "bn", "bkw"}
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    st = session.tune_stats()
    assert st.tunes == 1 and st.measurements > 0
    # same session, same shape: the tuning record is reused outright
    again = session.compile(net, target="pallas[tuned=true,bn=64]")
    np.testing.assert_array_equal(np.asarray(again(x)), _ref(net, x))
    assert session.tune_stats().tunes == 2     # pinned bn: new problem


def test_tuned_form_pinning_restricts_search():
    """`pallas[tuned=true,planes=true]` searches block sizes only — the
    datapath is pinned, and the winner must report it."""
    net = _random_net(1, sizes=(16, 12, 3))
    x = _images(1, 8, 16)
    art = netgen.compile_artifact(net, target="pallas[tuned=true,planes=true]")
    assert art.plan_form == "planes"
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))


def test_tuned_fused_searches_batch_tile(tmp_path):
    net = _random_net(2, sizes=(14, 9, 4))
    x = _images(2, 8, 14)
    session = netgen.Session(tune_store=tmp_path / "tune")
    art = session.compile(net, target="fused[tuned=true]")
    assert set(art.artifact.blocks) == {"bm"}
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    assert session.tune_stats().tunes == 1


def test_tuned_netserver_stacked_dispatch(tmp_path):
    """NetServer forwards the session tuner into the stacked multi-net
    build: tuned versions stack, stay bit-exact, and the stacked build
    reuses/creates tuning records instead of silently untuned defaults."""
    session = netgen.Session(store=tmp_path / "art",
                             tune_store=tmp_path / "tune")
    server = netgen.NetServer(session=session, target="pallas[tuned=true]",
                              slot_capacity=8, warmup=False)
    nets = {"a": _random_net(3, sizes=(15, 9, 4)),
            "b": _random_net(4, sizes=(15, 7, 4))}
    for name, net in nets.items():
        server.register(name, net)
    x = _images(3, 8, 15)
    out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["stacked"] == 1
    for name, net in nets.items():
        np.testing.assert_array_equal(out[name], _ref(net, x), err_msg=name)
    # single-version tunes + one stacked tune
    assert session.tune_stats().tunes >= 1


# ---------------------------------------------------------------------------
# Cross-process warm start: ZERO tuning measurements (ISSUE 5 acceptance)
# ---------------------------------------------------------------------------

def test_tuning_records_cross_process_zero_measurements(tmp_path):
    """A fresh process pointed at the same ArtifactStore + TuneStore
    rebuilds a `pallas[tuned=true]` artifact with zero compiles AND
    zero tuning measurements — the persisted record is picked up even
    though rebuilding the callable re-enters the tuned backend."""
    art_dir, tune_dir = tmp_path / "art", tmp_path / "tune"
    script = f"""
import json, sys
import numpy as np
sys.path.insert(0, {os.path.dirname(os.path.abspath(__file__))!r})
from _netgen_helpers import random_net, images
from repro import netgen

net = random_net(10, (20, 16, 4), lo=-5, hi=5)
x = images(10, 12, 20, salt=77)
session = netgen.Session(store={str(art_dir)!r}, tune_store={str(tune_dir)!r})
art = session.compile(net, target="pallas[tuned=true]")
ts = session.tune_stats()
print(json.dumps({{
    "key": art.key,
    "plan_form": art.plan_form,
    "blocks": art.artifact.blocks,
    "compiles": session.stats().compiles,
    "tunes": ts.tunes,
    "measurements": ts.measurements,
    "preds": np.asarray(art(x)).tolist(),
}}))
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        check=True, env={**os.environ, "PYTHONPATH": SRC})
    child = json.loads(out.stdout.strip().splitlines()[-1])
    assert child["compiles"] == 1 and child["tunes"] == 1
    assert child["measurements"] > 0

    session = netgen.Session(store=art_dir, tune_store=tune_dir)
    net = _random_net(10)
    x = _images(10, 12, 20)
    art = session.compile(net, target="pallas[tuned=true]")
    st, ts = session.stats(), session.tune_stats()
    assert (st.compiles, st.store_hits) == (0, 1)       # zero compiles
    assert ts.measurements == 0 and ts.tunes == 0       # zero measurements
    assert ts.store_hits == 1
    assert art.key == child["key"]
    assert art.plan_form == child["plan_form"]
    assert art.artifact.blocks == child["blocks"]
    np.testing.assert_array_equal(
        np.asarray(art(x)), np.asarray(child["preds"], dtype=np.int64))


# ---------------------------------------------------------------------------
# Session.compile_async (ROADMAP satellite)
# ---------------------------------------------------------------------------

def test_compile_async_returns_future_and_warms_cache(tmp_path):
    session = netgen.Session(store=tmp_path / "art")
    net = _random_net(20)
    x = _images(20, 8, 20)
    handle = session.compile_async(net, target="pallas[planes=true]")
    art = handle.result(timeout=120)
    assert handle.done() and art.plan_form == "planes"
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    # the synchronous path now hits the warm memory tier — registration
    # through a NetServer never blocks on a cold compile
    before = session.stats().compiles
    server = netgen.NetServer(session=session, target="pallas[planes=true]",
                              slot_capacity=8, warmup=False)
    server.register("v", net)
    assert session.stats().compiles == before  # cache hit, no new compile
    assert session.stats().hits >= 1
    session.shutdown()
    session.shutdown()                          # idempotent


def test_compile_async_coalesces_with_sync_compile(tmp_path):
    """Concurrent async + sync compiles of the same key compile once —
    the CompileCache lock serializes them."""
    session = netgen.Session()
    net = _random_net(21)
    futures = [session.compile_async(net, target="jnp") for _ in range(4)]
    sync = session.compile(net, target="jnp")
    arts = [f.result(timeout=120) for f in futures]
    assert all(a is sync for a in arts)         # the same Artifact object
    assert session.stats().compiles == 1
    session.shutdown()
