"""shard_map all-to-all MoE dispatch vs the GSPMD path (subprocess with a
faked 8-device mesh; tests proper must keep seeing 1 device)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro import configs
from repro.layers import moe as moe_lib
from repro.models import base, runtime
from repro.parallel import sharding as shd
from repro.launch.mesh import make_mesh_compat

cfg = configs.smoke("granite-moe-1b-a400m")   # 8 experts top-2
mesh = make_mesh_compat((2, 4), ("data", "model"))
p = base.tree_init(moe_lib.moe_params(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

with shd.use_mesh(mesh, {"batch": ("data",)}), mesh:
    ref, _ = moe_lib.moe(cfg, p, x, capacity_factor=4.0)
    with runtime.with_flags(moe_impl="shardmap"):
        got, aux = jax.jit(
            lambda p_, x_: moe_lib.moe(cfg, p_, x_, capacity_factor=4.0))(p, x)
        g = jax.jit(jax.grad(
            lambda x_: moe_lib.moe(cfg, p, x_, capacity_factor=4.0)[0].sum()))(x)

np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3)
assert bool(jnp.all(jnp.isfinite(g)))
assert float(aux["lb_loss"]) > 0
print("SHARD_MAP_MOE_OK")
"""


@pytest.mark.slow
def test_shardmap_moe_matches_gspmd():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD_MAP_MOE_OK" in out.stdout
