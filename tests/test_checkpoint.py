"""Checkpoint + fault-tolerance tests.

The headline test is kill/resume: a training run killed mid-flight by an
injected failure must, after resume-from-emergency-checkpoint, produce
bit-identical parameters to an uninterrupted run (deterministic data by
step + atomic checkpoints)."""
import os

import numpy as np
import pytest
import jax

from repro import configs
from repro.checkpoint import ckpt as ckpt_lib
from repro.models import base
from repro.optim import adamw
from repro.train import step as step_lib, trainer

CFG = configs.smoke("llama3.2-3b")
SHAPE = base.ShapeConfig("smoke", seq_len=16, global_batch=4, kind="train")
OC = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)


def test_save_restore_roundtrip(tmp_path):
    abstract = step_lib.abstract_state(CFG)
    state = base.tree_init(abstract, jax.random.PRNGKey(0))
    path = ckpt_lib.save(str(tmp_path), 7, state)
    restored = ckpt_lib.restore(path, abstract)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_partial_dirs(tmp_path):
    abstract = step_lib.abstract_state(CFG)
    state = base.tree_init(abstract, jax.random.PRNGKey(0))
    ckpt_lib.save(str(tmp_path), 1, state)
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))
    assert ckpt_lib.latest_step(str(tmp_path)) == 1


def test_manager_keeps_last_n(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=2)
    abstract = step_lib.abstract_state(CFG)
    state = base.tree_init(abstract, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_kill_resume_bit_identical(tmp_path):
    """Uninterrupted run == (run killed at step 6 -> resumed) run."""
    tc = trainer.TrainerConfig(
        total_steps=10, ckpt_every=4, ckpt_dir=str(tmp_path / "a"),
        seed=3, data_seed=11)
    state_a, _ = trainer.run(CFG, SHAPE, OC, tc)

    tc_b = trainer.TrainerConfig(
        total_steps=10, ckpt_every=4, ckpt_dir=str(tmp_path / "b"),
        seed=3, data_seed=11, fail_at_step=6)
    with pytest.raises(trainer.InjectedFailure):
        trainer.run(CFG, SHAPE, OC, tc_b)
    # supervisor behaviour: re-enter with resume=True
    tc_b.fail_at_step = -1
    state_b, hist_b = trainer.run(CFG, SHAPE, OC, tc_b, resume=True)

    for a, b in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_b["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_different_sharding(tmp_path):
    """Restore a checkpoint under a different mesh (elastic scaling): with
    one real device the mesh is trivial, but the code path (device_put to
    fresh NamedShardings derived from the active mesh) is exercised."""
    from repro.launch.mesh import make_host_mesh
    from repro.parallel import sharding as shd

    abstract = step_lib.abstract_state(CFG)
    state = base.tree_init(abstract, jax.random.PRNGKey(0))
    path = ckpt_lib.save(str(tmp_path), 3, state)
    mesh = make_host_mesh(data=1, model=1)
    with shd.use_mesh(mesh, {"batch": ("data",)}):
        restored = ckpt_lib.restore(path, abstract)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding is not None
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_decreases_over_training(tmp_path):
    tc = trainer.TrainerConfig(total_steps=30, ckpt_every=100,
                               ckpt_dir=str(tmp_path / "c"), seed=0)
    _, hist = trainer.run(CFG, SHAPE, OC, tc)
    first = np.mean(hist["loss"][:5])
    last = np.mean(hist["loss"][-5:])
    assert last < first, (first, last)
