"""Design-space explorer tests (ISSUE 10): the joint pipeline x
datapath x tile search (`repro.netgen.explore`), seeded determinism,
pre-measurement pruning through the shared analysis legality checks,
the `pallas[explored=true]` winner resolution, the serving layer's
explored-record preference, the check_trace counting-identity gate,
and the cross-process zero-measurement / zero-compile replay."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.netgen.explore import (
    Candidate, ExplorationReport, Explorer, SearchSpace, make_objective)

from _netgen_helpers import images, random_net

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
BENCH = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                     "benchmarks")


def _random_net(seed: int, sizes=(20, 16, 4)):
    return random_net(seed, sizes, lo=-5, hi=5)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=55)


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


def _session(tmp_path, name="a"):
    return netgen.Session(store=tmp_path / f"art-{name}",
                          tune_store=tmp_path / f"tune-{name}")


_FAST = dict(budget=6, seed=0, batch=16, reps=1, interpret=True)


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------

def test_search_space_product_and_validation():
    space = SearchSpace(pipelines=("default",), forms=("dense", "planes"),
                        tiles=({"bm": 32, "bn": 32, "bkw": 4},),
                        nets=("net",))
    cands = space.candidates()
    assert len(cands) == 2
    # pipeline strings are canonicalized, so aliases key identically
    assert all(c.pipeline == "zeros,prune" for c in cands)
    with pytest.raises(ValueError):
        SearchSpace(forms=("dense", "warp"))
    with pytest.raises(ValueError):
        SearchSpace(pipelines=())


# ---------------------------------------------------------------------------
# Determinism (satellite): same seed -> identical acceptance trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["random", "anneal"])
def test_explore_same_seed_identical_trace(tmp_path, strategy):
    """Two INDEPENDENT sessions (separate tune stores, so both truly
    search) produce bit-identical acceptance traces under the
    deterministic cells objective."""
    net = _random_net(0)
    reports = []
    for name in ("a", "b"):
        rep = _session(tmp_path, name).explore(
            net, objective="cells", strategy=strategy, **_FAST)
        assert rep.source == "search"
        reports.append(rep)
    a, b = reports
    assert a.trace == b.trace
    assert a.best == b.best and a.best_value == b.best_value
    assert a.evaluations == b.evaluations and a.pruned == b.pruned


def test_explore_different_seed_may_differ_but_is_reported(tmp_path):
    net = _random_net(1)
    a = _session(tmp_path, "a").explore(net, objective="cells",
                                        strategy="random", budget=4, seed=0,
                                        interpret=True)
    b = _session(tmp_path, "b").explore(net, objective="cells",
                                        strategy="random", budget=4, seed=1,
                                        interpret=True)
    # different seeds are different problems: both searched, neither
    # replayed the other's record
    assert a.source == "search" and b.source == "search"
    assert a.key != b.key


# ---------------------------------------------------------------------------
# Pre-measurement pruning through the shared analysis checks
# ---------------------------------------------------------------------------

def test_cse_pipeline_pruned_before_measurement_for_latency(tmp_path):
    """A CSE'd pipeline has no layer-structured ExecutionPlan, so a
    predictor objective must prune it with the irregularity reason —
    BEFORE any compile of a pallas candidate or any measurement."""
    net = _random_net(2)
    space = SearchSpace(
        pipelines=("zeros,prune,cse[bucketed=true]", "default"),
        forms=("dense",), tiles=({"bm": 32, "bn": 32, "bkw": 4},))
    session = _session(tmp_path)
    rep = session.explore(net, space=space, objective="latency",
                          strategy="random", budget=2, seed=0, batch=16,
                          reps=1, interpret=True)
    assert len(rep.pruned) == 1 and len(rep.evaluations) == 1
    (cand, reason), = rep.pruned
    assert "cse" in cand["pipeline"]
    assert "no ExecutionPlan" in reason
    assert rep.best.pipeline == "zeros,prune"
    # candidates identity the CI gate asserts from telemetry
    assert rep.candidates == len(rep.pruned) + len(rep.evaluations)


def test_duplicate_tiles_pruned_via_tile_legality(tmp_path):
    """Two tile candidates that clamp to the same effective kernel on a
    small plan: the second is rejected as a duplicate by the shared
    `analysis.tile_legality` closure, without spending a measurement."""
    net = _random_net(3)        # 20 inputs -> 1 lane word: bkw clamps
    space = SearchSpace(
        pipelines=("default",), forms=("planes",),
        tiles=({"bm": 128, "bn": 128, "bkw": 8},
               {"bm": 128, "bn": 128, "bkw": 16}))
    rep = _session(tmp_path).explore(
        net, space=space, objective="latency", strategy="random",
        budget=2, seed=0, batch=16, reps=1, interpret=True)
    assert len(rep.evaluations) == 1 and len(rep.pruned) == 1
    assert "duplicate kernel" in rep.pruned[0][1]


def test_everything_pruned_is_an_error(tmp_path):
    net = _random_net(4)
    space = SearchSpace(pipelines=("zeros,prune,cse[bucketed=true]",),
                        forms=("dense",),
                        tiles=({"bm": 32, "bn": 32, "bkw": 4},))
    with pytest.raises(ValueError, match="measured nothing"):
        _session(tmp_path).explore(net, space=space, objective="latency",
                                   strategy="random", budget=1, seed=0,
                                   interpret=True)


def test_cells_objective_admits_irregular_pipelines(tmp_path):
    """The cells objective never builds a predictor, so CSE'd pipelines
    are legal candidates (the FPGA flow can still emit them) and each
    (net, pipeline) is measured exactly once (tile/datapath dupes
    prune)."""
    net = _random_net(5)
    space = SearchSpace(
        pipelines=("default", "zeros,prune,addends",
                   "zeros,prune,addends,cse[bucketed=true]"),
        forms=("dense", "planes"),
        tiles=({"bm": 32, "bn": 32, "bkw": 4},))
    rep = _session(tmp_path).explore(
        net, space=space, objective="cells", strategy="random",
        budget=6, seed=0, interpret=True)
    measured_pipes = {c["pipeline"] for c, _ in rep.evaluations}
    assert len(rep.evaluations) == 3       # one per pipeline
    assert any("cse" in p for p in measured_pipes)
    # addends strictly shrinks the mult-free circuit's cell count
    by_pipe = {c["pipeline"]: v for c, v in rep.evaluations}
    assert by_pipe["zeros,prune,addends"] < by_pipe["zeros,prune"]


# ---------------------------------------------------------------------------
# Warm starts + persistence
# ---------------------------------------------------------------------------

def test_explore_warm_start_zero_measurements(tmp_path):
    net = _random_net(6)
    session = _session(tmp_path)
    rep = session.explore(net, objective="latency", strategy="anneal",
                          **_FAST)
    assert rep.source == "search"
    before = session.tune_stats().measurements
    again = session.explore(net, objective="latency", strategy="anneal",
                            **_FAST)
    assert again.source == "memory"
    assert session.tune_stats().measurements == before
    assert again.best == rep.best and again.trace == rep.trace


def test_objective_and_strategy_are_part_of_the_problem(tmp_path):
    net = _random_net(7)
    session = _session(tmp_path)
    a = session.explore(net, objective="cells", strategy="random", **_FAST)
    b = session.explore(net, objective="cells", strategy="anneal", **_FAST)
    assert a.key != b.key
    c = session.explore(net, objective="combined", strategy="random",
                        **_FAST)
    assert c.key not in (a.key, b.key)


def test_callable_objective_needs_stable_name(tmp_path):
    net = _random_net(8)
    session = _session(tmp_path)
    with pytest.raises(ValueError, match="stable name"):
        session.explore(net, objective=lambda ev: 0.0, **_FAST)
    obj = make_objective(lambda ev: float(ev.cells % 97), name="cells_mod",
                         needs_predictor=False, needs_latency=False)
    rep = session.explore(net, objective=obj, strategy="random", **_FAST)
    assert rep.objective == "cells_mod"


# ---------------------------------------------------------------------------
# pallas[explored=true] + serving preference
# ---------------------------------------------------------------------------

def test_explored_target_resolves_winner(tmp_path):
    net = _random_net(9)
    x = _images(9, 12, 20)
    session = _session(tmp_path)
    # pin the space so the winner is a non-default datapath
    space = SearchSpace(pipelines=("default",), forms=("packed",),
                        tiles=({"bm": 32, "bn": 32, "bkw": 4},))
    rep = session.explore(net, space=space, objective="latency",
                          strategy="random", budget=1, seed=0, batch=16,
                          reps=1, interpret=True)
    assert rep.best.form == "packed"
    art = session.compile(net, target="pallas[explored=true,interpret=true]")
    assert art.artifact.datapath == "packed"
    assert art.artifact.blocks == {"bm": 32, "bn": 32, "bkw": 4}
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    # the explored option is part of the canonical target string / key
    assert "explored=true" in art.target


def test_explored_without_record_is_inert(tmp_path):
    net = _random_net(10, sizes=(22, 14, 5))
    session = _session(tmp_path, "empty")
    art = session.compile(net, target="pallas[explored=true,interpret=true]")
    assert art.artifact.datapath == "dense"      # fell through to default


def test_explored_respects_contradicting_pin(tmp_path):
    """An explicit datapath pin wins over a contradicting record; the
    record's blocks only apply when the form family agrees."""
    net = _random_net(11)
    session = _session(tmp_path)
    space = SearchSpace(pipelines=("default",), forms=("packed",),
                        tiles=({"bm": 32, "bn": 32, "bkw": 4},))
    session.explore(net, space=space, objective="latency",
                    strategy="random", budget=1, seed=0, batch=16,
                    reps=1, interpret=True)
    art = session.compile(
        net, target="pallas[explored=true,planes=true,interpret=true]")
    assert art.artifact.datapath == "planes"     # pin kept, record ignored
    assert art.artifact.blocks.get("bm") is None


def test_serving_prefers_explored_record(tmp_path):
    """The stacked dispatch resolves the explored datapath (via the
    single-net signature fallback) instead of the hand-coded form
    precedence — and stays bit-exact."""
    net_a, net_b = _random_net(12), _random_net(13)
    x = _images(12, 8, 20)
    session = _session(tmp_path)
    space = SearchSpace(pipelines=("default",), forms=("packed",),
                        tiles=({"bm": 32, "bn": 32, "bkw": 4},))
    session.explore(net_a, space=space, objective="latency",
                    strategy="random", budget=1, seed=0, batch=16,
                    reps=1, interpret=True)
    server = netgen.NetServer(session=session,
                              target="pallas[interpret=true]",
                              slot_capacity=8, warmup=False)
    server.register("a", net_a)
    server.register("b", net_b)
    out = server.predict_many({"a": x, "b": x})
    np.testing.assert_array_equal(out["a"], _ref(net_a, x))
    np.testing.assert_array_equal(out["b"], _ref(net_b, x))
    fn, _ = server._stacked_fn(("a", "b"))
    assert fn.datapath == "packed"
    assert fn.blocks == {"bm": 32, "bn": 32, "bkw": 4}
    # opting out restores the hand-coded precedence
    plain = netgen.NetServer(session=session,
                             target="pallas[interpret=true]",
                             slot_capacity=8, warmup=False,
                             prefer_explored=False)
    plain.register("a", net_a)
    plain.register("b", net_b)
    pfn, _ = plain._stacked_fn(("a", "b"))
    assert pfn.datapath == "dense"


# ---------------------------------------------------------------------------
# Report + the check_trace gate
# ---------------------------------------------------------------------------

def test_report_roundtrips_and_best_config_compiles(tmp_path):
    net = _random_net(14)
    x = _images(14, 10, 20)
    session = _session(tmp_path)
    rep = session.explore(net, objective="latency", strategy="anneal",
                          **_FAST)
    blob = json.dumps(rep.as_dict())         # JSON-stable
    back = json.loads(blob)
    assert Candidate.from_dict(back["best"]) == rep.best
    spec, target = rep.best_config()
    art = session.compile(net, target=target, pipeline=spec.spec_string())
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    assert "explore[" in rep.describe()


def test_check_explore_gate_counting_identities():
    sys.path.insert(0, BENCH)
    try:
        from check_trace import check_explore
    finally:
        sys.path.remove(BENCH)
    good = [
        ("netgen_explore_candidates_total", {"explorer": "e1"}, 6.0),
        ("netgen_explore_pruned_total", {"explorer": "e1"}, 2.0),
        ("netgen_explore_measured_total", {"explorer": "e1"}, 4.0),
        ("netgen_explore_artifacts_total", {"explorer": "e1"}, 4.0),
    ]
    assert check_explore(good) == []
    assert check_explore([]) == []           # no explorer traffic: no-op
    lost = [("netgen_explore_candidates_total", {"explorer": "e2"}, 6.0),
            ("netgen_explore_pruned_total", {"explorer": "e2"}, 1.0),
            ("netgen_explore_measured_total", {"explorer": "e2"}, 4.0),
            ("netgen_explore_artifacts_total", {"explorer": "e2"}, 4.0)]
    assert any("candidates" in e for e in check_explore(lost))
    unbacked = [("netgen_explore_candidates_total", {"explorer": "e3"}, 4.0),
                ("netgen_explore_pruned_total", {"explorer": "e3"}, 0.0),
                ("netgen_explore_measured_total", {"explorer": "e3"}, 4.0),
                ("netgen_explore_artifacts_total", {"explorer": "e3"}, 3.0)]
    assert any("artifact" in e for e in check_explore(unbacked))


def test_live_explorer_counters_satisfy_the_gate(tmp_path):
    """The gate's identities hold for REAL explorer telemetry, not just
    synthetic samples."""
    from repro.netgen import telemetry

    sys.path.insert(0, BENCH)
    try:
        from check_trace import check_explore, parse_prometheus
    finally:
        sys.path.remove(BENCH)
    net = _random_net(15)
    _session(tmp_path).explore(net, objective="latency",
                               strategy="random", **_FAST)
    samples = parse_prometheus(telemetry.prometheus())
    assert check_explore(samples) == []
    assert any(name == "netgen_explore_candidates_total" and v > 0
               for name, _, v in samples)


# ---------------------------------------------------------------------------
# Ladder-depth dimension (satellite)
# ---------------------------------------------------------------------------

def test_nets_axis_explores_multiple_depths(tmp_path):
    """The ladder-depth sweep: nets of different depths enter one
    search space; the cells objective prices each, and the report
    carries per-depth evaluations."""
    nets = {"d1": _random_net(16, sizes=(20, 12, 4)),
            "d2": _random_net(17, sizes=(20, 12, 8, 4))}
    space = SearchSpace(pipelines=("default", "zeros,prune,addends"),
                        forms=("planes",),
                        tiles=({"bm": 32, "bn": 32, "bkw": 4},),
                        nets=("d1", "d2"))
    rep = _session(tmp_path).explore(
        nets=nets, space=space, objective="cells", strategy="random",
        budget=len(space.candidates()), seed=0, interpret=True)
    seen = {c["net"] for c, _ in rep.evaluations}
    assert seen == {"d1", "d2"}
    assert rep.best.net in nets
    with pytest.raises(ValueError, match="unknown nets"):
        Explorer(_session(tmp_path, "x"), nets=nets,
                 space=SearchSpace(nets=("d1", "d3")))


# ---------------------------------------------------------------------------
# Cross-process replay: ZERO measurements, ZERO compiles (acceptance)
# ---------------------------------------------------------------------------

def test_explore_cross_process_zero_measurements_zero_compiles(tmp_path):
    """A fresh process pointed at the same ArtifactStore + TuneStore
    replays the exploration from the persisted record — no tuning
    searches, no measurements — and rebuilds both the winner artifact
    and the `pallas[explored=true]` predictor with zero compiles."""
    art_dir, tune_dir = tmp_path / "art", tmp_path / "tune"
    session = netgen.Session(store=art_dir, tune_store=tune_dir)
    net = _random_net(18)
    x = _images(18, 10, 20)
    rep = session.explore(net, objective="latency", strategy="anneal",
                          **_FAST)
    assert rep.source == "search"
    spec, target = rep.best_config()
    session.compile(net, target=target, pipeline=spec.spec_string())
    session.compile(net, target="pallas[explored=true,interpret=true]")

    script = f"""
import json, sys
import numpy as np
sys.path.insert(0, {os.path.dirname(os.path.abspath(__file__))!r})
from _netgen_helpers import random_net, images
from repro import netgen

net = random_net(18, (20, 16, 4), lo=-5, hi=5)
x = images(18, 10, 20, salt=55)
session = netgen.Session(store={str(art_dir)!r}, tune_store={str(tune_dir)!r})
rep = session.explore(net, objective="latency", strategy="anneal",
                      budget=6, seed=0, batch=16, reps=1, interpret=True)
spec, target = rep.best_config()
art = session.compile(net, target=target, pipeline=spec.spec_string())
exp = session.compile(net, target="pallas[explored=true,interpret=true]")
ts = session.tune_stats()
print(json.dumps({{
    "source": rep.source,
    "best": rep.best.as_dict(),
    "trace_len": len(rep.trace),
    "tunes": ts.tunes,
    "measurements": ts.measurements,
    "compiles": session.stats().compiles,
    "store_hits": session.stats().store_hits,
    "datapath": exp.artifact.datapath,
    "pred": np.asarray(art(x)).tolist(),
}}))
"""
    out = subprocess.run(
        [sys.executable, "-c", script],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, check=True)
    got = json.loads(out.stdout.strip().splitlines()[-1])
    assert got["source"] == "store"
    assert got["best"] == rep.best.as_dict()
    assert got["trace_len"] == len(rep.trace)
    assert got["tunes"] == 0 and got["measurements"] == 0
    assert got["compiles"] == 0 and got["store_hits"] >= 2
    assert got["datapath"] == rep.best.form
    assert np.array_equal(np.asarray(got["pred"]), _ref(net, x))
