"""Dry-run machinery tests on a small faked-device mesh.

The full 512-device sweep runs via `python -m repro.launch.dryrun` (see
EXPERIMENTS.md). Here we exercise the same lowering path end-to-end in a
SUBPROCESS with 8 fake host devices (tests themselves must keep seeing a
single device), plus unit tests for the HLO collective parser.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import roofline as rl

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_collective_parser():
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={}
  %ag = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-gather(bf16[2,8]{1,0} %y, bf16[2,8]{1,0} %z), dimensions={0}
  %p = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
  %rs = bf16[8]{0} reduce-scatter(bf16[64]{0} %w), dimensions={0}
"""
    st = rl.collective_stats(hlo)
    assert st["_num_ops"] == 3
    assert st["all-reduce"] == 16 * 128 * 4
    assert st["all-gather"] == 2 * 4 * 8 * 2
    assert st["reduce-scatter"] == 8 * 2


def test_model_flops_sane():
    from repro import configs
    from repro.models.base import SHAPES
    cfg = configs.get_config("llama3.2-3b")
    f_train = rl.model_flops(cfg, SHAPES["train_4k"])
    f_dec = rl.model_flops(cfg, SHAPES["decode_32k"])
    # ~3.2B active params x ~1M tokens -> 6*N*D ~ 2e16
    assert 1e16 < f_train < 1e17, f_train
    assert f_dec < f_train / 1000


def test_roofline_bottleneck_logic():
    r = rl.Roofline(
        arch="a", shape="s", mesh="m", chips=256,
        flops_per_device=197e12,        # exactly 1s of compute
        bytes_per_device=819e9 * 0.5,   # 0.5s of memory
        collective_bytes=50e9 * 0.25,   # 0.25s of collective
        collective_breakdown={}, model_flops=197e12 * 256 * 0.7,
        peak_mem_per_device=1e9)
    assert r.bottleneck == "compute"
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.roofline_fraction - 0.7) < 1e-6


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess(tmp_path):
    """Lower+compile a smoke arch on a 2x4 fake mesh in a subprocess."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro import configs
from repro.launch.dryrun import run_cell
from repro.models.base import ShapeConfig

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
cfg = configs.smoke("llama3.2-3b")
shape = ShapeConfig("t", seq_len=64, global_batch=8, kind="train", accum=2)
record, meta = run_cell(cfg, shape, mesh, remat="full", verbose=False)
print(json.dumps({"flops": record.flops_per_device,
                  "coll": record.collective_bytes,
                  "mem": record.peak_mem_per_device}))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["mem"] > 0
