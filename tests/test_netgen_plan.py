"""ExecutionPlan lowering tests (ISSUE 4 tentpole): the one
circuit→tensor lowering shared by all array backends — dense form vs
`as_layered_weights`, bit-packed form on irregular widths (fan_in not a
multiple of 32), stacked multi-net form, and the Artifact plumbing that
records which form a compiled predictor executes."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.netgen.plan import lower_circuit, stack_plans

from _netgen_helpers import images, random_net


def _random_net(seed: int, sizes=(12, 9, 4), lo=-5, hi=5):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=31)


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


def _circuit(net):
    return netgen.compile_artifact(net, target="cost").circuit


# ---------------------------------------------------------------------------
# Dense lowering
# ---------------------------------------------------------------------------

def test_lower_circuit_matches_layered_weights():
    """The plan's weight matrices ARE the layered extraction — one
    lowering, shared by every backend."""
    c = _circuit(_random_net(0, sizes=(12, 9, 7, 4)))
    plan = lower_circuit(c)
    mats = netgen.as_layered_weights(c)
    assert plan.depth == len(mats) == 3
    assert plan.n_inputs == 12 and plan.input_threshold == c.input_threshold
    assert not plan.packed and not plan.stacked
    assert plan.form == "dense" and plan.n_classes == 4
    for layer, w in zip(plan.layers, mats):
        np.testing.assert_array_equal(layer.weights, w)
        assert layer.weights.dtype == np.int32
        assert layer.words is None
    assert [l.activation for l in plan.layers] == ["step", "step", "argmax"]
    assert "12-9x7x4 (dense)" == plan.describe()


def test_lower_circuit_rejects_irregular_dag():
    net = _random_net(1)
    circuit, _ = netgen.PipelineSpec.parse("zeros,addends,cse").run(
        netgen.lower(net))
    with pytest.raises(netgen.IrregularCircuitError):
        lower_circuit(circuit)


# ---------------------------------------------------------------------------
# Packed form
# ---------------------------------------------------------------------------

def test_pack_pads_fan_in_to_lanes():
    """Irregular widths (neither 37 inputs nor 45 hidden are /32) pad up
    to whole uint32 lanes with zero rows — exact by construction."""
    c = _circuit(_random_net(2, sizes=(37, 45, 10)))
    plan = lower_circuit(c)
    packed = plan.pack()
    assert packed.packed and packed.form == "packed"
    assert packed.pack() is packed                     # idempotent
    assert [l.weights.shape for l in packed.layers] == [(64, 45), (64, 10)]
    assert [l.words for l in packed.layers] == [2, 2]
    for dense_l, packed_l in zip(plan.layers, packed.layers):
        k = dense_l.fan_in
        np.testing.assert_array_equal(packed_l.weights[:k], dense_l.weights)
        assert not packed_l.weights[k:].any()          # zero padding
    # already-aligned widths are untouched
    aligned = lower_circuit(_circuit(_random_net(3, sizes=(32, 64, 4))))
    assert [l.weights.shape for l in aligned.pack().layers] == \
        [l.weights.shape for l in aligned.layers]
    assert lower_circuit(c, packed=True).layers[0].words == 2


@pytest.mark.parametrize("sizes", [(37, 45, 10), (12, 32, 4), (5, 3, 33, 2)])
def test_packed_pallas_bit_exact_irregular_widths(sizes):
    """ISSUE satellite: packed vs unpacked vs predict_quantized on
    widths that are not multiples of 32."""
    net = _random_net(4, sizes=sizes)
    x = _images(4, 16, sizes[0])
    ref = _ref(net, x)
    dense = netgen.compile_artifact(net, target="pallas")
    packed = netgen.compile_artifact(net, target="pallas[packed=true]")
    np.testing.assert_array_equal(np.asarray(dense(x)), ref)
    np.testing.assert_array_equal(np.asarray(packed(x)), ref)


@pytest.mark.slow
def test_packed_full_784_500_10_bit_exact():
    """ISSUE acceptance: `pallas[packed=true]` is bit-exact with the
    dense path on the full paper-sized net."""
    net = _random_net(5, sizes=(784, 500, 10))
    x = _images(5, 256, 784)
    ref = _ref(net, x)
    dense = netgen.compile_artifact(net, target="pallas")
    packed = netgen.compile_artifact(net, target="pallas[packed=true]")
    np.testing.assert_array_equal(np.asarray(dense(x)), ref)
    np.testing.assert_array_equal(np.asarray(packed(x)), ref)


# ---------------------------------------------------------------------------
# Bit-plane form
# ---------------------------------------------------------------------------

def _unpack_planes(pos, neg, n_planes):
    """Reconstruct the int64 weight matrix a (P, KW, N) plane pair
    encodes — the decomposition's correctness oracle."""
    shifts = np.arange(32, dtype=np.uint32)
    def unpack(plane):
        kw, n = plane.shape
        return ((plane[:, None, :] >> shifts[None, :, None])
                & np.uint32(1)).reshape(kw * 32, n).astype(np.int64)
    return sum((unpack(pos[b]) - unpack(neg[b])) << b
               for b in range(n_planes))


def test_planes_form_reconstructs_weights_exactly():
    """`plan.planes()` is a lossless re-representation: unpacking the
    signed bit-planes gives back the packed weight matrices bit for
    bit, and the plane count tracks each layer's actual magnitude."""
    c = _circuit(_random_net(20, sizes=(37, 45, 10), lo=-9, hi=9))
    plan = lower_circuit(c)
    lp = plan.planes()
    assert lp.bitplanes and lp.packed and lp.form == "planes"
    assert lp.planes() is lp                       # idempotent
    assert lp.describe().endswith("(planes)")
    packed = plan.pack()
    for lyr, plyr in zip(lp.layers, packed.layers):
        assert lyr.n_planes == max(
            1, int(np.abs(plyr.weights).max(initial=0)).bit_length())
        assert lyr.pos_planes.shape == \
            (lyr.n_planes, lyr.words, lyr.fan_out)
        assert lyr.pos_planes.dtype == np.uint32
        # a weight is never in both the pos and neg plane sets
        assert not np.bitwise_and(lyr.pos_planes, lyr.neg_planes).any()
        np.testing.assert_array_equal(
            _unpack_planes(lyr.pos_planes, lyr.neg_planes, lyr.n_planes),
            plyr.weights)


def test_decompose_planes_rejects_unpadded():
    with pytest.raises(ValueError, match="multiple of 32"):
        netgen.decompose_planes(np.zeros((33, 4), np.int32))


def test_lower_circuit_form_argument():
    c = _circuit(_random_net(21))
    assert lower_circuit(c, form="dense").form == "dense"
    assert lower_circuit(c, form="packed").form == "packed"
    assert lower_circuit(c, form="planes").form == "planes"
    assert lower_circuit(c, packed=True).form == "packed"   # legacy flag
    with pytest.raises(ValueError, match="unknown plan form"):
        lower_circuit(c, form="sparse")


@pytest.mark.parametrize("sizes", [(37, 45, 10), (12, 32, 4), (5, 3, 33, 2)])
def test_planes_pallas_bit_exact_irregular_widths(sizes):
    """ISSUE 5 acceptance: `pallas[planes=true]` vs dense vs
    predict_quantized on widths that are not multiples of 32."""
    net = _random_net(22, sizes=sizes)
    x = _images(22, 16, sizes[0])
    ref = _ref(net, x)
    dense = netgen.compile_artifact(net, target="pallas")
    planes = netgen.compile_artifact(net, target="pallas[planes=true]")
    np.testing.assert_array_equal(np.asarray(dense(x)), ref)
    np.testing.assert_array_equal(np.asarray(planes(x)), ref)


def test_packed_and_planes_options_are_exclusive():
    net = _random_net(23)
    with pytest.raises(ValueError, match="exclusive"):
        netgen.compile_artifact(net, target="pallas[packed=true,planes=true]")


@pytest.mark.slow
def test_planes_full_784_500_10_bit_exact():
    """ISSUE 5 acceptance: the fully bit-packed datapath is bit-exact
    with dense on the full paper-sized net."""
    net = _random_net(24, sizes=(784, 500, 10))
    x = _images(24, 256, 784)
    ref = _ref(net, x)
    planes = netgen.compile_artifact(net, target="pallas[planes=true]")
    np.testing.assert_array_equal(np.asarray(planes(x)), ref)


# ---------------------------------------------------------------------------
# Stacked form
# ---------------------------------------------------------------------------

def test_stack_plans_pads_hidden_widths():
    plans = [lower_circuit(_circuit(_random_net(6, sizes=(12, 9, 4)))),
             lower_circuit(_circuit(_random_net(7, sizes=(12, 6, 4))))]
    stacked = stack_plans(plans)
    assert stacked.stacked and stacked.n_models == 2
    assert [l.weights.shape for l in stacked.layers] == \
        [(2, 12, 9), (2, 9, 4)]
    # version 1's padded hidden columns (and their outgoing rows) are zero
    np.testing.assert_array_equal(
        stacked.layers[0].weights[1, :, :6], plans[1].layers[0].weights)
    assert not stacked.layers[0].weights[1, :, 6:].any()
    assert not stacked.layers[1].weights[1, 6:, :].any()
    assert stacked.describe().startswith("2x12-")
    # packing a stacked plan pads the (shared) fan_in axis
    packed = stacked.pack()
    assert [l.weights.shape for l in packed.layers] == \
        [(2, 32, 9), (2, 32, 4)]


def test_stack_plans_error_paths():
    mk = lambda seed, sizes: lower_circuit(  # noqa: E731
        _circuit(_random_net(seed, sizes=sizes)))
    with pytest.raises(ValueError, match="no plans"):
        stack_plans([])
    with pytest.raises(ValueError, match="depth"):
        stack_plans([mk(0, (8, 6, 4)), mk(1, (8, 6, 6, 4))])
    with pytest.raises(ValueError, match="input width"):
        stack_plans([mk(0, (8, 6, 4)), mk(1, (9, 6, 4))])
    with pytest.raises(ValueError, match="class count"):
        stack_plans([mk(0, (8, 6, 4)), mk(1, (8, 6, 5))])
    with pytest.raises(ValueError, match="pack after stacking"):
        stack_plans([mk(0, (8, 6, 4)).pack(), mk(1, (8, 6, 4))])
    two = stack_plans([mk(0, (8, 6, 4)), mk(1, (8, 6, 4))])
    with pytest.raises(ValueError, match="pack after stacking"):
        stack_plans([two, two])


def test_multi_backends_require_stacked_plans():
    from repro.netgen.backends import compile_multi
    plan = lower_circuit(_circuit(_random_net(8)))
    for backend in ("jnp", "pallas"):
        with pytest.raises(ValueError, match="stacked"):
            compile_multi(plan, backend=backend)
    with pytest.raises(ValueError, match="no multi-net dispatch"):
        compile_multi(plan, backend="fused")


def test_compile_multi_validates_declared_options():
    """ISSUE satellite: the multi-net form goes through the Target
    registry's declared options — no raw kwargs side door."""
    from repro.netgen.backends import compile_multi
    nets = [_random_net(9), _random_net(10)]
    plan = stack_plans([lower_circuit(_circuit(n)) for n in nets])
    with pytest.raises(ValueError, match="unknown option"):
        compile_multi(plan, backend="pallas", block_size=7)
    with pytest.raises(ValueError, match="unknown option"):
        compile_multi(plan, backend="jnp", interpret=True)
    fn = compile_multi(plan, backend="pallas[interpret=true,packed=true]")
    x = _images(9, 8, 12)
    block = np.stack([x, x])
    for i, net in enumerate(nets):
        np.testing.assert_array_equal(
            np.asarray(fn(block))[i], _ref(net, x))


def test_compile_multi_planes_stacked():
    """The stacked multi-net dispatch through the bit-plane datapath:
    plane decomposition happens over the stacked (M, K, N) weights
    (shared plane count), bit-exact per version."""
    from repro.netgen.backends import compile_multi
    nets = [_random_net(30, sizes=(13, 9, 4)),
            _random_net(31, sizes=(13, 6, 4))]    # padded hidden widths
    plan = stack_plans([lower_circuit(_circuit(n)) for n in nets])
    lp = plan.planes()
    assert lp.stacked and lp.form == "planes"
    lyr = lp.layers[0]
    assert lyr.pos_planes.shape == (2, lyr.n_planes, lyr.words, lyr.fan_out)
    fn = compile_multi(plan, backend="pallas[planes=true]")
    x = _images(30, 8, 13)
    block = np.stack([x, x])
    for i, net in enumerate(nets):
        np.testing.assert_array_equal(
            np.asarray(fn(block))[i], _ref(net, x), err_msg=f"version {i}")


# ---------------------------------------------------------------------------
# Artifacts record the plan form
# ---------------------------------------------------------------------------

def test_artifact_records_plan_form(tmp_path):
    net = _random_net(11)
    session = netgen.Session(store=netgen.ArtifactStore(tmp_path / "s"))
    dense = session.compile(net, target="pallas")
    packed = session.compile(net, target="pallas[packed=true]")
    planes = session.compile(net, target="pallas[planes=true]")
    assert dense.plan_form == "dense" and packed.plan_form == "packed"
    assert planes.plan_form == "planes"
    assert len({dense.key, packed.key, planes.key}) == 3  # distinct entries
    assert not dense.plan().packed and packed.plan().packed
    assert planes.plan().bitplanes
    text = session.compile(net, target="verilog")
    assert text.plan_form is None
    with pytest.raises(TypeError, match="no execution plan"):
        text.plan()

    # a second session warm-starts every form from disk, form preserved
    warm = netgen.Session(store=netgen.ArtifactStore(tmp_path / "s"))
    wd = warm.compile(net, target="pallas")
    wp = warm.compile(net, target="pallas[packed=true]")
    wl = warm.compile(net, target="pallas[planes=true]")
    assert warm.stats().compiles == 0
    assert wd.plan_form == "dense" and wp.plan_form == "packed"
    assert wl.plan_form == "planes"
    x = _images(11, 8, 12)
    np.testing.assert_array_equal(np.asarray(wp(x)), np.asarray(packed(x)))
    np.testing.assert_array_equal(np.asarray(wl(x)), _ref(net, x))
    np.testing.assert_array_equal(np.asarray(wp(x)), _ref(net, x))
