"""binary_matvec kernel vs jnp oracle: shape/dtype sweeps + properties."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro.kernels.binary_matvec import ops, ref

SHAPES = [
    (1, 784, 500),     # the paper's layer-1 shape
    (4, 500, 10),      # the paper's layer-2 shape
    (8, 128, 128),
    (3, 200, 77),      # ragged, forces padding
    (16, 64, 256),
    (2, 1024, 32),
]


@pytest.mark.parametrize("b,k,n", SHAPES)
@pytest.mark.parametrize("wdtype", [jnp.int32, jnp.int8])
def test_binary_matmul_matches_oracle(b, k, n, wdtype):
    rng = np.random.default_rng(b * 1000 + k + n)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-9, 10, size=(k, n)).astype(np.int32)
    got = ops.binary_matmul(jnp.asarray(x), jnp.asarray(w).astype(wdtype))
    want = ref.binary_matmul_ref(jnp.asarray(x), jnp.asarray(w).astype(wdtype))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,k,n", [(4, 256, 64), (2, 784, 500), (5, 96, 40)])
def test_binary_matmul_packed_matches_oracle(b, k, n):
    rng = np.random.default_rng(k + n)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-9, 10, size=(k, n)).astype(np.int32)
    xp = ops.pack_bits(jnp.asarray(x))
    kp = xp.shape[1] * 32
    wp = jnp.zeros((kp, n), jnp.int32).at[:k].set(jnp.asarray(w))
    got = ops.binary_matmul_packed(xp, wp)
    want = np.asarray(x.astype(np.int64) @ w.astype(np.int64))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, size=(7, 130)).astype(np.int8)
    xp = ops.pack_bits(jnp.asarray(x))
    back = ref.unpack_bits_ref(xp, 130)
    np.testing.assert_array_equal(np.asarray(back)[:, :130], x)


def test_masked_form_equals_matmul():
    """The paper's L5 identity: masked column-sum == matmul for binary x."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 2, size=(9, 61)).astype(np.int8))
    w = jnp.asarray(rng.integers(-5, 6, size=(61, 13)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(ref.binary_matmul_masked_ref(x, w)),
        np.asarray(ref.binary_matmul_ref(x, w)),
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    k=st.integers(1, 200),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_matmul_property(b, k, n, seed):
    """Property: kernel == int matmul for any binary input / int weights."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-9, 10, size=(k, n)).astype(np.int32)
    got = np.asarray(ops.binary_matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, x.astype(np.int64) @ w.astype(np.int64))


# ---------------------------------------------------------------------------
# Bit-plane kernel: both operands packed, popcount accumulation
# ---------------------------------------------------------------------------

def _planes_for(w: np.ndarray):
    """Decompose a dense int32 (K, N) into packed signed bit-planes,
    zero-padding K up to a lane multiple (what `plan.planes()` does)."""
    from repro.netgen.plan import decompose_planes
    k, n = w.shape
    kp = ((k + 31) // 32) * 32
    if kp != k:
        w = np.pad(w, ((0, kp - k), (0, 0)))
    return decompose_planes(w.astype(np.int32))


@pytest.mark.parametrize("b,k,n,lo,hi", [
    (4, 256, 64, -9, 9),
    (2, 784, 500, -5, 5),      # the paper's layer-1 shape
    (5, 96, 40, -1, 1),        # single plane (pure BNN case)
    (3, 77, 13, -300, 300),    # 9 planes: wide post-pass magnitudes
    (1, 33, 3, 0, 0),          # all-zero weights: one zero plane
])
def test_binary_matmul_planes_matches_matmul(b, k, n, lo, hi):
    rng = np.random.default_rng(k * 31 + n)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(lo, hi + 1, size=(k, n)).astype(np.int32)
    xp = ops.pack_bits(jnp.asarray(x))
    pos, neg, p = _planes_for(w)
    assert p == max(1, int(np.abs(w).max(initial=0)).bit_length())
    got = np.asarray(ops.binary_matmul_planes(
        xp, jnp.asarray(pos), jnp.asarray(neg)))
    np.testing.assert_array_equal(got, x.astype(np.int64) @ w.astype(np.int64))


def test_binary_matmul_planes_matches_plane_oracle():
    """Kernel vs the unpack-and-matmul oracle on the same plane arrays
    (isolates kernel arithmetic from the decomposition)."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 2, size=(6, 64)).astype(np.int8)
    w = rng.integers(-7, 8, size=(64, 20)).astype(np.int32)
    xp = ops.pack_bits(jnp.asarray(x))
    pos, neg, _ = _planes_for(w)
    pos, neg = jnp.asarray(pos), jnp.asarray(neg)
    np.testing.assert_array_equal(
        np.asarray(ops.binary_matmul_planes(xp, pos, neg)),
        np.asarray(ref.plane_matmul_ref(xp, pos, neg)))


@pytest.mark.parametrize("bm,bn,bkw", [(64, 64, 4), (128, 32, 2), (8, 8, 1)])
def test_binary_matmul_planes_block_sizes(bm, bn, bkw):
    """The tuner's search axes: every block-size choice is exact (ragged
    shapes force padding on all three grid axes)."""
    rng = np.random.default_rng(bm + bn + bkw)
    x = rng.integers(0, 2, size=(9, 200)).astype(np.int8)
    w = rng.integers(-6, 7, size=(200, 77)).astype(np.int32)
    xp = ops.pack_bits(jnp.asarray(x))
    pos, neg, _ = _planes_for(w)
    got = np.asarray(ops.binary_matmul_planes(
        xp, jnp.asarray(pos), jnp.asarray(neg), bm=bm, bn=bn, bkw=bkw))
    np.testing.assert_array_equal(got, x.astype(np.int64) @ w.astype(np.int64))


def test_step_pack_fuses_step_and_pack():
    """step_pack == strict step then pack_bits, without the int8 hop
    (the packed chains' layer boundary)."""
    rng = np.random.default_rng(3)
    acc = rng.integers(-40, 41, size=(7, 45)).astype(np.int32)
    got = ops.step_pack(jnp.asarray(acc), words=2)
    want = ops.pack_bits(jnp.asarray((acc > 0).astype(np.int8)))
    assert got.dtype == jnp.uint32 and got.shape == (7, 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # extra padded words stay zero (next layer's wider padded fan_in)
    wide = np.asarray(ops.step_pack(jnp.asarray(acc), words=4))
    np.testing.assert_array_equal(wide[:, :2], np.asarray(want))
    assert not wide[:, 2:].any()


def test_binarize_pack_matches_threshold_then_pack():
    rng = np.random.default_rng(4)
    x = rng.integers(0, 256, size=(5, 70)).astype(np.uint8)
    thr = 128
    got = ops.binarize_pack(jnp.asarray(x), threshold=thr, words=3)
    want = ops.pack_bits(jnp.asarray((x > thr).astype(np.int8)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    k=st.integers(1, 150),
    n=st.integers(1, 40),
    mag=st.integers(0, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_matmul_planes_property(b, k, n, mag, seed):
    """Property: the bit-plane kernel == int matmul for any binary input
    and any signed weight magnitude range (plane count adapts)."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-mag, mag + 1, size=(k, n)).astype(np.int32)
    xp = ops.pack_bits(jnp.asarray(x))
    pos, neg, _ = _planes_for(w)
    got = np.asarray(ops.binary_matmul_planes(
        xp, jnp.asarray(pos), jnp.asarray(neg)))
    np.testing.assert_array_equal(got, x.astype(np.int64) @ w.astype(np.int64))


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 40),
    n_in=st.integers(1, 80),
    n_h=st.integers(1, 40),
    n_out=st.integers(2, 8),
    mag=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_forward_planes_property(b, n_in, n_h, n_out, mag, seed):
    """Property: the whole-net megakernel == the layer-by-layer numpy
    forward (binarize, matmul, strict step, matmul, argmax) for any
    widths, batch, and weight magnitude — the in-register repack and
    all padding seams must be exact."""
    from repro.netgen.plan import lower_circuit
    from repro.core import quantize
    from repro import netgen

    rng = np.random.default_rng(seed)
    w1 = rng.integers(-mag, mag + 1, size=(n_in, n_h)).astype(np.int32)
    w2 = rng.integers(-mag, mag + 1, size=(n_h, n_out)).astype(np.int32)
    net = quantize.QuantizedNet(weights=[w1, w2])
    x = rng.integers(0, 256, size=(b, n_in)).astype(np.uint8)

    a = (x.astype(np.int64) > net.input_threshold).astype(np.int64)
    acc = ((a @ w1 > 0).astype(np.int64)) @ w2
    want = np.argmax(acc, axis=-1).astype(np.int32)

    view = lower_circuit(netgen.lower(net)).megakernel_view()
    got = np.asarray(ops.binary_forward_planes(
        jnp.asarray(x), *[jnp.asarray(p) for p in view.arrays],
        threshold=net.input_threshold, n_classes=view.n_classes))
    np.testing.assert_array_equal(got, want)
