"""binary_matvec kernel vs jnp oracle: shape/dtype sweeps + properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro.kernels.binary_matvec import ops, ref

SHAPES = [
    (1, 784, 500),     # the paper's layer-1 shape
    (4, 500, 10),      # the paper's layer-2 shape
    (8, 128, 128),
    (3, 200, 77),      # ragged, forces padding
    (16, 64, 256),
    (2, 1024, 32),
]


@pytest.mark.parametrize("b,k,n", SHAPES)
@pytest.mark.parametrize("wdtype", [jnp.int32, jnp.int8])
def test_binary_matmul_matches_oracle(b, k, n, wdtype):
    rng = np.random.default_rng(b * 1000 + k + n)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-9, 10, size=(k, n)).astype(np.int32)
    got = ops.binary_matmul(jnp.asarray(x), jnp.asarray(w).astype(wdtype))
    want = ref.binary_matmul_ref(jnp.asarray(x), jnp.asarray(w).astype(wdtype))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,k,n", [(4, 256, 64), (2, 784, 500), (5, 96, 40)])
def test_binary_matmul_packed_matches_oracle(b, k, n):
    rng = np.random.default_rng(k + n)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-9, 10, size=(k, n)).astype(np.int32)
    xp = ops.pack_bits(jnp.asarray(x))
    kp = xp.shape[1] * 32
    wp = jnp.zeros((kp, n), jnp.int32).at[:k].set(jnp.asarray(w))
    got = ops.binary_matmul_packed(xp, wp)
    want = np.asarray(x.astype(np.int64) @ w.astype(np.int64))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 2, size=(7, 130)).astype(np.int8)
    xp = ops.pack_bits(jnp.asarray(x))
    back = ref.unpack_bits_ref(xp, 130)
    np.testing.assert_array_equal(np.asarray(back)[:, :130], x)


def test_masked_form_equals_matmul():
    """The paper's L5 identity: masked column-sum == matmul for binary x."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 2, size=(9, 61)).astype(np.int8))
    w = jnp.asarray(rng.integers(-5, 6, size=(61, 13)).astype(np.int32))
    np.testing.assert_array_equal(
        np.asarray(ref.binary_matmul_masked_ref(x, w)),
        np.asarray(ref.binary_matmul_ref(x, w)),
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 8),
    k=st.integers(1, 200),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_binary_matmul_property(b, k, n, seed):
    """Property: kernel == int matmul for any binary input / int weights."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 2, size=(b, k)).astype(np.int8)
    w = rng.integers(-9, 10, size=(k, n)).astype(np.int32)
    got = np.asarray(ops.binary_matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(got, x.astype(np.int64) @ w.astype(np.int64))
