"""Whole-net megakernel (`pallas[fusednet=true]`) edge cases — ISSUE 9.

The fusednet datapath fuses an entire planes-form plan into ONE Pallas
launch: binarize+pack on entry, per-layer popcount accumulate, strict
step + repack in-register between layers, argmax fused at the end.
These tests pin the shapes where the in-kernel padding contracts can
silently break: 1-layer nets (no repack at all), fan-in/out that
straddle the 32-lane word boundary, per-layer plane counts that differ,
stacked M>1 plans whose hidden widths were padded for stacking, and the
interpret-mode path CPU-only CI runs. The launch-accounting contract
(`netgen_kernel_launches_total{form}`, `launches_per_call`, the
check_trace gate) is covered here too, against the per-layer chain's
depth-launch count.

Everything runs in interpret mode — the container has no TPU — which is
exactly the parity CI needs: bit-exact against the dense reference
`quantize.predict_quantized`.
"""
import sys
from pathlib import Path

import numpy as np
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.kernels.binary_matvec import ops
from repro.netgen import telemetry
from repro.netgen.plan import PACK_LANES, lower_circuit, stack_plans

from _netgen_helpers import images, random_net

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "benchmarks"))
from check_trace import check_launches  # noqa: E402


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


def _fused(net):
    return netgen.specialize(net, backend="pallas[fusednet=true]")


# ---------------------------------------------------------------------------
# Edge-case exactness
# ---------------------------------------------------------------------------

def test_single_layer_net():
    """Depth 1: no hidden repack ever runs; the kernel goes straight
    from the packed input to the fused argmax."""
    net = random_net(3, (40, 7), lo=-5, hi=5)
    x = images(3, 9, 40)
    np.testing.assert_array_equal(np.asarray(_fused(net)(jnp.asarray(x))),
                                  _ref(net, x))


def test_non_multiple_of_32_fan_in_and_out():
    """Widths off the 32-lane boundary force padding at every seam:
    input pack, hidden repack, and the final argmax slice."""
    for seed, sizes in ((5, (31, 33, 5)), (6, (45, 21, 7)),
                        (7, (33, 1, 4))):
        net = random_net(seed, sizes, lo=-5, hi=5)
        x = images(seed, 11, sizes[0])
        np.testing.assert_array_equal(
            np.asarray(_fused(net)(jnp.asarray(x))), _ref(net, x),
            err_msg=str(sizes))


def test_per_layer_plane_counts_differ():
    """P is per layer (bit_length of that layer's max |w|); a ternary
    first layer chained to a wide-magnitude second layer must keep
    separate plane counts, not pad to a uniform maximum."""
    net = quantize.QuantizedNet(weights=[
        np.asarray(random_net(9, (50, 20), lo=-1, hi=1).weights[0]),
        np.asarray(random_net(10, (20, 6), lo=-37, hi=37).weights[0])])
    view = lower_circuit(netgen.lower(net)).megakernel_view()
    assert view.layer_planes[0] == 1
    assert view.layer_planes[1] == 6        # bit_length(37)
    x = images(9, 13, 50)
    np.testing.assert_array_equal(np.asarray(_fused(net)(jnp.asarray(x))),
                                  _ref(net, x))


def test_megakernel_view_padding_invariants():
    """The view's whole contract: hidden fan_out padded so
    N_l == W_{l+1} * 32 (repack is a reshape), the FINAL layer unpadded
    (a phantom class must never reach the argmax), arrays interleaved
    pos/neg with per-layer plane counts."""
    net = random_net(11, (45, 21, 13, 7), lo=-5, hi=5)
    view = lower_circuit(netgen.lower(net)).megakernel_view()
    assert view.depth == 3 and not view.stacked
    assert len(view.arrays) == 2 * view.depth
    for li in range(view.depth):
        pos, neg = view.arrays[2 * li], view.arrays[2 * li + 1]
        assert pos.shape == neg.shape
        p, w, n = pos.shape
        assert (p, w) == (view.layer_planes[li], view.layer_words[li])
        if li + 1 < view.depth:             # hidden: padded to next words
            assert n == view.layer_words[li + 1] * PACK_LANES
            assert n >= view.layer_fan_out[li]
        else:                               # final: true class count
            assert n == view.layer_fan_out[li] == view.n_classes == 7
    # VMEM estimate is positive and monotone in the batch tile
    assert 0 < view.vmem_bytes(bm=8, bkw=1) < view.vmem_bytes(bm=64, bkw=1)


def test_stacked_plan_padded_hidden_widths():
    """M>1: stack_plans pads hidden widths across versions; the stacked
    megakernel must agree with every version's own dense reference."""
    sizes_by_version = ((20, 13, 5), (20, 16, 5), (20, 19, 5))
    nets = [random_net(20 + i, s, lo=-5, hi=5)
            for i, s in enumerate(sizes_by_version)]
    plans = [lower_circuit(netgen.lower(n)) for n in nets]
    stacked = stack_plans(plans).planes()
    view = stacked.megakernel_view()
    assert view.stacked and view.n_models == 3
    x = images(21, 8, 20)
    xs = jnp.asarray(np.stack([x] * 3))
    got = np.asarray(ops.binary_forward_planes(
        xs, *[jnp.asarray(a) for a in view.arrays],
        threshold=view.input_threshold, n_classes=view.n_classes))
    assert got.shape == (3, 8)
    for m, net in enumerate(nets):
        np.testing.assert_array_equal(got[m], _ref(net, x), err_msg=str(m))


def test_server_stacked_dispatch_prefers_fusednet():
    """A bit-plane NetServer's stacked dispatch rides the megakernel:
    one launch per round, `form=fusednet` on the kernel span, and the
    check_trace launch gate passes on the resulting trace."""
    telemetry.enable()
    server = netgen.NetServer(target="pallas[planes=true]",
                              slot_capacity=8, warmup=False)
    nets = {f"v{i}": random_net(30 + i, (20, 13 + 3 * i, 5), lo=-5, hi=5)
            for i in range(3)}
    for name, net in nets.items():
        server.register(name, net)
    x = images(31, 6, 20)
    out = server.predict_many({name: x for name in nets})
    assert server.dispatch_counts["stacked"] == 1
    for name, net in nets.items():
        np.testing.assert_array_equal(out[name], _ref(net, x), err_msg=name)

    spans = [r.as_dict() for r in telemetry.get_registry().spans()]
    rounds = [r for r in spans if r.get("name") == "netgen.kernel"
              and (r.get("attrs") or {}).get("form") == "fusednet"]
    assert rounds, "stacked bit-plane dispatch did not use the megakernel"
    assert all((r["attrs"] or {}).get("launches") == 1 for r in rounds)
    samples = [("netgen_kernel_launches_total", {"form": "fusednet"},
                float(telemetry.kernel_launches("fusednet").value))]
    assert check_launches(spans, samples) == []


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

def test_launch_counter_one_vs_depth():
    """The counter IS the claim: a fusednet forward is one launch, the
    per-layer planes chain is `depth` launches."""
    net = random_net(40, (24, 10, 8, 4), lo=-5, hi=5)
    x = jnp.asarray(images(40, 5, 24))
    fused = _fused(net)
    chain = netgen.specialize(net, backend="pallas[planes=true]")
    assert fused.launches_per_call == 1
    assert chain.launches_per_call == 3
    c_fused = telemetry.kernel_launches("fusednet")
    c_chain = telemetry.kernel_launches("planes")
    base_f, base_c = c_fused.value, c_chain.value
    np.asarray(fused(x)), np.asarray(chain(x))
    assert c_fused.value - base_f == 1
    assert c_chain.value - base_c == 3
    np.asarray(fused(x))
    assert c_fused.value - base_f == 2


def test_check_launches_gate_rejects_multi_launch_round():
    """The CI gate itself: a fusednet round claiming 2 launches, or a
    counter that undercounts the rounds, must fail; a trace with no
    fusednet traffic is a no-op."""
    def span(launches):
        return {"name": "netgen.kernel", "span_id": 1,
                "attrs": {"form": "fusednet", "launches": launches}}
    counter = [("netgen_kernel_launches_total", {"form": "fusednet"}, 1.0)]
    assert check_launches([span(1)], counter) == []
    assert any("launches=2" in e
               for e in check_launches([span(2)], counter))
    starved = [("netgen_kernel_launches_total", {"form": "fusednet"}, 0.0)]
    assert any("only 0" in e for e in check_launches([span(1)], starved))
    plain = [{"name": "netgen.kernel", "span_id": 1,
              "attrs": {"form": "planes", "launches": 3}}]
    assert check_launches(plain, starved) == []


# ---------------------------------------------------------------------------
# Interpret-mode parity (the CPU-only CI path)
# ---------------------------------------------------------------------------

def test_interpret_mode_kernel_parity():
    """Direct kernel call with interpret pinned on — the only mode this
    container (and CI) can run — stays bit-exact, including a batch
    that is not a multiple of the default batch tile."""
    net = random_net(50, (61, 29, 6), lo=-9, hi=9)
    view = lower_circuit(netgen.lower(net)).megakernel_view()
    x = images(50, 37, 61)                      # 37: pads to the bm tile
    got = np.asarray(ops.binary_forward_planes(
        jnp.asarray(x), *[jnp.asarray(a) for a in view.arrays],
        threshold=view.input_threshold, n_classes=view.n_classes,
        interpret=True))
    np.testing.assert_array_equal(got, _ref(net, x))
