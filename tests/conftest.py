"""Test-suite defaults.

Strict verification is opt-in in production (`NETGEN_VERIFY` unset ->
off, compiles count `netgen_verify_failures_total` and proceed) but
every test run should catch a broken rewrite immediately, so the suite
turns it on unless the environment already pinned a value (tests that
need the permissive path set `verify=False` explicitly).
"""
import os

os.environ.setdefault("NETGEN_VERIFY", "1")
