"""ssd_scan kernel vs sequential-recurrence oracle (Mamba2 SSD)."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro.kernels.ssd_scan import ops, ref


def _inputs(b, l, h, g, p, n, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, l, h, p)).astype(dtype)
    dt = rng.uniform(0.001, 0.1, size=(b, l, h)).astype(dtype)
    a = -rng.uniform(0.5, 2.0, size=(h,)).astype(np.float32)
    bb = rng.normal(size=(b, l, g, n)).astype(dtype) / np.sqrt(n)
    cc = rng.normal(size=(b, l, g, n)).astype(dtype) / np.sqrt(n)
    return x, dt, a, bb, cc


def test_chunked_ref_matches_sequential():
    """The SSD chunk decomposition is exact vs the recurrence."""
    x, dt, a, b, c = _inputs(1, 128, 2, 1, 16, 32, seed=0)
    y1, s1 = ref.ssd_sequential_ref(
        jnp.asarray(x[0, :, 0]), jnp.asarray(dt[0, :, 0]), float(a[0]),
        jnp.asarray(b[0, :, 0]), jnp.asarray(c[0, :, 0]))
    y2, s2 = ref.ssd_chunked_ref(
        jnp.asarray(x[0, :, 0]), jnp.asarray(dt[0, :, 0]), float(a[0]),
        jnp.asarray(b[0, :, 0]), jnp.asarray(c[0, :, 0]), chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,l,h,g,p,n,chunk", [
    (1, 64, 1, 1, 16, 32, 16),
    (2, 128, 4, 2, 32, 64, 64),
    (1, 256, 2, 1, 64, 128, 64),   # production-like head dims
    (2, 64, 8, 8, 16, 16, 32),     # groups == heads
])
def test_ssd_kernel_matches_oracle(b, l, h, g, p, n, chunk):
    x, dt, a, bb, cc = _inputs(b, l, h, g, p, n, seed=l + h)
    y, s = ops.ssd(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                   jnp.asarray(bb), jnp.asarray(cc), chunk=chunk)
    yref, sref = ref.ssd_batched_ref(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                                     jnp.asarray(bb), jnp.asarray(cc), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    x, dt, a, bb, cc = _inputs(1, 64, 2, 1, 16, 32, seed=9)
    y, s = ops.ssd(jnp.asarray(x, dtype), jnp.asarray(dt, dtype), jnp.asarray(a),
                   jnp.asarray(bb, dtype), jnp.asarray(cc, dtype), chunk=32)
    assert y.dtype == dtype
    yref, _ = ref.ssd_batched_ref(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(a),
                                  jnp.asarray(bb), jnp.asarray(cc), chunk=32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yref), rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(l_chunks=st.integers(1, 4), p=st.sampled_from([8, 16]),
       n=st.sampled_from([16, 32]), seed=st.integers(0, 2**31 - 1))
def test_ssd_property_state_consistency(l_chunks, p, n, seed):
    """Property: running the scan over [0:L] equals running [0:L/2] then
    [L/2:L] seeded with the midpoint state (checkpointable recurrence)."""
    l = 64 * l_chunks
    x, dt, a, bb, cc = _inputs(1, l, 1, 1, p, n, seed=seed)
    args = (jnp.asarray(x[0, :, 0]), jnp.asarray(dt[0, :, 0]), float(a[0]),
            jnp.asarray(bb[0, :, 0]), jnp.asarray(cc[0, :, 0]))
    y_full, s_full = ref.ssd_chunked_ref(*args, chunk=32)
    half = l // 2
    if half % 32 != 0:
        return
    y1, s1 = ref.ssd_chunked_ref(args[0][:half], args[1][:half], args[2],
                                 args[3][:half], args[4][:half], chunk=32)
    y2, s2 = ref.ssd_chunked_ref(args[0][half:], args[1][half:], args[2],
                                 args[3][half:], args[4][half:], chunk=32, s_init=s1)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2])), np.asarray(y_full), rtol=1e-4, atol=1e-4)
