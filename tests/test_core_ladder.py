"""Paper-core tests: optimization ladder, netgen rewrites, Verilog artifact.

These encode the paper's own claims as assertions:
  * ladder accuracies stay high and close to the fp32 baseline (§III),
  * L4 pruning and L5 mult-free/specialized backends are EXACT rewrites,
  * netgen's resource model shows the pruning/addend savings (§V.D),
  * the emitted Verilog matches the structure of the paper's Figure 6.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import dataset, mlp, netgen, quantize
from repro.core.ladder import run_ladder


@pytest.fixture(scope="module")
def trained_small():
    """A small-but-real trained net (fast); full-size is exercised in
    benchmarks. 256 hidden units train in seconds and reach >90%."""
    xtr, ytr, xte, yte = dataset.train_test_split(800, 400, seed=3)
    cfg = mlp.MLPConfig(n_hidden=256, epochs=40, lr=2.0, seed=7)
    params = mlp.train(cfg, xtr, ytr)
    return params, xte, yte


def test_ladder_accuracy_pattern(trained_small):
    params, xte, yte = trained_small
    a0 = mlp.accuracy(mlp.predict_l0(params), xte, yte)
    a1 = mlp.accuracy(quantize.predict_l1(params), xte, yte)
    a2 = mlp.accuracy(quantize.predict_l2(params), xte, yte)
    a3 = mlp.accuracy(quantize.predict_l3(params), xte, yte)
    assert a0 > 0.85, a0
    # paper: each simplification costs only a few points (98->95->94->92)
    assert a1 > a0 - 0.10 and a2 > a0 - 0.10 and a3 > a0 - 0.10, (a0, a1, a2, a3)


def test_l4_l5_exact_rewrites(trained_small):
    params, xte, _ = trained_small
    qnet = quantize.quantize(params)
    l3 = quantize.predict_l3(params)(jnp.asarray(xte))
    for backend in ("jnp", "pallas", "fused"):
        got = netgen.specialize(qnet, backend=backend)(jnp.asarray(xte))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(l3)), backend


def test_prune_is_exact():
    rng = np.random.default_rng(0)
    w1 = rng.integers(-3, 4, size=(20, 16)).astype(np.int32)
    w2 = rng.integers(-3, 4, size=(16, 5)).astype(np.int32)
    w1[:, 3] = 0          # dead hidden unit (no inputs)
    w2[7, :] = 0          # dead hidden unit (no outputs)
    net = quantize.QuantizedNet(w1=w1, w2=w2)
    pruned, info = netgen.prune(net)
    assert info.hidden_removed == 2
    x = jnp.asarray(rng.integers(0, 256, size=(32, 20)).astype(np.uint8))
    a = netgen.specialize(net, backend="jnp")(x)
    b = netgen.specialize(pruned, backend="jnp")(x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_netgen_stats_savings(trained_small):
    params, _, _ = trained_small
    st = netgen.stats(quantize.quantize(params))
    assert st.mults_addend == 0                       # L5: no multiplies
    assert st.mults_pruned < st.mults_dense           # L4: pruning removed terms
    assert 0.05 < st.zero_fraction < 0.95


def test_verilog_structure():
    """Emitted Verilog mirrors the paper's Figure 6 building blocks."""
    rng = np.random.default_rng(1)
    net = quantize.QuantizedNet(
        w1=rng.integers(-9, 10, size=(3, 3)).astype(np.int32),
        w2=rng.integers(-9, 10, size=(3, 3)).astype(np.int32),
    )
    v = netgen.emit_verilog(net, addend=True)
    assert "module nn_inference" in v and "endmodule" in v
    assert "(px0 > 128) ? 1'b1 : 1'b0" in v          # input comparator
    assert "~hi0[" in v                               # MSB step trick (§V.D)
    assert "assign prediction" in v                   # argmax mux
    assert "*" not in v.split("// hidden-input sums")[1].split("// step")[0], (
        "addend form must contain no multiplies")
    # mult-style emission keeps multiplies for nonunit weights
    v2 = netgen.emit_verilog(net, addend=False)
    assert "endmodule" in v2


def test_full_ladder_smoke():
    """End-to-end mini-ladder run (small sizes for CI speed)."""
    r = run_ladder(n_train=400, n_test=200, epochs=30, seed=5,
                   backends=("jnp", "pallas"))
    assert r.exact_l4_l5
    assert r.acc["L0_baseline"] > 0.6
    assert r.stats.mults_addend == 0
