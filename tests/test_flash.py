"""Flash chunked attention vs dense oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.layers.flash import flash_attention, flash_attention_ref


@pytest.mark.parametrize("b,h,kv,s,hd,qb,kb", [
    (2, 4, 4, 256, 32, 64, 64),
    (1, 8, 2, 512, 64, 128, 128),    # GQA rep=4
    (2, 4, 1, 256, 32, 64, 128),     # MQA, uneven blocks
    (1, 4, 4, 384, 16, 128, 128),    # S not multiple of k_blk? 384%128==0 ok
])
def test_flash_matches_dense(b, h, kv, s, hd, qb, kb):
    rng = np.random.default_rng(s + hd)
    q = jnp.asarray(rng.normal(size=(b, h, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, kv, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, kv, s, hd)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, q_blk=qb, k_blk=kb)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 4, 256, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 32)), jnp.bfloat16)
    got = flash_attention(q, k, v, q_blk=64, k_blk=64)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_grad_finite():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 16)).astype(np.float32))
    g = jax.grad(lambda q_: flash_attention(q_, k, v, q_blk=64, k_blk=64).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("kv,qb,kb", [(4, 64, 64), (2, 64, 128), (1, 128, 64)])
def test_flash_custom_vjp_matches_dense_autodiff(kv, qb, kb):
    """The two-pass recomputation backward == autodiff of dense attention."""
    rng = np.random.default_rng(kv * 100 + qb)
    q = jnp.asarray(rng.normal(size=(2, 4, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, kv, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, kv, 256, 32)).astype(np.float32))
    # weighted sum so every position matters differently
    w = jnp.asarray(rng.normal(size=(2, 4, 256, 32)).astype(np.float32))
    f = lambda *a: (flash_attention(*a, q_blk=qb, k_blk=kb) * w).sum()
    g = lambda *a: (flash_attention_ref(*a) * w).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)
