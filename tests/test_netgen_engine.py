"""Async online serving engine tests (ISSUE 7 tentpole): admission /
rejection semantics, continuous slot batching, futures plumbing under
N-producer x M-version Poisson load (bit-exact vs direct Artifact
calls, no lost or duplicated futures), deadline rejections, drain vs
fail-fast shutdown, and the no-thread-leak contract (reusing the PR-6
harness pattern: filter `threading.enumerate()` by thread-name prefix
and gc-collect the dropped engine)."""
import gc
import os
import sys
import threading
import time

import numpy as np
import pytest

from repro import netgen
from repro.netgen.engine import (
    DeadlineExceededError, EngineClosedError, QueueFullError, ServingEngine,
)

from _netgen_helpers import images, random_net

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(__file__)), "benchmarks"))
from check_trace import check_metrics, parse_prometheus  # noqa: E402

SIZES = (12, 9, 4)


def _net(seed: int, sizes=SIZES):
    return random_net(seed, sizes, lo=-5, hi=5)


def _images(seed: int, b: int, n_in: int = SIZES[0]) -> np.ndarray:
    return images(seed, b, n_in, salt=55)


def _engine_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("netgen-engine")]


def _gated_target(name: str):
    """Register a callable fake target whose artifacts block on `gate`
    and flag `in_call` — the deterministic way to hold the batcher
    inside a dispatch while a test inspects the queue."""
    gate = threading.Event()
    in_call = threading.Event()

    def compile_gated(circuit, **opts):
        n = circuit.n_inputs  # noqa: F841 — shape sanity via closure

        def artifact(x):
            in_call.set()
            assert gate.wait(10.0), "test gate never released"
            return np.zeros((np.asarray(x).shape[0],), np.int64)
        return artifact

    netgen.register_target(netgen.Target(
        name=name, kind="callable",
        description="test-only gated predictor", compile=compile_gated))
    return gate, in_call


# ---------------------------------------------------------------------------
# Admission semantics
# ---------------------------------------------------------------------------

def test_submit_resolves_future_bit_exact():
    with ServingEngine(target="jnp", slot_capacity=8,
                       max_batch_delay=0.001) as eng:
        art = eng.register("v", _net(0))
        xs = _images(1, 20)
        futs = [eng.submit("v", x) for x in xs]
        got = np.array([f.result(timeout=10) for f in futs])
        assert np.array_equal(got, np.asarray(art(xs)))
        assert eng.infer("v", xs[0]) == int(np.asarray(art(xs[:1]))[0])
    st = eng.stats()
    assert st.submitted == st.completed == 21
    assert st.queue_depth == 0 and st.batches >= 1


def test_submit_rejects_unknown_version_and_bad_input():
    with ServingEngine(target="jnp", slot_capacity=4) as eng:
        eng.register("v", _net(1))
        with pytest.raises(KeyError):
            eng.submit("nope", _images(2, 1)[0])
        with pytest.raises(ValueError):          # batches go to NetServer
            eng.submit("v", _images(2, 3))
        with pytest.raises(TypeError):           # non-uint8
            eng.submit("v", _images(2, 1)[0].astype(np.float32))
        with pytest.raises(ValueError):          # wrong width
            eng.submit("v", _images(2, 1, n_in=5)[0])
    assert eng.stats().submitted == 0


def test_engine_constructor_validation():
    with pytest.raises(ValueError):
        ServingEngine(target="jnp", max_batch_delay=-1.0)
    with pytest.raises(ValueError):
        ServingEngine(target="jnp", max_queue_depth=0)
    server = netgen.NetServer(slot_capacity=2)
    with pytest.raises(ValueError):              # server XOR session/target
        ServingEngine(server, target="jnp")
    with ServingEngine(server) as eng:
        assert eng.server is server


def test_session_engine_shares_compile_tier():
    with netgen.Session(capacity=8) as sess:
        with sess.engine(slot_capacity=4, max_batch_delay=0.0) as eng:
            assert eng.server.cache is sess.cache
            eng.register("v", _net(2))
            assert sess.stats().misses == 1
            assert eng.infer("v", _images(3, 1)[0]) in range(SIZES[-1])


# ---------------------------------------------------------------------------
# SLO knobs: queue bound, deadlines
# ---------------------------------------------------------------------------

def test_queue_full_rejection_is_explicit():
    gate, in_call = _gated_target("gatedfake_qfull")
    gate.set()                                   # let warmup through
    eng = ServingEngine(target="gatedfake_qfull", slot_capacity=1,
                        max_batch_delay=0.0, max_queue_depth=2)
    try:
        eng.register("v", _net(3))
        gate.clear()
        in_call.clear()
        x = _images(4, 1)[0]
        first = eng.submit("v", x)               # batcher blocks in dispatch
        assert in_call.wait(10.0)
        q1, q2 = eng.submit("v", x), eng.submit("v", x)   # fill the queue
        with pytest.raises(QueueFullError):
            eng.submit("v", x)                   # explicit shedding
        assert eng.stats().rejected_queue_full == 1
        gate.set()
        assert first.result(timeout=10) == 0
        assert q1.result(timeout=10) == 0 and q2.result(timeout=10) == 0
    finally:
        gate.set()
        eng.shutdown()


def test_deadline_expired_in_queue_is_rejected():
    # slot_capacity far above the offered load + a long batch delay: the
    # batcher provably sits on the requests long enough for the tight
    # deadline to expire before dispatch
    with ServingEngine(target="jnp", slot_capacity=64,
                       max_batch_delay=0.25) as eng:
        art = eng.register("v", _net(5))
        xs = _images(6, 4)
        doomed = eng.submit("v", xs[0], deadline=1e-4)
        live = [eng.submit("v", x) for x in xs[1:]]
        with pytest.raises(DeadlineExceededError):
            doomed.result(timeout=10)
        got = np.array([f.result(timeout=10) for f in live])
        assert np.array_equal(got, np.asarray(art(xs[1:])))
    st = eng.stats()
    assert st.rejected_deadline == 1
    assert st.completed == 3


# ---------------------------------------------------------------------------
# Shutdown: drain vs fail-fast, closed admission, no thread leak
# ---------------------------------------------------------------------------

def test_shutdown_drains_accepted_requests():
    eng = ServingEngine(target="jnp", slot_capacity=4, max_batch_delay=0.2)
    art = eng.register("v", _net(7))
    xs = _images(8, 6)
    futs = [eng.submit("v", x) for x in xs]
    eng.shutdown()                               # drain=True default
    got = np.array([f.result(timeout=1) for f in futs])
    assert np.array_equal(got, np.asarray(art(xs)))
    with pytest.raises(EngineClosedError):
        eng.submit("v", xs[0])
    assert eng.stats().rejected_closed == 1
    eng.shutdown()                               # idempotent
    assert not _engine_threads()


def test_shutdown_without_drain_fails_pending():
    gate, in_call = _gated_target("gatedfake_drain")
    gate.set()
    eng = ServingEngine(target="gatedfake_drain", slot_capacity=1,
                        max_batch_delay=0.0, max_queue_depth=64)
    try:
        eng.register("v", _net(9))
        gate.clear()
        in_call.clear()
        x = _images(10, 1)[0]
        inflight = eng.submit("v", x)            # blocks inside dispatch
        assert in_call.wait(10.0)
        queued = eng.submit("v", x)              # still in the queue
        eng.shutdown(drain=False, timeout=0.2)   # thread still gated: ok
        with pytest.raises(EngineClosedError):
            queued.result(timeout=1)
        assert eng.stats().rejected_closed == 1
    finally:
        gate.set()
    assert inflight.result(timeout=10) == 0      # in-flight work completes
    eng.shutdown()


def test_dropped_engine_leaks_no_threads():
    eng = ServingEngine(target="jnp", slot_capacity=4, max_batch_delay=0.0)
    eng.register("v", _net(11))
    assert eng.infer("v", _images(12, 1)[0]) in range(SIZES[-1])
    assert _engine_threads()
    del eng
    gc.collect()
    deadline = time.time() + 5.0
    while _engine_threads() and time.time() < deadline:
        time.sleep(0.01)
    assert not _engine_threads(), "batcher thread leaked after GC"


# ---------------------------------------------------------------------------
# The tentpole under load: N producers x M versions, seeded Poisson
# ---------------------------------------------------------------------------

def test_concurrent_poisson_load_bit_exact_no_lost_futures():
    m, producers, per_producer = 3, 6, 25
    nets = {f"v{i}": _net(20 + i) for i in range(m)}
    with ServingEngine(target="jnp", slot_capacity=8,
                       max_batch_delay=0.002,
                       max_queue_depth=1 << 14) as eng:
        arts = {v: eng.register(v, net) for v, net in nets.items()}
        results: list[list] = [[] for _ in range(producers)]

        def producer(k: int) -> None:
            rng = np.random.default_rng(1000 + k)
            for i in range(per_producer):
                v = f"v{rng.integers(0, m)}"
                x = _images(int(rng.integers(1 << 16)), 1)[0]
                results[k].append((v, x, eng.submit(v, x)))
                time.sleep(float(rng.exponential(0.0005)))

        threads = [threading.Thread(target=producer, args=(k,))
                   for k in range(producers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        flat = [r for rs in results for r in rs]
        # no lost futures: every submit resolved, each exactly one result
        assert len(flat) == producers * per_producer
        for v, x, fut in flat:
            want = int(np.asarray(arts[v](x[None, :]))[0])
            assert fut.result(timeout=30) == want
            assert fut.done() and fut.exception() is None
    st = eng.stats()
    assert st.submitted == st.completed == producers * per_producer
    assert (st.rejected_queue_full, st.rejected_deadline,
            st.rejected_closed) == (0, 0, 0)
    assert st.queue_depth == 0
    # continuous batching actually batched: fewer dispatches than requests
    assert st.batches < st.submitted
    assert not _engine_threads()
    # the CI metrics gate holds on the engine's own telemetry too
    # (including latency-count == request-count per served version)
    assert check_metrics(parse_prometheus(netgen.telemetry.prometheus())) \
        == []
