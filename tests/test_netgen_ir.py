"""repro.netgen compiler tests: IR, passes, backend parity, golden Verilog.

Backend parity is the load-bearing property (ISSUE acceptance): for
random nets of depth 2 and 3, the jnp and pallas backends and the IR
interpreter must agree bit-exactly with the reference L3 dense path
(`quantize.predict_quantized`). The Verilog backend is pinned to the
seed emitter's bytes via golden files.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import netgen as shim
from repro.core import quantize
from repro import netgen

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from _netgen_helpers import images, random_net

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


def _random_net(seed: int, sizes: tuple[int, ...], lo: int = -9, hi: int = 9):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=99)


# ---------------------------------------------------------------------------
# Backend parity (the acceptance property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", [(12, 10, 4), (9, 8, 6, 5), (20, 16, 5)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_backend_parity(sizes, seed):
    """jnp == pallas == interpreter == reference L3 path, depths 2 and 3."""
    net = _random_net(seed, sizes)
    x = _images(seed, 48, sizes[0])
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))

    circuit, _ = netgen.run_pipeline(netgen.lower(net))
    interp = netgen.evaluate(circuit, x, check_widths=True)
    np.testing.assert_array_equal(interp, ref)
    for backend in ("jnp", "pallas"):
        got = np.asarray(netgen.specialize(net, backend=backend)(jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref, err_msg=backend)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_in=st.integers(2, 24),
       n_h=st.integers(1, 16), n_out=st.integers(2, 8),
       depth3=st.integers(0, 1))
def test_backend_parity_property(seed, n_in, n_h, n_out, depth3):
    sizes = (n_in, n_h, n_h, n_out) if depth3 else (n_in, n_h, n_out)
    net = _random_net(seed, sizes, lo=-4, hi=4)
    x = _images(seed, 16, n_in)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
    circuit, _ = netgen.run_pipeline(netgen.lower(net))
    np.testing.assert_array_equal(netgen.evaluate(circuit, x), ref)
    got = np.asarray(netgen.specialize(net, backend="jnp")(jnp.asarray(x)))
    np.testing.assert_array_equal(got, ref)


def test_fused_backend_2layer_only():
    net2 = _random_net(3, (12, 10, 4))
    x = _images(3, 32, 12)
    ref = np.asarray(quantize.predict_quantized(net2)(jnp.asarray(x)))
    got = np.asarray(netgen.specialize(net2, backend="fused")(jnp.asarray(x)))
    np.testing.assert_array_equal(got, ref)
    with pytest.raises(netgen.IrregularCircuitError):
        netgen.specialize(_random_net(3, (9, 8, 6, 5)), backend="fused")


# ---------------------------------------------------------------------------
# Passes: exactness and claimed savings
# ---------------------------------------------------------------------------

def _exact_under(pass_fn, circuit, x):
    before = netgen.evaluate(circuit, x)
    after_c = pass_fn(circuit)
    after_c.validate()
    np.testing.assert_array_equal(netgen.evaluate(after_c, x), before)
    return after_c


def test_passes_are_exact_rewrites():
    rng = np.random.default_rng(7)
    ws = [rng.integers(-4, 5, size=s).astype(np.int32)
          for s in [(14, 12), (12, 9), (9, 5)]]
    ws[0][:, 2] = 0       # dead unit: no inputs
    ws[1][5, :] = 0       # dead unit: no outputs
    x = _images(7, 64, 14)
    c = netgen.lower(ws, input_threshold=128)
    c = _exact_under(netgen.delete_zero_terms, c, x)
    c = _exact_under(netgen.prune_dead_units, c, x)
    c = _exact_under(netgen.addend_rewrite, c, x)
    _exact_under(netgen.share_common_addends, c, x)


def test_pass_stats_claims():
    rng = np.random.default_rng(11)
    net = quantize.QuantizedNet(
        w1=rng.integers(-3, 4, size=(16, 12)).astype(np.int32),
        w2=rng.integers(-3, 4, size=(12, 5)).astype(np.int32))
    _, stats = netgen.run_pipeline(netgen.lower(net), netgen.HW_PASSES)
    by_name = {s.name: s for s in stats}
    # L4: zero terms really deleted
    assert by_name["delete_zero_terms"].terms_deleted > 0
    # L5: multiplication-free after the addend rewrite
    assert by_name["addend_rewrite"].after.mults == 0
    # CSE: strictly fewer two-input adders, never more
    assert by_name["share_common_addends"].adds_saved > 0


def test_prune_dead_units_cascade():
    """An unread unit in layer 2 strands its layer-1 feeder; pruning runs
    to fixpoint and removes both."""
    w1 = np.ones((4, 2), np.int32)                    # units A0, A1
    w2 = np.eye(2, dtype=np.int32)                    # B0 <- A0, B1 <- A1
    w3 = np.zeros((2, 2), np.int32); w3[0, :] = 1     # only B0 is read
    c, _ = netgen.run_pipeline(
        netgen.lower([w1, w2, w3], input_threshold=128), netgen.DEFAULT_PASSES)
    hidden = [n for n in c.by_kind(netgen.WeightedSum) if n.layer < c.depth]
    # B1 is unread -> deleted; that strands A1 -> deleted too
    assert sum(1 for n in hidden if n.layer == 1) == 1
    assert sum(1 for n in hidden if n.layer == 2) == 1
    x = _images(0, 16, 4)
    ref = np.asarray(quantize.predict_quantized(
        quantize.QuantizedNet(weights=[w1, w2, w3]))(jnp.asarray(x)))
    np.testing.assert_array_equal(netgen.evaluate(c, x), ref)


def test_fully_dead_hidden_layer():
    """A hidden layer pruned down to zero units must still compile (the
    seed's boolean-mask prune produced a constant-0 predictor; the IR path
    reconstructs it as a zero-width matrix, not a crash)."""
    net = quantize.QuantizedNet(
        w1=np.ones((4, 3), np.int32), w2=np.zeros((3, 2), np.int32))
    pruned, info = shim.prune(net)
    assert info.n_hidden_after == 0 and pruned.w1.shape == (4, 0)
    x = _images(6, 16, 4)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
    for backend in ("jnp", "pallas"):
        got = np.asarray(netgen.specialize(net, backend=backend)(jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref, err_msg=backend)
    circuit, _ = netgen.run_pipeline(netgen.lower(net))
    np.testing.assert_array_equal(netgen.evaluate(circuit, x), ref)


def test_share_common_addends_full_784_input_net_bucketed():
    """The bucketed CSE on a full-width (784-input) net (the ROADMAP
    "Scale" item, un-slow-marked): (sign, magnitude) bucketing keeps the
    candidate search ~O(terms * bucket), so a reduced budget completes
    inside the default suite while staying an exact rewrite and
    reporting nonzero adder sharing."""
    rng = np.random.default_rng(0)
    net = quantize.QuantizedNet(weights=[
        rng.integers(-2, 3, size=(784, 4)).astype(np.int32),
        rng.integers(-2, 3, size=(4, 10)).astype(np.int32)])

    shared, stats = netgen.PipelineSpec.parse(
        "zeros,cse[budget=8,bucketed=true]").run(netgen.lower(net))
    cse = stats[-1]
    assert cse.name == "cse[bucketed=true,budget=8]"
    assert cse.adds_saved > 0                      # nonzero sharing reported
    assert cse.after.nodes > cse.before.nodes      # shared sub-sums exist
    with pytest.raises(netgen.IrregularCircuitError):
        netgen.as_layered_weights(shared)
    x = _images(0, 24, 784)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
    np.testing.assert_array_equal(netgen.evaluate(shared, x), ref)


@pytest.mark.slow
def test_share_common_addends_full_784_input_net_exhaustive():
    """The classic exhaustive greedy search at the same scale (slow: the
    pair counting is O(terms^2) per round) must agree with the bucketed
    variant on exactness and also find sharing."""
    rng = np.random.default_rng(0)
    net = quantize.QuantizedNet(weights=[
        rng.integers(-2, 3, size=(784, 4)).astype(np.int32),
        rng.integers(-2, 3, size=(4, 10)).astype(np.int32)])

    shared, stats = netgen.PipelineSpec.parse(
        "zeros,cse[budget=2]").run(netgen.lower(net))
    assert stats[-1].adds_saved > 0
    x = _images(0, 24, 784)
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))
    np.testing.assert_array_equal(netgen.evaluate(shared, x), ref)


def test_share_common_addends_shares():
    # two accumulators with an identical 3-term tail: CSE must factor it
    w1 = np.array([[1, 1], [1, 1], [1, 1], [1, 0]], np.int32)
    w2 = np.ones((2, 2), np.int32)
    c = netgen.lower([w1, w2], input_threshold=128)
    before = netgen.ops(c)
    shared, _ = netgen.run_pipeline(c, (netgen.share_common_addends,))
    after = netgen.ops(shared)
    assert after.adds < before.adds
    assert after.nodes > before.nodes  # shared sub-sum nodes exist
    with pytest.raises(netgen.IrregularCircuitError):
        netgen.as_layered_weights(shared)
    x = _images(1, 32, 4)
    np.testing.assert_array_equal(
        netgen.evaluate(shared, x), netgen.evaluate(c, x))


# ---------------------------------------------------------------------------
# Bit-width inference and step semantics
# ---------------------------------------------------------------------------

def test_node_widths_exact():
    w1 = np.array([[3], [-4]], np.int32)       # |w| sum = 7 -> 4 bits signed
    w2 = np.array([[1], [1]], np.int32)[:1]    # 1 term of a 1-bit src
    c = netgen.lower([w1, w2], input_threshold=128)
    widths = netgen.node_widths(c)
    sums = c.by_kind(netgen.WeightedSum)
    assert widths[sums[0].id] == 4             # [-7, 7] needs 4 signed bits
    assert widths[sums[1].id] == 2             # [0, 1] signed


def test_step_semantics_diverge_only_at_zero():
    """The emitted Verilog's MSB trick fires on acc >= 0; the compiled
    backends on acc > 0. A weight row summing to exactly 0 exposes it."""
    w1 = np.array([[1], [-1]], np.int32)       # acc == 0 when both bits equal
    w2 = np.array([[0, 1]], np.int32)          # the step bit elects class 1
    c = netgen.lower([w1, w2], input_threshold=128)
    x = np.array([[255, 255]], np.uint8)       # both comparators fire -> acc 0
    strict = netgen.evaluate(c, x, step_semantics="strict")
    msb = netgen.evaluate(c, x, step_semantics="msb")
    assert strict[0] == 0 and msb[0] == 1


# ---------------------------------------------------------------------------
# Verilog backend: golden files and generic style
# ---------------------------------------------------------------------------

def _golden_net():
    rng = np.random.default_rng(1)
    return quantize.QuantizedNet(
        w1=rng.integers(-9, 10, size=(3, 3)).astype(np.int32),
        w2=rng.integers(-9, 10, size=(3, 3)).astype(np.int32))


@pytest.mark.parametrize("addend,fname", [
    (True, "nn_inference_3x3.v"), (False, "nn_inference_3x3_mult.v")])
def test_verilog_golden(addend, fname):
    """Byte-identical to the seed emitter (captured before the rewrite)."""
    with open(os.path.join(GOLDEN, fname)) as f:
        want = f.read()
    assert netgen.emit_verilog(_golden_net(), addend=addend) == want
    assert shim.emit_verilog(_golden_net(), addend=addend) == want


def test_verilog_generic_3layer():
    net = _random_net(5, (6, 5, 4, 3))
    v = netgen.compile_net(net, backend="verilog", passes=netgen.HW_PASSES).artifact
    assert "module nn_inference" in v and "endmodule" in v
    assert "// 6-5-4-3 feed-forward classifier" in v
    assert "s1_0" in v and "a2_0" in v and "fi0" in v
    # HW pipeline is multiplication-free
    assert "*" not in v.split(");", 1)[1].split("// prediction")[0]
    # this net has repeated addend pairs -> CSE wires must be emitted
    assert "shared sub-sums" in v and "t0" in v


# ---------------------------------------------------------------------------
# Shim + multi-layer core plumbing
# ---------------------------------------------------------------------------

def test_shim_prune_matches_seed_behavior():
    rng = np.random.default_rng(0)
    w1 = rng.integers(-9, 10, size=(20, 16)).astype(np.int32)
    w2 = rng.integers(-9, 10, size=(16, 5)).astype(np.int32)
    w1[:, 3] = 0
    w2[7, :] = 0
    pruned, info = shim.prune(quantize.QuantizedNet(w1=w1, w2=w2))
    assert info.n_hidden_before == 16 and info.hidden_removed == 2
    alive = [j for j in range(16) if j not in (3, 7)]
    np.testing.assert_array_equal(pruned.w1, w1[:, alive])
    np.testing.assert_array_equal(pruned.w2, w2[alive, :])


def test_shim_stats_multilayer():
    net = _random_net(2, (10, 8, 6, 4), lo=-3, hi=3)
    st_ = shim.stats(net)
    total = sum(w.size for w in net.weights)
    nnz = sum(int(np.count_nonzero(w)) for w in net.weights)
    assert st_.mults_dense == total and st_.mults_pruned == nnz
    assert st_.mults_addend == 0
    assert st_.adds_addend == sum(int(np.abs(w).sum()) for w in net.weights)


def test_quantized_net_compat_accessors():
    net2 = _random_net(4, (5, 4, 3))
    assert net2.w1.shape == (5, 4) and net2.w2.shape == (4, 3)
    assert net2.shapes == ((5, 4), (4, 3))
    net3 = _random_net(4, (5, 4, 3, 2))
    assert net3.depth == 3
    with pytest.raises(AttributeError):
        _ = net3.w1


def test_multilayer_train_quantize_compile():
    """3-layer end to end through the real ladder: train -> quantize ->
    compile -> parity with the reference path."""
    from repro.core import dataset, mlp

    xtr, ytr, xte, _ = dataset.train_test_split(200, 64, seed=9)
    cfg = mlp.MLPConfig(n_hidden=(32, 16), epochs=8, lr=1.0, seed=9)
    params = mlp.train(cfg, xtr, ytr)
    assert sorted(params) == ["w1", "w2", "w3"]
    qnet = quantize.quantize(params)
    assert qnet.depth == 3
    ref = np.asarray(quantize.predict_quantized(qnet)(jnp.asarray(xte)))
    l3 = np.asarray(quantize.predict_l3(params)(jnp.asarray(xte)))
    np.testing.assert_array_equal(ref, l3)
    for backend in ("jnp", "pallas"):
        got = np.asarray(shim.specialize(qnet, backend=backend)(jnp.asarray(xte)))
        np.testing.assert_array_equal(got, ref, err_msg=backend)
    v = shim.emit_verilog(qnet)
    assert "feed-forward classifier" in v and "endmodule" in v
