"""Telemetry subsystem tests (ISSUE 6): metric primitives (exact
percentiles, atomic counters under thread hammer), span nesting and
JSONL export, the instrumented compile/store/serve lifecycle, the
concurrent-serving histogram/occupancy/parentage invariants, Session
executor lifecycle (finalizer + context manager), and the
`benchmarks/check_trace.py` CI gate functions."""
import gc
import json
import os
import sys
import threading
import time

import numpy as np
import pytest

from repro import netgen
from repro.netgen import telemetry

from _netgen_helpers import images, random_net

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(__file__)), "benchmarks"))
from check_trace import (  # noqa: E402
    check_metrics, check_spans, check_trace_dir, parse_prometheus,
)

SIZES = (12, 9, 4)


@pytest.fixture(autouse=True)
def _clean_registry():
    """Each test starts with zeroed metrics and no retained spans, and
    leaves tracing disabled for the rest of the suite."""
    telemetry.reset()
    yield
    telemetry.disable()
    telemetry.reset()


def _net(seed: int):
    return random_net(seed, SIZES, lo=-5, hi=5)


def _x(seed: int, b: int) -> np.ndarray:
    return images(seed, b, SIZES[0], salt=77)


# ---------------------------------------------------------------------------
# Metric primitives
# ---------------------------------------------------------------------------

def test_histogram_exact_percentiles():
    h = telemetry.Histogram("h", {})
    for v in range(1, 101):                  # 1..100, shuffled in
        h.observe(((v * 37) % 100) + 1)
    assert h.count == 100
    assert h.p50 == 50
    assert h.p95 == 95
    assert h.p99 == 99
    assert h.percentile(1.0) == 100
    assert h.mean == pytest.approx(50.5)
    empty = telemetry.Histogram("e", {})
    assert empty.p50 == 0.0 and empty.count == 0
    with pytest.raises(ValueError):
        h.percentile(0.0)


def test_histogram_window_bounds_memory():
    h = telemetry.Histogram("h", {}, window=8)
    for v in range(100):
        h.observe(v)
    assert h.count == 100                    # all-time
    assert h.sum == sum(range(100))
    assert h.percentile(1.0) == 99           # window keeps the newest 8
    assert h.p50 == 95                       # nearest-rank over 92..99


def test_counter_thread_hammer():
    c = telemetry.Counter("c", {})
    n_threads, per_thread = 8, 5000

    def work():
        for _ in range(per_thread):
            c.inc()

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per_thread


def test_registry_get_or_create_and_labels():
    reg = telemetry.Registry()
    a = reg.counter("x_total", k="1")
    b = reg.counter("x_total", k="1")
    c = reg.counter("x_total", k="2")
    assert a is b and a is not c
    a.inc(3)
    assert reg.counter("x_total", k="1").value == 3
    # reset zeroes in place: live handles stay valid
    reg.reset()
    assert a.value == 0
    a.inc()
    assert reg.counter("x_total", k="1").value == 1


# ---------------------------------------------------------------------------
# Spans
# ---------------------------------------------------------------------------

def test_spans_disabled_are_noop():
    reg = telemetry.Registry()
    with reg.span("outer", a=1) as sp:
        sp.set_attr("b", 2)
    assert reg.spans() == []


def test_span_nesting_and_jsonl_export(tmp_path):
    reg = telemetry.Registry()
    reg.enabled = True
    with reg.span("outer", kind="test"):
        with reg.span("inner"):
            pass
        with reg.span("inner"):
            pass
    spans = reg.spans()
    assert [s.name for s in spans] == ["inner", "inner", "outer"]
    outer = spans[-1]
    assert outer.parent_id is None
    for inner in spans[:2]:
        assert inner.parent_id == outer.span_id
        assert inner.trace_id == outer.trace_id
        assert inner.duration_s >= 0
    path = tmp_path / "t.jsonl"
    n = reg.export_jsonl(path)
    assert n == 3
    lines = [json.loads(line) for line in path.read_text().splitlines()]
    assert {rec["name"] for rec in lines} == {"outer", "inner"}
    assert check_spans(lines, require=("outer", "inner")) == []


def test_span_records_error_type():
    reg = telemetry.Registry()
    reg.enabled = True
    with pytest.raises(RuntimeError):
        with reg.span("boom"):
            raise RuntimeError("x")
    (rec,) = reg.spans()
    assert rec.error == "RuntimeError"


def test_threads_root_their_own_traces():
    reg = telemetry.Registry()
    reg.enabled = True
    def worker():
        with reg.span("worker"):
            pass

    with reg.span("main-root"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    worker_rec = next(r for r in reg.spans() if r.name == "worker")
    assert worker_rec.parent_id is None      # not adopted by main's stack


# ---------------------------------------------------------------------------
# Instrumented lifecycle: compile -> store -> serve
# ---------------------------------------------------------------------------

def test_compile_trace_nests_pipeline_and_passes():
    telemetry.enable()
    netgen.Session(capacity=4).compile(_net(0), target="jnp")
    spans = {r.span_id: r for r in telemetry.get_registry().spans()}
    by_name = {}
    for r in spans.values():
        by_name.setdefault(r.name, []).append(r)
    compile_span = by_name["netgen.compile"][0]
    assert compile_span.attrs["target"] == "jnp"
    for child in ("netgen.lower", "netgen.pipeline", "netgen.backend"):
        (rec,) = by_name[child]
        assert rec.parent_id == compile_span.span_id
    pipeline_span = by_name["netgen.pipeline"][0]
    passes = by_name["netgen.pass"]
    assert len(passes) == 2                  # default pipeline: zeros,prune
    for p in passes:
        assert p.parent_id == pipeline_span.span_id
        assert p.attrs["terms_after"] <= p.attrs["terms_before"]


def test_store_and_cache_counters_route_through_registry(tmp_path):
    store = netgen.ArtifactStore(tmp_path / "store")
    s1 = netgen.Session(store=store, capacity=4)
    s1.compile(_net(1), target="jnp")
    assert store.stats.saves == 1
    s2 = netgen.Session(store=store, capacity=4)   # fresh memory tier
    s2.compile(_net(1), target="jnp")
    st = s2.stats()
    assert (st.compiles, st.store_hits) == (0, 1)
    assert store.stats.loads == 1
    assert store.stats.load_seconds > 0
    # the prometheus exposition carries the same counters
    prom = telemetry.prometheus()
    assert "netgen_store_saves_total" in prom
    assert "netgen_cache_store_hits_total" in prom
    assert check_metrics(parse_prometheus(prom)) == []


def test_compile_cache_concurrent_hammer():
    """Satellite 2: identical concurrent compiles race safely — counters
    add up exactly and only one compile happens."""
    cache = netgen.CompileCache(capacity=8)
    net = _net(2)
    n_threads, per_thread = 8, 10
    errors = []

    def work():
        try:
            for _ in range(per_thread):
                cache.get_or_compile(net)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    st = cache.stats()
    assert st.hits + st.misses == n_threads * per_thread
    assert st.compiles == 1
    assert st.misses == st.compiles + st.store_hits


def test_tuner_stats_snapshot_and_search_span():
    telemetry.enable()
    tuner = netgen.KernelTuner()
    calls = []

    def measure(params):
        calls.append(dict(params))
        return 0.001 * (1 + params["bm"])

    key_fields = {"target": "t", "device_kind": "cpu", "shape": [4, 4]}
    best = tuner.get_or_tune(key_fields, [{"bm": 0}, {"bm": 1}], measure)
    assert best == {"bm": 0}
    st = tuner.stats
    assert (st.tunes, st.measurements, st.hits) == (1, 2, 0)
    assert st.measure_seconds > 0
    best2 = tuner.get_or_tune(key_fields, [{"bm": 0}, {"bm": 1}], measure)
    assert best2 == best and tuner.stats.hits == 1
    (rec,) = [r for r in telemetry.get_registry().spans()
              if r.name == "netgen.tune.search"]
    assert rec.attrs["candidates"] == 2
    assert rec.attrs["winner"] == {"bm": 0}


# ---------------------------------------------------------------------------
# Concurrent serving invariants (satellite 3)
# ---------------------------------------------------------------------------

def _server_with(nets, **kw):
    server = netgen.NetServer(cache=netgen.CompileCache(capacity=8),
                              slot_capacity=8, warmup=False, **kw)
    for i, net in enumerate(nets):
        server.register(f"v{i}", net)
    return server


def _hammer_predict_many(server, reqs, n_threads, per_thread):
    errors = []

    def work():
        try:
            for _ in range(per_thread):
                server.predict_many(reqs)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors


def _assert_serving_invariants(server, versions, n_requests):
    reg = telemetry.get_registry()
    for v in versions:
        h = reg.histogram("netgen_predict_latency_seconds",
                          server=server._scope, version=v)
        assert h.count == n_requests, (v, h.count)
        assert h.p50 <= h.p99
    occ = reg.histogram("netgen_slot_occupancy", server=server._scope)
    assert occ.count > 0
    assert 0.0 < occ.percentile(1.0) <= 1.0
    assert 0.0 < occ.percentile(0.01) <= 1.0
    # span parentage: every netgen.kernel has a netgen.dispatch parent
    spans = {r.span_id: r for r in reg.spans()}
    kernels = [r for r in spans.values() if r.name == "netgen.kernel"]
    dispatches = [r for r in spans.values() if r.name == "netgen.dispatch"]
    assert kernels and dispatches
    for k in kernels:
        parent = spans.get(k.parent_id)
        assert parent is not None, "orphan kernel span"
        assert parent.name == "netgen.dispatch"
    assert check_spans(
        [r.as_dict() for r in spans.values()],
        require=("netgen.dispatch", "netgen.kernel")) == []


def test_concurrent_predict_many_stacked():
    telemetry.enable()
    server = _server_with([_net(3), _net(4)])
    reqs = {"v0": _x(0, 13), "v1": _x(1, 13)}
    n_threads, per_thread = 8, 5
    _hammer_predict_many(server, reqs, n_threads, per_thread)
    n = n_threads * per_thread
    assert server.dispatch_counts["stacked"] == n
    _assert_serving_invariants(server, ("v0", "v1"), n)


def test_concurrent_predict_many_fallback():
    telemetry.enable()
    # different topology -> stack-incompatible -> fallback dispatch
    deep = random_net(5, (12, 10, 6, 4), lo=-5, hi=5)
    server = _server_with([_net(3)])
    server.register("deep", deep)
    reqs = {"v0": _x(0, 13), "deep": _x(2, 13)}
    n_threads, per_thread = 8, 5
    _hammer_predict_many(server, reqs, n_threads, per_thread)
    n = n_threads * per_thread
    assert server.dispatch_counts["fallback"] == n
    assert server.dispatch_counts["stacked"] == 0
    _assert_serving_invariants(server, ("v0", "deep"), n)


# ---------------------------------------------------------------------------
# Session executor lifecycle (satellite 1)
# ---------------------------------------------------------------------------

def _compile_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("netgen-compile")]


def test_session_context_manager_joins_executor():
    with netgen.Session(capacity=4) as session:
        art = session.compile_async(_net(6), target="jnp").result()
        assert art.kind == "callable"
        assert _compile_threads()
    assert not _compile_threads()
    session.shutdown()                       # idempotent


def test_dropped_session_leaks_no_threads():
    session = netgen.Session(capacity=4)
    session.compile_async(_net(7), target="jnp").result()
    assert _compile_threads()
    del session
    gc.collect()
    deadline = time.time() + 5.0
    while _compile_threads() and time.time() < deadline:
        time.sleep(0.01)
    assert not _compile_threads(), "executor threads leaked after GC"


# ---------------------------------------------------------------------------
# Exporters + the acceptance lifecycle + the CI gate
# ---------------------------------------------------------------------------

def test_report_renders_metrics_and_spans():
    telemetry.enable()
    telemetry.counter("demo_total", kind="x").inc(2)
    with telemetry.span("demo.span"):
        telemetry.histogram("demo_seconds").observe(0.25)
    text = telemetry.report()
    assert 'demo_total{kind="x"}: 2' in text
    assert "histogram demo_seconds" in text
    assert "span      demo.span: n=1" in text


def test_prometheus_exposition_shape():
    telemetry.counter("demo_total", a="b").inc()
    telemetry.histogram("demo_seconds").observe(0.5)
    prom = telemetry.prometheus()
    assert "# TYPE demo_total counter" in prom
    assert '# TYPE demo_seconds summary' in prom
    assert 'demo_seconds{quantile="0.5"} 0.5' in prom
    assert "demo_seconds_count 1" in prom
    # label values are escaped
    telemetry.gauge("g", v='say "hi"\n').set(1)
    assert r'say \"hi\"\n' in telemetry.prometheus()


def test_acceptance_full_lifecycle(tmp_path):
    """ISSUE 6 acceptance: one compile + one predict_many round yields a
    JSONL trace nesting pipeline->passes and dispatch->kernel, a
    Prometheus exposition with compile/store-hit counters and a
    per-version latency histogram with p50/p99, and a report() with
    non-zero occupancy — and the CI gate passes on the directory."""
    telemetry.enable()
    store = netgen.ArtifactStore(tmp_path / "store")
    with netgen.Session(store=store, capacity=4) as session:
        server = netgen.NetServer(session=session, slot_capacity=8,
                                  warmup=False)
        server.register("v0", _net(8))
        server.register("v1", _net(9))
        out = server.predict_many({"v0": _x(3, 11), "v1": _x(4, 11)})
    assert set(out) == {"v0", "v1"}
    assert all(len(p) == 11 for p in out.values())

    trace_dir = tmp_path / "trace"
    trace_dir.mkdir()
    n = telemetry.export_jsonl(trace_dir / "trace.jsonl")
    assert n > 0
    (trace_dir / "metrics.prom").write_text(telemetry.prometheus())

    spans = [json.loads(line) for line in
             (trace_dir / "trace.jsonl").read_text().splitlines()]
    by_id = {s["span_id"]: s for s in spans}
    pass_spans = [s for s in spans if s["name"] == "netgen.pass"]
    assert pass_spans
    for p in pass_spans:
        assert by_id[p["parent_id"]]["name"] == "netgen.pipeline"
    kernel_spans = [s for s in spans if s["name"] == "netgen.kernel"]
    assert kernel_spans
    for k in kernel_spans:
        assert by_id[k["parent_id"]]["name"] == "netgen.dispatch"

    prom = (trace_dir / "metrics.prom").read_text()
    assert "netgen_cache_compiles_total" in prom
    assert "netgen_cache_store_hits_total" in prom
    assert 'netgen_predict_latency_seconds{quantile="0.5"' in prom \
        or 'version="v0"' in prom
    samples = parse_prometheus(prom)
    latency_quantiles = [
        (labels, v) for name, labels, v in samples
        if name == "netgen_predict_latency_seconds"
        and "quantile" in labels and labels.get("server") == server._scope]
    assert {l["quantile"] for l, _ in latency_quantiles} >= {"0.5", "0.99"}
    assert {l["version"] for l, _ in latency_quantiles} == {"v0", "v1"}

    report = telemetry.report()
    occ_line = next(line for line in report.splitlines()
                    if "netgen_slot_occupancy" in line
                    and server._scope in line)
    assert "count=0" not in occ_line
    assert "p50=0 " not in occ_line          # non-zero occupancy rendered

    assert check_trace_dir(trace_dir) == []


def test_check_trace_gate_warm_run(tmp_path):
    """A process that warm-starts every artifact from the store never
    compiles, so its trace has no compile/pipeline/pass spans — the
    gate must accept store-load + dispatch + kernel instead (this is
    exactly CI's cached-store tier-1 run)."""
    telemetry.enable()
    store = netgen.ArtifactStore(tmp_path / "store")
    net = _net(8)
    with netgen.Session(store=store) as s0:      # cold: populate store
        s0.compile(net, target="jnp")
    telemetry.reset()
    with netgen.Session(store=store, capacity=4) as session:  # warm
        server = netgen.NetServer(session=session, slot_capacity=8,
                                  warmup=False)
        server.register("v0", net)
        server.predict_many({"v0": _x(3, 11)})
    trace_dir = tmp_path / "trace"
    trace_dir.mkdir()
    telemetry.export_jsonl(trace_dir / "trace.jsonl")
    (trace_dir / "metrics.prom").write_text(telemetry.prometheus())
    names = {json.loads(line)["name"] for line in
             (trace_dir / "trace.jsonl").read_text().splitlines()}
    assert "netgen.compile" not in names          # genuinely warm
    assert "netgen.store.load" in names
    assert check_trace_dir(trace_dir) == []


def test_check_trace_gate_catches_violations(tmp_path):
    good = [
        {"trace_id": 1, "span_id": 1, "parent_id": None,
         "name": "netgen.compile", "start_unix": 1.0, "duration_s": 0.5,
         "attrs": {}, "thread": "t"},
    ]
    assert check_spans(good, require=("netgen.compile",)) == []
    # orphan parent
    bad = good + [{"trace_id": 1, "span_id": 2, "parent_id": 99,
                   "name": "netgen.pass", "start_unix": 1.0,
                   "duration_s": 0.1, "attrs": {}, "thread": "t"}]
    assert any("orphan" in e for e in check_spans(bad, require=()))
    # compile budget
    slow = [dict(good[0], duration_s=1e4)]
    assert any("over budget" in e
               for e in check_spans(slow, require=(), compile_budget_s=300))
    # duplicate ids
    assert any("duplicate" in e
               for e in check_spans(good + good, require=()))
    # counter identity breakage via metrics
    broken = parse_prometheus(
        'netgen_cache_misses_total{cache="c"} 3\n'
        'netgen_cache_compiles_total{cache="c"} 1\n'
        'netgen_cache_store_hits_total{cache="c"} 1\n')
    assert any("misses" in e for e in check_metrics(broken))
    # occupancy domain (only gated for scopes with observations)
    occ = parse_prometheus(
        'netgen_slot_occupancy{server="s",quantile="0.5"} 1.5\n'
        'netgen_slot_occupancy_count{server="s"} 4\n')
    assert any("occupancy" in e for e in check_metrics(occ))
    idle = parse_prometheus(
        'netgen_slot_occupancy{server="s",quantile="0.5"} 0.0\n'
        'netgen_slot_occupancy_count{server="s"} 0\n')
    assert check_metrics(idle) == []
    # missing files
    errors = check_trace_dir(tmp_path)
    assert any("trace.jsonl missing" in e for e in errors)
