"""Session API tests (ISSUE 3): declarative PipelineSpec parsing and
error paths, Target registry resolution, the cost target's Figure-7
estimates, the persistent ArtifactStore (including cross-process reuse
with zero recompiles), and the deprecated compile_net shim."""
import functools
import json
import os
import subprocess
import sys
import time
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.netgen.pipeline import PipelineSpec
from repro.netgen.serve import _pass_fingerprint

from _netgen_helpers import images, random_net

SRC = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")


def _random_net(seed: int, sizes=(12, 9, 4), lo=-5, hi=5):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=55)


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


# A module-level pass so the dotted-name fallback has something real to
# import: identity rewrite, stable under evaluate.
def identity_pass(circuit):
    return circuit


# ---------------------------------------------------------------------------
# PipelineSpec: round-trip, canonical form, fingerprints
# ---------------------------------------------------------------------------

def test_pipeline_spec_round_trips():
    for raw, canonical in [
        ("zeros,prune", "zeros,prune"),
        ("prune, addends ,cse", "prune,addends,cse"),
        ("cse[budget=5000,bucketed=true]", "cse[bucketed=true,budget=5000]"),
        ("cse[bucketed]", "cse[bucketed=true]"),
        ("delete_zero_terms,share_common_addends", "zeros,cse"),
    ]:
        spec = PipelineSpec.parse(raw)
        assert spec.spec_string() == canonical, raw
        # the acceptance identity: parse . spec_string is idempotent
        assert PipelineSpec.parse(spec.spec_string()).spec_string() == canonical


def test_pipeline_spec_named_and_coerce():
    assert PipelineSpec.named("default").spec_string() == "zeros,prune"
    assert PipelineSpec.named("hw").spec_string() == "zeros,prune,addends,cse"
    assert PipelineSpec.coerce(None).spec_string() == "zeros,prune"
    assert PipelineSpec.coerce("hw").spec_string() == \
        PipelineSpec.named("hw").spec_string()
    spec = PipelineSpec.parse("zeros")
    assert PipelineSpec.coerce(spec) is spec
    assert PipelineSpec.coerce(
        (netgen.delete_zero_terms, netgen.prune_dead_units)
    ).spec_string() == "zeros,prune"
    assert "default" in netgen.list_pipelines()
    with pytest.raises(ValueError, match="unknown pipeline"):
        PipelineSpec.named("nope")


def test_pipeline_spec_fingerprint_distinguishes():
    base = PipelineSpec.parse("zeros,cse").fingerprint()
    assert PipelineSpec.parse("zeros,cse").fingerprint() == base
    assert PipelineSpec.parse("zeros,cse[budget=5]").fingerprint() != base
    assert PipelineSpec.parse("cse,zeros").fingerprint() != base  # order
    assert PipelineSpec.parse(
        "zeros,cse[bucketed=true]").fingerprint() != base


def test_pipeline_spec_fingerprint_stable_across_processes():
    """Same spec -> same fingerprint in a fresh interpreter: the property
    that makes PipelineSpec one axis of the ArtifactStore key."""
    spec = "zeros,prune,cse[budget=7,bucketed=true]"
    want = PipelineSpec.parse(spec).fingerprint()
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.netgen.pipeline import PipelineSpec;"
         f"print(PipelineSpec.parse({spec!r}).fingerprint())"],
        capture_output=True, text=True, check=True,
        env={**os.environ, "PYTHONPATH": SRC})
    assert out.stdout.strip() == want


def test_pipeline_spec_runs_with_labeled_stats():
    net = _random_net(0)
    circuit, stats = PipelineSpec.parse("zeros,cse[budget=3]").run(
        netgen.lower(net))
    assert [s.name for s in stats] == ["zeros", "cse[budget=3]"]
    x = _images(0, 16, 12)
    np.testing.assert_array_equal(netgen.evaluate(circuit, x), _ref(net, x))


def test_pipeline_spec_dotted_passes_round_trip():
    spec = PipelineSpec.from_passes([identity_pass])
    dotted = spec.spec_string()
    assert dotted.endswith(".identity_pass")
    assert PipelineSpec.parse(dotted).spec_string() == dotted
    net = _random_net(1)
    circuit, stats = spec.run(netgen.lower(net))
    assert stats[0].terms_deleted == 0


# ---------------------------------------------------------------------------
# PipelineSpec: error paths (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_pipeline_spec_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown pass"):
        PipelineSpec.parse("zeros,retime")
    with pytest.raises(ValueError, match="not importable"):
        PipelineSpec.parse("no.such.module.pass_fn")


@pytest.mark.parametrize("bad", [
    "cse[budget=5", "cse[bud[get=5]", "cse[]", "cse[=5]", "cse[,]",
    "cse]budget=5[", "zeros,", ",zeros", "", "   ", "cse[budget=1,budget=2]",
])
def test_pipeline_spec_rejects_malformed_specs(bad):
    with pytest.raises(ValueError):
        PipelineSpec.parse(bad)


def test_pipeline_spec_rejects_bad_options():
    with pytest.raises(ValueError, match="unknown option"):
        PipelineSpec.parse("cse[depth=3]")
    with pytest.raises(ValueError, match="unknown option"):
        PipelineSpec.parse("prune[budget=3]")   # prune declares no options
    with pytest.raises(ValueError, match="integer"):
        PipelineSpec.parse("cse[budget=fast]")
    with pytest.raises(ValueError, match="integer"):
        PipelineSpec.parse("cse[budget=true]")
    with pytest.raises(ValueError, match="true/false"):
        PipelineSpec.parse("cse[bucketed=7]")


def test_pipeline_spec_rejects_duplicate_passes():
    with pytest.raises(ValueError, match="duplicate pass"):
        PipelineSpec.parse("zeros,prune,zeros")
    with pytest.raises(ValueError, match="duplicate"):
        PipelineSpec.from_passes(
            [netgen.delete_zero_terms, netgen.delete_zero_terms])


def test_pipeline_spec_refuses_lambdas_and_closures():
    with pytest.raises(ValueError, match="lambda"):
        PipelineSpec.from_passes([lambda c: c])

    def closure(c):
        return c

    with pytest.raises(ValueError, match="functools.partial"):
        PipelineSpec.from_passes([closure])


def test_pass_fingerprint_compat():
    """The serve-layer helper now canonicalizes through PipelineSpec."""
    budget = functools.partial(netgen.share_common_addends, max_new_nodes=2)
    assert _pass_fingerprint(budget) == "cse[budget=2]"
    assert _pass_fingerprint(netgen.share_common_addends) == "cse"
    assert _pass_fingerprint(budget) != _pass_fingerprint(
        netgen.share_common_addends)


# ---------------------------------------------------------------------------
# Target registry
# ---------------------------------------------------------------------------

def test_list_targets_enumerates_registry():
    targets = {t.name: t for t in netgen.list_targets()}
    assert set(targets) >= {"jnp", "pallas", "fused", "verilog", "cost"}
    assert all(t.description for t in targets.values())
    assert targets["jnp"].callable and targets["pallas"].callable
    assert targets["verilog"].kind == "text"
    assert targets["cost"].kind == "report"
    assert targets["jnp"].compile_multi is not None
    assert targets["fused"].compile_multi is None


def test_resolve_target_options():
    tgt, opts = netgen.resolve_target("verilog[style=legacy]")
    assert tgt.name == "verilog" and opts == {"style": "legacy"}
    tgt, opts = netgen.resolve_target("pallas[interpret]")
    assert opts == {"interpret": True}
    with pytest.raises(ValueError, match="unknown target"):
        netgen.resolve_target("llvm")
    with pytest.raises(ValueError, match="unknown option"):
        netgen.resolve_target("jnp[style=fast]")
    with pytest.raises(ValueError, match="true/false"):
        netgen.resolve_target("pallas[interpret=3]")
    with pytest.raises(ValueError, match="twice"):
        netgen.resolve_target("verilog[style=legacy]", {"style": "generic"})


def test_string_options_must_round_trip():
    """String option values are embedded in canonical target strings
    (which key the store and must re-parse on warm load), so syntax
    characters and bool/int literals are rejected at resolve time."""
    for bad in ("my,mod", "a]b", "a=b", "true", "42", "two words"):
        with pytest.raises(ValueError):
            netgen.resolve_target("verilog", {"module_name": bad})
    tgt, opts = netgen.resolve_target("verilog", {"module_name": "my_mod.v2"})
    assert opts == {"module_name": "my_mod.v2"}


@pytest.mark.parametrize(
    "target", ["pallas[interpret=true]", "pallas[interpret=true,packed=true]"])
def test_stacked_dispatch_honors_target_opts(target):
    """predict_many's multi-net build must receive the same declared
    options as the single-version path (interpret and packed for
    pallas), routed through the registry's validation."""
    server = netgen.NetServer(
        target=target, slot_capacity=8, warmup=False)
    nets = {name: _random_net(35 + i, sizes=(10, 8, 4))
            for i, name in enumerate("ab")}
    for name, net in nets.items():
        server.register(name, net)
    x = _images(35, 6, 10)
    out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["stacked"] == 1
    for name, net in nets.items():
        np.testing.assert_array_equal(out[name], _ref(net, x), err_msg=name)


def test_target_strings_reach_backends():
    net = _random_net(2)
    x = _images(2, 8, 12)
    ref = _ref(net, x)
    art = netgen.compile_artifact(net, target="pallas[interpret=true]")
    np.testing.assert_array_equal(np.asarray(art(x)), ref)
    v = netgen.compile_artifact(net, target="verilog[module_name=custom]")
    assert "module custom" in v.artifact


def test_cost_target_full_784_500_10_per_pass():
    """ISSUE acceptance: the cost target prices the paper-sized net and
    attributes cells per pass — the zero-deletion (L4) and addend (L5)
    savings must be visible in the trajectory, reported alongside the
    paper's Figure-7 reference counts."""
    rng = np.random.default_rng(3)
    w1 = rng.integers(-9, 10, size=(784, 500)).astype(np.int32)
    w2 = rng.integers(-9, 10, size=(500, 10)).astype(np.int32)
    w1[rng.random(w1.shape) < 0.5] = 0          # paper-like ~50% zeros
    net = quantize.QuantizedNet(weights=[w1, w2])

    art = netgen.compile_artifact(net, target="cost",
                                  pipeline="zeros,prune,addends")
    report = art.artifact
    stages = dict(report.per_pass)
    assert set(stages) == {"lowered", "zeros", "prune", "addends"}
    # L4: deleting zero terms frees their adder slots
    assert stages["zeros"].total < stages["lowered"].total
    # L5: the addend rewrite eliminates every multiplier cell
    assert stages["addends"].mult_cells == 0
    assert stages["addends"].total < stages["zeros"].total
    assert report.final == stages["addends"]
    assert dict(report.paper_fig7) == {
        "naive": 80000, "pruned": 38000, "addend": 16000}
    assert "paper fig7" in report.report()
    # report artifacts are not callable predictors
    with pytest.raises(TypeError, match="not callable"):
        art(_images(3, 4, 784))


# ---------------------------------------------------------------------------
# Frontend threshold validation (ISSUE satellite)
# ---------------------------------------------------------------------------

def test_lower_validates_input_threshold():
    net = [np.ones((4, 3), np.int32), np.ones((3, 2), np.int32)]
    for ok in (0, 128, 254, np.int64(17)):
        assert netgen.lower(net, input_threshold=ok).input_threshold == int(ok)
    for unreachable in (255, 300, -1, -128):
        with pytest.raises(ValueError, match="uint8"):
            netgen.lower(net, input_threshold=unreachable)
    for bad_type in (128.0, "128", True):
        with pytest.raises(TypeError, match="integer"):
            netgen.lower(net, input_threshold=bad_type)
    with pytest.raises(ValueError, match="uint8"):
        netgen.compile_artifact(
            quantize.QuantizedNet(weights=net, input_threshold=999))


# ---------------------------------------------------------------------------
# Circuit array codec (the store's on-disk circuit form)
# ---------------------------------------------------------------------------

def test_circuit_codec_round_trips_irregular_dag():
    net = _random_net(4)
    circuit, _ = PipelineSpec.parse("zeros,addends,cse").run(netgen.lower(net))
    back = netgen.circuit_from_arrays(netgen.circuit_to_arrays(circuit))
    assert back == circuit
    x = _images(4, 16, 12)
    np.testing.assert_array_equal(
        netgen.evaluate(back, x), netgen.evaluate(circuit, x))


# ---------------------------------------------------------------------------
# Session + ArtifactStore
# ---------------------------------------------------------------------------

def test_session_compile_artifact_fields(tmp_path):
    session = netgen.Session(store=netgen.ArtifactStore(tmp_path / "s"))
    net = _random_net(5)
    art = session.compile(net, target="jnp", pipeline="default")
    assert art.source == "compile"
    assert art.kind == "callable" and art.backend == "jnp"
    assert art.pipeline == "zeros,prune"
    assert art.digest == net.digest()
    assert art.timings["total_s"] > 0
    assert art.cost.total > 0
    assert "cells" in art.report()
    x = _images(5, 8, 12)
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    # memory tier: same object back
    assert session.compile(net, target="jnp", pipeline="default") is art
    assert session.stats().hits == 1


def test_session_key_crosses_digest_pipeline_target(tmp_path):
    session = netgen.Session(store=netgen.ArtifactStore(tmp_path / "s"))
    net = _random_net(6)
    keys = {
        session.compile(net, target="jnp").key,
        session.compile(net, target="pallas").key,
        session.compile(net, target="jnp", pipeline="zeros").key,
        session.compile(_random_net(7), target="jnp").key,
    }
    assert len(keys) == 4
    assert session.stats().compiles == 4
    assert sorted(session.store.keys()) == sorted(keys)


def test_artifact_store_warm_second_session(tmp_path):
    """A second Session over the same directory rebuilds predictors from
    the store: zero full compiles, bit-exact predictions."""
    store_dir = tmp_path / "store"
    net = _random_net(8)
    x = _images(8, 12, 12)
    first = netgen.Session(store=netgen.ArtifactStore(store_dir))
    cold = first.compile(net, target="jnp")
    assert first.stats().compiles == 1

    warm_session = netgen.Session(store=netgen.ArtifactStore(store_dir))
    warm = warm_session.compile(net, target="jnp")
    st = warm_session.stats()
    assert (st.compiles, st.store_hits) == (0, 1)
    assert warm.source == "store"
    assert warm.key == cold.key
    assert "load_s" in warm.timings
    assert [s.row() for s in warm.pass_stats] == \
        [s.row() for s in cold.pass_stats]
    assert warm.cost == cold.cost
    np.testing.assert_array_equal(np.asarray(warm(x)), np.asarray(cold(x)))


def test_artifact_store_text_and_report_round_trip(tmp_path):
    store_dir = tmp_path / "store"
    net = _random_net(9)
    a = netgen.Session(store=store_dir)
    b = netgen.Session(store=store_dir)
    v_cold = a.compile(net, target="verilog", pipeline="hw")
    v_warm = b.compile(net, target="verilog", pipeline="hw")
    assert v_warm.source == "store" and v_warm.artifact == v_cold.artifact
    c_cold = a.compile(net, target="cost", pipeline="hw")
    c_warm = b.compile(net, target="cost", pipeline="hw")
    assert c_warm.artifact.as_dict() == c_cold.artifact.as_dict()
    assert b.stats().compiles == 0


def test_artifact_store_cross_process_reuse(tmp_path):
    """ISSUE acceptance: compile in a SUBPROCESS, then load warm in this
    process — bit-exact outputs and zero compiles, via the store and
    session counters."""
    store_dir = tmp_path / "store"
    net = _random_net(10)
    x = _images(10, 16, 12)
    script = f"""
import json, sys
import numpy as np
sys.path.insert(0, {os.path.dirname(os.path.abspath(__file__))!r})
from _netgen_helpers import random_net, images
from repro import netgen

net = random_net(10, (12, 9, 4), lo=-5, hi=5)
x = images(10, 16, 12, salt=55)
session = netgen.Session(store={str(store_dir)!r})
art = session.compile(net, target="jnp")
print(json.dumps({{
    "key": art.key,
    "compiles": session.stats().compiles,
    "preds": np.asarray(art(x)).tolist(),
}}))
"""
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        check=True, env={**os.environ, "PYTHONPATH": SRC})
    child = json.loads(out.stdout.strip().splitlines()[-1])
    assert child["compiles"] == 1

    session = netgen.Session(store=netgen.ArtifactStore(store_dir))
    art = session.compile(net, target="jnp")
    st = session.stats()
    assert (st.compiles, st.store_hits) == (0, 1)   # zero compiles warm
    assert st.compile_seconds == 0.0                # zero compile time
    assert art.key == child["key"]
    np.testing.assert_array_equal(
        np.asarray(art(x)), np.asarray(child["preds"], dtype=np.int64))


def test_artifact_store_layout_and_idempotent_put(tmp_path):
    store = netgen.ArtifactStore(tmp_path / "store")
    art = netgen.compile_artifact(_random_net(11), target="verilog")
    store.put(art)
    assert art.key in store and len(store) == 1
    store.put(art)                                   # second put: no-op
    assert store.stats.saves == 1
    meta = json.loads(
        (tmp_path / "store" / art.key / "meta.json").read_text())
    assert meta["target"] == "verilog" and meta["pipeline"] == "zeros,prune"
    assert store.get("0" * 64) is None
    assert store.stats.misses == 1


def test_artifact_store_recovers_from_corrupt_entry(tmp_path):
    """Bit-rot must degrade to a recompile, not a hard failure: a
    readable meta.json with an unreadable payload is evicted and
    re-missed, and the subsequent compile re-creates the entry."""
    store_dir = tmp_path / "store"
    net = _random_net(13)
    x = _images(13, 8, 12)
    first = netgen.Session(store=store_dir)
    cold = first.compile(net, target="jnp")
    (store_dir / cold.key / "circuit.npz").write_bytes(b"not a zipfile")

    session = netgen.Session(store=netgen.ArtifactStore(store_dir))
    art = session.compile(net, target="jnp")
    st = session.stats()
    assert (st.compiles, st.store_hits) == (1, 0)
    assert session.store.stats.corrupt == 1
    np.testing.assert_array_equal(np.asarray(art(x)), _ref(net, x))
    # the recompile re-persisted a healthy entry
    warm = netgen.Session(store=store_dir).compile(net, target="jnp")
    assert warm.source == "store"


def test_artifact_store_gc_count_bound(tmp_path):
    """ISSUE 4 satellite: size/count bounds with LRU-by-mtime eviction.
    put() runs gc automatically; get() refreshes recency, so a reused
    entry survives a never-reused older one."""
    store = netgen.ArtifactStore(tmp_path / "store", max_entries=2)
    arts = [netgen.compile_artifact(_random_net(60 + i), target="verilog")
            for i in range(3)]
    now = time.time()
    for i, art in enumerate(arts[:2]):
        store.put(art)
        # decouple LRU order from filesystem mtime granularity
        os.utime(tmp_path / "store" / art.key / "meta.json",
                 (now - 100 + i, now - 100 + i))
    assert store.get(arts[0].key) is not None      # touch: 0 newer than 1
    store.put(arts[2])                             # bound hit: evicts 1
    assert store.stats.gc_evictions == 1
    assert sorted(store.keys()) == sorted([arts[0].key, arts[2].key])
    assert store.get(arts[1].key) is None
    # an unbounded store never gc-evicts
    free = netgen.ArtifactStore(tmp_path / "free")
    for art in arts:
        free.put(art)
    assert free.gc() == [] and len(free) == 3


def test_artifact_store_gc_byte_bound(tmp_path):
    store = netgen.ArtifactStore(tmp_path / "store", max_bytes=1)
    art = netgen.compile_artifact(_random_net(63), target="verilog")
    store.put(art)                 # every entry exceeds 1 byte...
    evicted_more = store.gc()      # ...and an explicit gc() stays stable
    assert len(store) == 0 and evicted_more == []
    assert store.stats.gc_evictions == 1
    with pytest.raises(ValueError, match="max_entries"):
        netgen.ArtifactStore(tmp_path / "bad", max_entries=0)
    with pytest.raises(ValueError, match="max_bytes"):
        netgen.ArtifactStore(tmp_path / "bad2", max_bytes=0)


def test_compile_cache_over_store(tmp_path):
    """serve.CompileCache is the in-memory tier over the store: a fresh
    cache on the same directory loads instead of compiling."""
    store = netgen.ArtifactStore(tmp_path / "store")
    net = _random_net(12)
    cache = netgen.CompileCache(capacity=4, store=store)
    first = cache.get_or_compile(net)
    assert cache.get_or_compile(net) is first
    st = cache.stats()
    assert (st.hits, st.misses, st.compiles, st.store_hits) == (1, 1, 1, 0)

    cache2 = netgen.CompileCache(capacity=4, store=store)
    warm = cache2.get_or_compile(net)
    st2 = cache2.stats()
    assert (st2.compiles, st2.store_hits) == (0, 1)
    assert st2.load_seconds > 0
    x = _images(12, 8, 12)
    np.testing.assert_array_equal(np.asarray(warm(x)), np.asarray(first(x)))


def test_netserver_over_session(tmp_path):
    """NetServer(session=...) serves through the session's store: a
    second server in a fresh session warm-starts every version."""
    store_dir = tmp_path / "store"
    nets = {f"v{i}": _random_net(20 + i) for i in range(2)}
    s1 = netgen.Session(store=store_dir)
    server = netgen.NetServer(session=s1, slot_capacity=8)
    for name, net in nets.items():
        server.register(name, net)
    assert s1.stats().compiles == 2
    x = _images(20, 8, 12)
    out = server.predict_many({"v0": x, "v1": x})
    for name, net in nets.items():
        np.testing.assert_array_equal(out[name], _ref(net, x))

    s2 = netgen.Session(store=store_dir)
    server2 = netgen.NetServer(session=s2, slot_capacity=8)
    for name, net in nets.items():
        server2.register(name, net)
    st = s2.stats()
    assert (st.compiles, st.store_hits) == (0, 2)
    out2 = server2.predict_many({"v0": x, "v1": x})
    for name in nets:
        np.testing.assert_array_equal(out2[name], out[name])
    with pytest.raises(ValueError, match="not both"):
        netgen.NetServer(session=s2, cache=netgen.CompileCache())


def test_netserver_accepts_target_strings():
    server = netgen.NetServer(
        target="pallas[interpret=true]", pipeline="default",
        slot_capacity=8, warmup=False)
    net = _random_net(30, sizes=(10, 8, 4))
    server.register("v", net)
    x = _images(30, 6, 10)
    np.testing.assert_array_equal(server.predict("v", x), _ref(net, x))
    with pytest.raises(ValueError, match="callable"):
        netgen.NetServer(target="cost")


# ---------------------------------------------------------------------------
# Deprecated shim
# ---------------------------------------------------------------------------

def test_compile_net_still_accepts_unrepresentable_pipelines():
    """PR1-era calls with closure or repeated passes keep compiling (the
    acceptance promise) — directly and uncached, since such pipelines
    have no stable fingerprint for the store."""
    net = _random_net(41)
    x = _images(41, 8, 12)

    def budgeted(c):
        return netgen.share_common_addends(c, max_new_nodes=2)

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        via_closure = netgen.compile_net(
            net, backend="verilog",
            passes=(netgen.delete_zero_terms, budgeted))
        repeated = netgen.compile_net(
            net, passes=(netgen.delete_zero_terms, netgen.prune_dead_units,
                         netgen.delete_zero_terms))
    assert "endmodule" in via_closure.artifact
    assert [s.name for s in via_closure.pass_stats][-1] == "budgeted"
    np.testing.assert_array_equal(np.asarray(repeated(x)), _ref(net, x))


def test_artifact_key_includes_compiler_sources(tmp_path):
    """The store key folds in a fingerprint of the netgen sources, so a
    compiler edit can never warm-start stale circuits."""
    from repro.netgen import session as session_mod
    net = _random_net(42)
    spec = PipelineSpec.named("default")
    k1 = session_mod.artifact_key(net.digest(), spec, "jnp")
    old = session_mod._SOURCE_FINGERPRINT
    try:
        session_mod._SOURCE_FINGERPRINT = "deadbeef"  # simulate code change
        k2 = session_mod.artifact_key(net.digest(), spec, "jnp")
    finally:
        session_mod._SOURCE_FINGERPRINT = old
    assert k1 != k2


def test_compile_net_deprecated_but_equivalent():
    net = _random_net(40)
    x = _images(40, 8, 12)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        compiled = netgen.compile_net(net)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert isinstance(compiled, netgen.CompiledNet)
    art = netgen.default_session().compile(net, target="jnp")
    np.testing.assert_array_equal(np.asarray(compiled(x)), np.asarray(art(x)))
    np.testing.assert_array_equal(np.asarray(compiled(x)), _ref(net, x))
