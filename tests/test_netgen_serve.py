"""Compile-cache serving tests: content-addressed hits/misses, LRU
eviction, thread safety, input validation, the NetServer's stacked
multi-net dispatch (ISSUE 2 acceptance: 4 versions in one jitted call,
bit-exact vs serving each CompiledNet individually), and the
mesh-sharded stacked dispatch (ISSUE 4: shard_map over the slot
dimension when a mesh with a data axis is active, single-device
fallback otherwise)."""
import os
import subprocess
import sys
import threading

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import quantize
from repro import netgen
from repro.netgen.serve import _pass_fingerprint
from repro.serve.engine import pad_slots

from _netgen_helpers import images, random_net


def _random_net(seed: int, sizes=(12, 9, 4), lo=-5, hi=5):
    return random_net(seed, sizes, lo=lo, hi=hi)


def _images(seed: int, b: int, n_in: int) -> np.ndarray:
    return images(seed, b, n_in, salt=77)


def _ref(net, x):
    return np.asarray(quantize.predict_quantized(net)(jnp.asarray(x)))


# ---------------------------------------------------------------------------
# Digest
# ---------------------------------------------------------------------------

def test_digest_content_addressed():
    net = _random_net(0)
    clone = quantize.QuantizedNet(weights=[w.copy() for w in net.weights])
    assert net.digest() == clone.digest()
    # dtype of the container must not matter, only the integer content
    as_i8 = quantize.QuantizedNet(
        weights=[w.astype(np.int8) for w in net.weights])
    assert as_i8.digest() == net.digest()
    # any perturbation must change it
    w = [w.copy() for w in net.weights]
    w[0][0, 0] += 1
    assert quantize.QuantizedNet(weights=w).digest() != net.digest()
    other_thr = quantize.QuantizedNet(
        weights=list(net.weights), input_threshold=64)
    assert other_thr.digest() != net.digest()


def test_digest_rejects_float_weights():
    with pytest.raises(TypeError):
        quantize.weights_digest([np.ones((2, 2), np.float32)])


# ---------------------------------------------------------------------------
# Cache hit/miss semantics
# ---------------------------------------------------------------------------

def test_cache_hit_returns_same_object():
    cache = netgen.CompileCache()
    net = _random_net(1)
    clone = quantize.QuantizedNet(weights=[w.copy() for w in net.weights])
    first = cache.get_or_compile(net)
    again = cache.get_or_compile(clone)      # equal content, new containers
    assert again is first
    st = cache.stats()
    assert (st.hits, st.misses) == (1, 1)
    assert st.compile_seconds > 0
    key = cache.key_for(net)
    assert key in cache and cache.compile_seconds(key) > 0


def test_cache_misses_on_weights_passes_backend():
    cache = netgen.CompileCache()
    net = _random_net(2)
    base = cache.get_or_compile(net)

    perturbed = [w.copy() for w in net.weights]
    perturbed[1][0, 0] -= 1
    assert cache.get_or_compile(
        quantize.QuantizedNet(weights=perturbed)) is not base
    assert cache.get_or_compile(
        net, passes=(netgen.delete_zero_terms,)) is not base
    assert cache.get_or_compile(net, backend="pallas") is not base
    st = cache.stats()
    assert (st.hits, st.misses) == (0, 4)


def test_cache_key_distinguishes_backend_opts_and_partial_passes():
    import functools
    cache = netgen.CompileCache()
    net = _random_net(3)
    k_plain = cache.key_for(net, backend="verilog")
    k_named = cache.key_for(net, backend="verilog", module_name="other")
    assert k_plain != k_named
    budget = functools.partial(netgen.share_common_addends, max_new_nodes=2)
    assert _pass_fingerprint(budget) != _pass_fingerprint(
        netgen.share_common_addends)
    assert cache.key_for(net, passes=(budget,)) != cache.key_for(
        net, passes=(netgen.share_common_addends,))


def test_cache_refuses_unfingerprintable_passes():
    """A lambda/closure pass has no stable fingerprint — two different
    ones would alias to one key and serve each other's artifacts."""
    cache = netgen.CompileCache()
    net = _random_net(8)
    with pytest.raises(ValueError, match="lambda"):
        cache.key_for(net, passes=(lambda c: c,))

    def make(budget):
        def p(c):
            return netgen.share_common_addends(c, max_new_nodes=budget)
        return p

    with pytest.raises(ValueError, match="functools.partial"):
        cache.key_for(net, passes=(make(1),))


def test_cache_eviction_bound():
    cache = netgen.CompileCache(capacity=2)
    nets = [_random_net(10 + i) for i in range(3)]
    first = cache.get_or_compile(nets[0])
    cache.get_or_compile(nets[1])
    cache.get_or_compile(nets[2])            # evicts nets[0] (LRU)
    assert len(cache) == 2
    assert cache.stats().evictions == 1
    assert cache.key_for(nets[0]) not in cache
    assert cache.get_or_compile(nets[0]) is not first   # recompiled
    assert cache.stats().misses == 4
    with pytest.raises(ValueError):
        netgen.CompileCache(capacity=0)


def test_cache_lru_recency():
    cache = netgen.CompileCache(capacity=2)
    a, b, c = (_random_net(20 + i) for i in range(3))
    ca = cache.get_or_compile(a)
    cache.get_or_compile(b)
    cache.get_or_compile(a)                  # touch a: b is now LRU
    cache.get_or_compile(c)                  # evicts b, keeps a
    assert cache.get_or_compile(a) is ca
    assert cache.stats().evictions == 1


def test_cache_thread_safety_smoke():
    cache = netgen.CompileCache()
    net = _random_net(4)
    results = [None] * 8
    barrier = threading.Barrier(len(results))

    def worker(i):
        barrier.wait()
        results[i] = cache.get_or_compile(net)

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(len(results))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(r is results[0] for r in results)
    st = cache.stats()
    assert st.misses == 1 and st.hits == len(results) - 1


def test_cached_compile_net_uses_default_cache():
    net = _random_net(5, sizes=(7, 5, 3))
    a = netgen.cached_compile_net(net)
    b = netgen.cached_compile_net(net)
    assert a is b


# ---------------------------------------------------------------------------
# CompiledNet input validation
# ---------------------------------------------------------------------------

def test_compiled_net_rejects_bad_input():
    net = _random_net(6)
    compiled = netgen.compile_net(net)
    x = _images(6, 8, 12)
    ok = np.asarray(compiled(x))
    assert ok.shape == (8,)
    np.testing.assert_array_equal(np.asarray(compiled(jnp.asarray(x))), ok)
    with pytest.raises(TypeError, match="uint8"):
        compiled(x.astype(np.float32))
    with pytest.raises(TypeError, match="uint8"):
        compiled(x.astype(np.int32))
    with pytest.raises(ValueError, match=r"\(batch, 12\)"):
        compiled(x[:, :5])                   # wrong trailing dim
    with pytest.raises(ValueError, match=r"\(batch, 12\)"):
        compiled(x[0])                       # 1-D
    with pytest.raises(TypeError):
        compiled(x.tolist())                 # no dtype at all


def test_verilog_artifact_not_callable():
    compiled = netgen.compile_net(_random_net(7), backend="verilog")
    with pytest.raises(TypeError, match="not callable"):
        compiled(_images(7, 4, 12))


# ---------------------------------------------------------------------------
# NetServer: routing, slot batching, stacked dispatch
# ---------------------------------------------------------------------------

def test_netserver_routes_per_version():
    server = netgen.NetServer(slot_capacity=16)
    nets = {f"v{i}": _random_net(30 + i) for i in range(2)}
    for name, net in nets.items():
        server.register(name, net)
    assert server.versions() == ["v0", "v1"]
    x = _images(30, 10, 12)
    for name, net in nets.items():
        np.testing.assert_array_equal(server.predict(name, x), _ref(net, x))
    assert server.dispatch_counts["single"] == 2
    with pytest.raises(KeyError):
        server.predict("nope", x)


def test_netserver_slot_chunking():
    """Batches beyond slot capacity are served in fixed-shape chunks."""
    server = netgen.NetServer(slot_capacity=8)
    net = _random_net(31)
    server.register("v", net)
    x = _images(31, 21, 12)                  # 3 chunks: 8 + 8 + 5
    np.testing.assert_array_equal(server.predict("v", x), _ref(net, x))
    assert server.predict("v", x[:0]).shape == (0,)


def test_netserver_stacked_dispatch_4_versions_bit_exact():
    """ISSUE acceptance: 4 model versions through ONE jitted multi-net
    call, per-version outputs bit-exact vs each CompiledNet individually."""
    cache = netgen.CompileCache()
    server = netgen.NetServer(cache=cache, slot_capacity=16)
    nets = {f"v{i}": _random_net(40 + i) for i in range(4)}
    for name, net in nets.items():
        server.register(name, net)
    reqs = {name: _images(40 + i, 12, 12) for i, name in enumerate(nets)}
    out = server.predict_many(reqs)
    assert server.dispatch_counts["stacked"] == 1
    assert server.dispatch_counts["fallback"] == 0
    for name, net in nets.items():
        individual = np.asarray(server.compiled_for(name)(
            pad_slots(reqs[name], 16)[0]))[:reqs[name].shape[0]]
        np.testing.assert_array_equal(out[name], individual, err_msg=name)
        np.testing.assert_array_equal(out[name], _ref(net, reqs[name]))


def test_netserver_stacked_pads_pruned_hidden_widths():
    """Versions whose pruning left different hidden widths still stack:
    the padded columns are constant-0 units (exact under strict step)."""
    a = _random_net(50)
    wz = [w.copy() for w in _random_net(51).weights]
    wz[0][:, :4] = 0                         # 4 dead hidden units
    b = quantize.QuantizedNet(weights=wz)
    ca = netgen.compile_net(a)
    cb = netgen.compile_net(b)
    assert (netgen.as_layered_weights(ca.circuit)[0].shape[1]
            != netgen.as_layered_weights(cb.circuit)[0].shape[1])
    server = netgen.NetServer(slot_capacity=8)
    server.register("a", a)
    server.register("b", b)
    x = _images(50, 8, 12)
    out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["stacked"] == 1
    np.testing.assert_array_equal(out["a"], _ref(a, x))
    np.testing.assert_array_equal(out["b"], _ref(b, x))


def test_netserver_stacked_chunks_unequal_batches():
    server = netgen.NetServer(slot_capacity=8)
    nets = {name: _random_net(60 + i) for i, name in enumerate("ab")}
    for name, net in nets.items():
        server.register(name, net)
    reqs = {"a": _images(60, 19, 12), "b": _images(61, 3, 12)}
    out = server.predict_many(reqs)
    for name, net in nets.items():
        np.testing.assert_array_equal(out[name], _ref(net, reqs[name]))


def test_netserver_pallas_stacked_dispatch():
    server = netgen.NetServer(
        backend="pallas", slot_capacity=8, warmup=False)
    nets = {name: _random_net(70 + i, sizes=(10, 8, 4))
            for i, name in enumerate("ab")}
    for name, net in nets.items():
        server.register(name, net)
    x = _images(70, 6, 10)
    out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["stacked"] == 1
    for name, net in nets.items():
        np.testing.assert_array_equal(out[name], _ref(net, x), err_msg=name)


def test_netserver_reregister_invalidates_stacked_dispatch():
    """Re-registering a version must drop the stacked dispatch built for
    the old weights — serving stale predictions silently is the failure
    the generation counter guards against."""
    server = netgen.NetServer(slot_capacity=8, warmup=False)
    old = _random_net(100)
    other = _random_net(101)
    server.register("a", old)
    server.register("b", other)
    x = _images(100, 8, 12)
    server.predict_many({"a": x, "b": x})            # builds the stacked fn
    new = _random_net(102)
    server.register("a", new)                        # same name, new weights
    out = server.predict_many({"a": x, "b": x})
    np.testing.assert_array_equal(out["a"], _ref(new, x))
    np.testing.assert_array_equal(out["b"], _ref(other, x))
    assert server.dispatch_counts["stacked"] == 2


def test_netserver_fallback_on_incompatible_topologies():
    server = netgen.NetServer(slot_capacity=8)
    shallow = _random_net(80)                          # 12-9-4
    deep = _random_net(81, sizes=(12, 8, 8, 4))        # different depth
    server.register("s", shallow)
    server.register("d", deep)
    x = _images(80, 8, 12)
    out = server.predict_many({"s": x, "d": x})
    assert server.dispatch_counts["fallback"] == 1
    assert server.dispatch_counts["stacked"] == 0
    np.testing.assert_array_equal(out["s"], _ref(shallow, x))
    np.testing.assert_array_equal(out["d"], _ref(deep, x))


def test_netserver_shares_cache_across_servers():
    """A second server over the same cache acquires predictors warm."""
    cache = netgen.CompileCache()
    net = _random_net(90)
    netgen.NetServer(cache=cache, slot_capacity=8).register("v", net)
    assert cache.stats().misses == 1
    netgen.NetServer(cache=cache, slot_capacity=8).register("v", net)
    st = cache.stats()
    assert (st.misses, st.hits) == (1, 1)


def test_netserver_rejects_bad_config():
    with pytest.raises(ValueError):
        netgen.NetServer(backend="verilog")
    with pytest.raises(ValueError):
        netgen.NetServer(slot_capacity=0)


def test_stack_layered_weights_incompatibility_errors():
    c = lambda seed, sizes: netgen.compile_net(  # noqa: E731
        _random_net(seed, sizes=sizes)).circuit
    with pytest.raises(ValueError, match="depth"):
        netgen.stack_layered_weights([c(0, (8, 6, 4)), c(1, (8, 6, 6, 4))])
    with pytest.raises(ValueError, match="input width"):
        netgen.stack_layered_weights([c(0, (8, 6, 4)), c(1, (9, 6, 4))])
    with pytest.raises(ValueError, match="class count"):
        netgen.stack_layered_weights([c(0, (8, 6, 4)), c(1, (8, 6, 5))])
    with pytest.raises(ValueError, match="no circuits"):
        netgen.stack_layered_weights([])


# ---------------------------------------------------------------------------
# Mesh-sharded stacked dispatch (ISSUE 4)
# ---------------------------------------------------------------------------

def test_netserver_sharded_stacked_under_mesh():
    """With a mesh carrying a data axis active, the stacked dispatch
    runs under shard_map (slot dimension split across the axis) and
    stays bit-exact; leaving the mesh context falls back to the
    single-device build."""
    import math

    import jax
    from repro.launch.mesh import make_host_mesh
    from repro.parallel import sharding as shd

    server = netgen.NetServer(slot_capacity=8, warmup=False)
    nets = {name: _random_net(110 + i) for i, name in enumerate("ab")}
    for name, net in nets.items():
        server.register(name, net)
    x = _images(110, 11, 12)                 # 2 slot rounds: 8 + 3

    plain = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["sharded"] == 0
    # a data axis that divides slot_capacity, whatever the host has
    with shd.use_mesh(make_host_mesh(data=math.gcd(len(jax.devices()), 8))):
        sharded = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["sharded"] == 1
    assert server.dispatch_counts["stacked"] == 2
    for name, net in nets.items():
        np.testing.assert_array_equal(sharded[name], plain[name])
        np.testing.assert_array_equal(sharded[name], _ref(net, x))
    # back outside the mesh: the single-device build serves again
    server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["sharded"] == 1


def test_netserver_sharded_falls_back_without_data_axis():
    """A mesh without a data axis (or a capacity the axis cannot divide)
    must cleanly fall back to the single-device stacked dispatch."""
    from repro.launch.mesh import make_mesh_compat
    from repro.parallel import sharding as shd

    server = netgen.NetServer(slot_capacity=8, warmup=False)
    for i, name in enumerate("ab"):
        server.register(name, _random_net(120 + i))
    x = _images(120, 8, 12)
    with shd.use_mesh(make_mesh_compat((1,), ("model",))):
        out = server.predict_many({"a": x, "b": x})
    assert server.dispatch_counts["stacked"] == 1
    assert server.dispatch_counts["sharded"] == 0
    np.testing.assert_array_equal(out["a"], _ref(_random_net(120), x))


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import sys
sys.path.insert(0, {test_dir!r})
from _netgen_helpers import random_net, images
from repro.core import quantize
from repro import netgen
from repro.launch.mesh import make_host_mesh
from repro.parallel import sharding as shd

assert len(jax.devices()) == 8
nets = {{name: random_net(130 + i, (12, 9, 4), lo=-5, hi=5)
        for i, name in enumerate("abc")}}
reqs = {{name: images(130 + i, 19, 12, salt=77)
        for i, name in enumerate("abc")}}
server = netgen.NetServer(target={target!r}, slot_capacity=16, warmup=False)
for name, net in nets.items():
    server.register(name, net)

single = server.predict_many(reqs)                   # single-device path
assert server.dispatch_counts["sharded"] == 0
with shd.use_mesh(make_host_mesh(data=8)):           # 8-way batch sharding
    sharded = server.predict_many(reqs)
assert server.dispatch_counts["sharded"] == 1, server.dispatch_counts
for name, net in nets.items():
    ref = np.asarray(quantize.predict_quantized(net)(jnp.asarray(reqs[name])))
    assert np.array_equal(sharded[name], single[name]), name
    assert np.array_equal(sharded[name], ref), name
print("SHARDED_NETSERVE_OK")
"""


@pytest.mark.parametrize("target", ["jnp", "pallas[packed=true]"])
def test_netserver_sharded_8_devices_bit_exact(target):
    """ISSUE satellite: sharded-vs-single-device bit-exactness of
    stacked predict_many on a real (faked-8-device) mesh — subprocess,
    because device count is fixed at jax init."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SHARDED_SCRIPT.format(
        test_dir=os.path.dirname(os.path.abspath(__file__)), target=target)
    out = subprocess.run(
        [sys.executable, "-c", script],
        env={**os.environ, "PYTHONPATH": src},
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED_NETSERVE_OK" in out.stdout


def test_pad_slots():
    x = np.arange(6, dtype=np.uint8).reshape(3, 2)
    padded, n = pad_slots(x, 5)
    assert padded.shape == (5, 2) and n == 3
    np.testing.assert_array_equal(padded[:3], x)
    assert not padded[3:].any()
    same, n_same = pad_slots(x, 3)
    assert same is x and n_same == 3
    with pytest.raises(ValueError):
        pad_slots(x, 2)


# ---------------------------------------------------------------------------
# ISSUE 7 regression tests: the latent serving-path concurrency bugs
# ---------------------------------------------------------------------------

def test_cache_compile_does_not_block_unrelated_keys():
    """Head-of-line blocking regression: while key A sits in a slow
    compile, a hit on key B — and even a fresh compile of key C — must
    proceed (the old code held the cache lock across the compile)."""
    import time

    started, release = threading.Event(), threading.Event()

    def slow_compile(circuit, **opts):
        started.set()
        assert release.wait(10.0), "test never released the slow compile"
        return lambda x: np.zeros((np.asarray(x).shape[0],), np.int64)

    netgen.register_target(netgen.Target(
        name="slowfake_hol", kind="callable",
        description="test-only gated-slow compile", compile=slow_compile))
    cache = netgen.CompileCache()
    net_a, net_b, net_c = _random_net(80), _random_net(81), _random_net(82)
    warm_b = cache.get_or_compile(net_b)     # resident before the stall
    out: dict = {}
    slow = threading.Thread(target=lambda: out.update(
        a=cache.get_or_compile(net_a, backend="slowfake_hol")))
    slow.start()
    try:
        assert started.wait(10.0)
        # watchdog thread instead of a bare call: under the old locking
        # this blocked forever, which should fail the test, not hang it
        hit: dict = {}
        h = threading.Thread(target=lambda: hit.update(
            b=cache.get_or_compile(net_b)))
        h.start()
        h.join(5.0)
        assert hit.get("b") is warm_b, \
            "hit on unrelated key blocked behind an in-flight compile"
        miss: dict = {}
        c = threading.Thread(target=lambda: miss.update(
            c=cache.get_or_compile(net_c)))
        c.start()
        c.join(30.0)
        assert "c" in miss, \
            "compile of unrelated key blocked behind an in-flight compile"
    finally:
        release.set()
        slow.join(10.0)
    assert out["a"] is cache.get_or_compile(net_a, backend="slowfake_hol")
    st = cache.stats()
    assert st.misses == st.compiles == 3     # b, a, c: one compile each
    assert st.hits == 2                      # the gated hit + the re-get


def test_register_warms_up_before_publishing():
    """Warmup race regression: a registering version must not be visible
    to concurrent predicts until its warmup trace has executed (the old
    code published into the routing table first)."""
    import time

    calls: list = []
    gate = threading.Event()

    def compile_cold(circuit, **opts):
        def artifact(x):
            calls.append(np.asarray(x).shape)
            if len(calls) == 1:              # the warmup execution
                assert gate.wait(10.0), "test never released the warmup"
            return np.zeros((np.asarray(x).shape[0],), np.int64)
        return artifact

    netgen.register_target(netgen.Target(
        name="coldfake_pub", kind="callable",
        description="test-only gated warmup", compile=compile_cold))
    server = netgen.NetServer(target="coldfake_pub", slot_capacity=4,
                              warmup=True)
    reg = threading.Thread(
        target=lambda: server.register("v", _random_net(85)))
    reg.start()
    try:
        deadline = time.time() + 10.0
        while not calls and time.time() < deadline:
            time.sleep(0.005)
        assert calls, "warmup never ran"
        # mid-warmup, the second thread must still see the OLD state
        assert server.versions() == []
        with pytest.raises(KeyError):
            server.predict("v", _images(86, 2, 12))
    finally:
        gate.set()
        reg.join(10.0)
    assert server.versions() == ["v"]
    assert len(calls) == 1                   # exactly one warmup execution
    assert calls[0] == (4, 12)               # the serving slot shape
    server.predict("v", _images(86, 2, 12))
    assert len(calls) == 2


def test_predict_many_skewed_batches_skip_empty_rounds():
    """Skewed-batch regression: with batch sizes (1, 4*cap) the rounds
    after the first must serve ONLY the longer version — no all-zero
    padded block for the exhausted one — and occupancy is observed over
    requested slots only."""
    cap = 4
    server = netgen.NetServer(slot_capacity=cap)
    net_a, net_b = _random_net(87), _random_net(88)
    server.register("a", net_a)
    server.register("b", net_b)
    xa, xb = _images(89, 1, 12), _images(90, 4 * cap, 12)
    out = server.predict_many({"a": xa, "b": xb})
    np.testing.assert_array_equal(out["a"], _ref(net_a, xa))
    np.testing.assert_array_equal(out["b"], _ref(net_b, xb))
    h = netgen.telemetry.get_registry().histogram(
        "netgen_slot_occupancy", server=server._scope)
    # round 0 stacks both: (1 + 4) / (2 * 4); rounds 1-3 are b alone
    # through the single-version tail at full occupancy. The old code
    # padded a's empty row into every round: 4 observations over 8
    # slots each, summing to 2.125.
    assert h.count == 4
    assert abs(h.sum - (5 / 8 + 3 * 1.0)) < 1e-9, h.snapshot()
    assert server.dispatch_counts["stacked"] == 1


def test_predict_many_records_per_version_service_time():
    """Latency misattribution regression: a 1-row version co-batched
    with a 16*cap-row one must record only the rounds it participated
    in, not the whole-call wall clock — and every version gets exactly
    one latency observation per dispatch (the check_trace.py gate)."""
    cap = 4
    server = netgen.NetServer(slot_capacity=cap)
    net_s, net_b = _random_net(91), _random_net(92)
    server.register("small", net_s)
    server.register("big", net_b)
    reqs = {"small": _images(93, 1, 12), "big": _images(94, 16 * cap, 12)}
    out = server.predict_many(reqs)
    np.testing.assert_array_equal(out["small"], _ref(net_s, reqs["small"]))
    np.testing.assert_array_equal(out["big"], _ref(net_b, reqs["big"]))
    tel = netgen.telemetry.get_registry()
    for v in ("small", "big"):
        lat = tel.histogram("netgen_predict_latency_seconds",
                            server=server._scope, version=v)
        req = tel.counter("netgen_requests_total",
                          server=server._scope, version=v)
        assert lat.count == 1 and int(req.value) == 1
    small = tel.histogram("netgen_predict_latency_seconds",
                          server=server._scope, version="small")
    big = tel.histogram("netgen_predict_latency_seconds",
                        server=server._scope, version="big")
    # small saw round 0 only; big additionally paid 15 more rounds
    assert small.sum < big.sum
