"""Deterministic stand-in for the `hypothesis` API subset the suite uses.

The property tests import `given / settings / strategies` at module
scope, so a missing hypothesis used to break *collection* of the whole
suite. Test modules now fall back to this stub, which runs each property
against a fixed number of pseudo-random examples drawn from a seed
derived from the test name — deterministic across runs and machines, no
shrinking, no database. Install the real `hypothesis` (see
requirements.txt) to get genuine randomized search; CI does.

Only the strategies the suite needs are provided (`integers`,
`sampled_from`, `booleans`). Extend here if a test needs more.
"""
from __future__ import annotations


import random
import zlib

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `hypothesis.strategies` module usage `st.<name>`
    @staticmethod
    def integers(min_value, max_value) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)


def given(**strats):
    """Run the wrapped test once per generated example set."""
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {k: s.example_from(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)
        # No functools.wraps: pytest must see the 0-arg wrapper signature,
        # not the strategy params (it would look for fixtures of that name).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        if hasattr(fn, "pytestmark"):
            wrapper.pytestmark = fn.pytestmark
        return wrapper
    return deco


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Records max_examples on the given-wrapper; other knobs (deadline,
    ...) are accepted and ignored."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco
