"""End-to-end system behaviour tests (the paper's pipeline as a system).

These tie the layers together the way the deliverables use them:
train -> quantize -> specialize -> serve, exactness of the specialized
artifacts, and the LM-side train->serve round trip through checkpoints.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import dataset, mlp, netgen, quantize
from repro.models import api, base
from repro.optim import adamw
from repro.serve.engine import Engine, ServeConfig
from repro.train import step as step_lib


@pytest.fixture(scope="module")
def paper_system():
    """A small trained instance of the paper's full pipeline."""
    xtr, ytr, xte, yte = dataset.train_test_split(500, 300, seed=11)
    cfg = mlp.MLPConfig(n_hidden=96, epochs=30, lr=2.0, seed=13)
    params = mlp.train(cfg, xtr, ytr)
    return params, xte, yte


def test_paper_pipeline_end_to_end(paper_system):
    """train -> ladder -> netgen -> specialized artifact, all consistent."""
    params, xte, yte = paper_system
    qnet = quantize.quantize(params)
    fn = netgen.specialize(qnet, backend="jnp")
    acc = float(np.mean(np.asarray(fn(jnp.asarray(xte))) == yte))
    base_acc = mlp.accuracy(mlp.predict_l0(params), xte, yte)
    assert acc > base_acc - 0.12          # paper: few-point cost
    v = netgen.emit_verilog(netgen.prune(qnet)[0], addend=True)
    assert v.count("assign") > qnet.w1.shape[1]  # one assign per node + I/O


def test_verilog_addend_form_has_no_multiplies(paper_system):
    params, _, _ = paper_system
    qnet = quantize.quantize(params)
    v = netgen.emit_verilog(netgen.prune(qnet)[0], addend=True)
    body = v.split("// hidden-input sums")[1]
    assert "*" not in body.split("// prediction")[0]


def test_lm_train_then_serve_roundtrip(tmp_path):
    """Train a smoke LM a few steps, checkpoint, restore, serve: the
    engine must produce identical generations from restored params."""
    from repro.checkpoint import ckpt as ckpt_lib

    cfg = configs.smoke("gemma-2b")
    shape = base.ShapeConfig("t", 16, 4, "train")
    oc = adamw.OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    state = base.tree_init(step_lib.abstract_state(cfg), jax.random.PRNGKey(0))
    train_step = jax.jit(step_lib.make_train_step(cfg, shape, oc))
    from repro.data.pipeline import make_batch
    for s in range(5):
        b = {k: jnp.asarray(v) for k, v in make_batch(cfg, shape, s).items()}
        state, _ = train_step(state, b)

    path = ckpt_lib.save(str(tmp_path), 5, state)
    restored = ckpt_lib.restore(path, step_lib.abstract_state(cfg))

    prompts = (np.arange(8, dtype=np.int32).reshape(2, 4) * 3) % cfg.vocab
    sc = ServeConfig(max_len=32, max_new_tokens=6)
    out1 = Engine(cfg, state["params"], sc).generate(prompts)
    out2 = Engine(cfg, restored["params"], sc).generate(prompts)
    np.testing.assert_array_equal(out1, out2)


def test_w8_served_lm_matches_quality(tmp_path):
    """Paper technique on a (briefly) trained LM: W8 generations mostly
    agree with fp generations (greedy argmax is robust to 1% weight
    perturbation on a confident model)."""
    from repro.quantized import apply as qapply

    cfg = configs.smoke("llama3.2-3b")
    params = base.tree_init(api.abstract_params(cfg), jax.random.PRNGKey(5))
    qp = qapply.quantize_params_for_serving(cfg, params, min_size=0)
    prompts = (np.arange(12, dtype=np.int32).reshape(3, 4) * 11) % cfg.vocab
    sc = ServeConfig(max_len=32, max_new_tokens=4)
    out_fp = Engine(cfg, params, sc).generate(prompts)
    out_q = Engine(cfg, qp, sc).generate(prompts)
    agree = (out_fp == out_q).mean()
    assert agree >= 0.5, agree            # random-init logits are near-ties
