"""Optimizer, gradient compression, schedule, and data-pipeline tests."""
import numpy as np
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep (requirements.txt); stub keeps suite collectable
    from _hypothesis_stub import given, settings, strategies as st

from repro import configs
from repro.data import pipeline
from repro.models import base
from repro.optim import adamw, compression


def test_schedule_warmup_cosine():
    oc = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                         min_lr_ratio=0.1)
    s = lambda t: float(adamw.schedule(oc, jnp.asarray(t)))
    assert s(0) == 0.0
    assert abs(s(10) - 1.0) < 0.11          # end of warmup ~ peak
    assert s(110) <= 0.1 + 1e-6 or abs(s(110) - 0.1) < 1e-5
    assert s(5) < s(10)


def test_adamw_converges_quadratic():
    oc = adamw.OptConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                         weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "v": jax.tree.map(jnp.zeros_like, params),
           "step": jnp.zeros((), jnp.int32)}
    for _ in range(300):
        grads = jax.tree.map(lambda w: 2 * w, params)   # d/dw w^2
        params, opt, _ = adamw.apply_updates(params, grads, opt, oc)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_applied():
    oc = adamw.OptConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    opt = {"m": {"w": jnp.zeros((4,))}, "v": {"w": jnp.zeros((4,))},
           "step": jnp.zeros((), jnp.int32)}
    _, _, metrics = adamw.apply_updates(
        params, {"w": jnp.full((4,), 100.0)}, opt, oc)
    assert float(metrics["grad_norm"]) > 100.0   # reported pre-clip


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1))
def test_int8_compression_bounded_error(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32) * 10)
    q, s = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, s, x.shape, jnp.float32)
    blockmax = float(jnp.max(jnp.abs(x)))
    # per-block error bound: half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= blockmax / 127.0 + 1e-5


def test_error_feedback_unbiased_over_time():
    """With error feedback, the cumulative compressed sum tracks the true
    cumulative sum (bias does not accumulate)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((1024,), jnp.float32)
    total_true = np.zeros(1024, np.float32)
    total_sent = np.zeros(1024, np.float32)
    for i in range(50):
        g = jnp.asarray(rng.normal(size=(1024,)).astype(np.float32))
        sent, err = compression.compress_decompress(g, err)
        total_true += np.asarray(g)
        total_sent += np.asarray(sent)
    # residual bounded by one step's quantization error, not 50 steps'
    resid = np.abs(total_true - total_sent).max()
    one_step = np.abs(np.asarray(g)).max() / 127 * 4
    assert resid < one_step * 3, (resid, one_step)


def test_data_deterministic_and_resumable():
    cfg = configs.smoke("qwen1.5-4b")
    shape = base.ShapeConfig("smoke", 16, 4, "train")
    b1 = pipeline.make_batch(cfg, shape, step=5, seed=9)
    b2 = pipeline.make_batch(cfg, shape, step=5, seed=9)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = pipeline.make_batch(cfg, shape, step=6, seed=9)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    it = pipeline.batch_iterator(cfg, shape, seed=9, start_step=5)
    s, b = next(it)
    assert s == 5
    np.testing.assert_array_equal(b["tokens"], b1["tokens"])


def test_data_has_learnable_structure():
    cfg = configs.smoke("qwen1.5-4b")
    shape = base.ShapeConfig("smoke", 128, 8, "train")
    b = pipeline.make_batch(cfg, shape, step=0, seed=1)
    toks, tgts = b["tokens"], b["targets"]
    pred = (toks.astype(np.int64) * (31337 % cfg.vocab) + 17) % cfg.vocab
    agreement = (pred == tgts).mean()
    assert agreement > 0.8, agreement     # ~90% bigram-predictable
